"""zamba2-1.2b [hybrid] 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention block [arXiv:2411.15242;
hf]. Simplifications noted in DESIGN.md (no concat-embedding projection or
LoRA on the shared block)."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=8192, vocab=32000, head_dim=64,
        mamba_version=2, ssm_state=64, d_inner=4096, d_conv=4,
        ssm_head_dim=64, attn_every=6, rope_theta=10000.0,
        tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b-smoke", family="hybrid", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=16,
        mamba_version=2, ssm_state=8, d_inner=128, d_conv=4,
        ssm_head_dim=32, attn_every=2, ssm_chunk=8, rope_theta=10000.0,
        tie_embeddings=True, remat="none")
