"""Subprocess helper: runs on 8 forced host devices; exits nonzero on
mismatch between the SPMD dkpca and the reference simulator."""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import KernelSpec, build_setup, run_admm  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.core.dkpca import dkpca_distributed  # noqa: E402
from repro.core.topology import ring  # noqa: E402
from repro.data import node_dataset  # noqa: E402


def main():
    mode = sys.argv[1] if len(sys.argv) > 1 else "exact"
    spec = KernelSpec(kind="rbf", gamma=None)
    j, n, m = 8, 16, 12
    nodes, _ = node_dataset(j, n, m, seed=0)
    mesh = make_mesh((4, 2), ("data", "model"))
    alpha0 = jax.random.normal(jax.random.PRNGKey(0), (j, n), jnp.float32)
    graph = ring(j, hops=2)

    if mode == "exact":
        center, use_pallas, project = "global", False, "ball"
    elif mode == "pallas":
        center, use_pallas, project = "global", True, "ball"
    elif mode == "rescale":
        center, use_pallas, project = "none", False, "rescale"
    else:
        raise SystemExit(f"unknown mode {mode}")

    setup = build_setup(jnp.asarray(nodes), graph, spec, center=center)
    sim = run_admm(setup, n_iters=10, alpha0=alpha0, project=project)
    dist = dkpca_distributed(nodes, mesh, ("data", "model"), hops=2,
                             spec=spec, center=center, n_iters=10,
                             alpha0=alpha0, project=project,
                             use_pallas=use_pallas)
    a_s = np.asarray(sim.alpha)
    a_d = np.asarray(dist.alpha)
    err = np.abs(a_s - a_d).max()
    scale = max(np.abs(a_s).max(), 1e-6)
    print(f"mode={mode} max|diff|={err:.3e} scale={scale:.3e}")
    assert err < 5e-3 * scale + 1e-4, f"mismatch: {err} vs scale {scale}"
    assert np.isfinite(a_d).all()
    print("OK")


if __name__ == "__main__":
    main()
