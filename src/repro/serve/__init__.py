from .engine import DecodeEngine, ServeConfig
from .kpca_engine import (EngineStats, KpcaEngine, KpcaServeConfig,
                          RequestStats)
from .sharded import project_sharded

__all__ = ["DecodeEngine", "EngineStats", "KpcaEngine", "KpcaServeConfig",
           "RequestStats", "ServeConfig", "project_sharded"]
