"""Jitted public wrappers around the projection Pallas kernel.

Two entry points share one Pallas kernel (``project_tiles``):

  * ``project_op`` — the single-device serving path: fused scores with the
    centering epilogue applied inside the kernel.
  * ``project_partial_op`` — the sharded serving path: raw per-shard partial
    scores plus the raw kernel row-sum, with NO epilogue; callers ``psum``
    partials across shards and apply the global centering terms exactly once
    after the reduction (see ``repro.serve.sharded``).

Both handle padding to block multiples (features zero-pad exactly; padded
support rows carry zero coefficients AND a zero entry in the fused ones-
column, so they contribute nothing to scores or row-means; padded query
rows are sliced off), sq-norm/self-kernel precomputation, component-axis
padding to the 128-lane boundary, gamma resolution and backend dispatch
(interpret=True everywhere except real TPU)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ...core.kernels_math import KernelSpec, resolve_gamma, _self_k
from ..autotune import get_tiles
from .._util import _on_tpu, _pad_to, _round_up
from .project import project_tiles


def _resolve_tiles(op: str, x_query: jax.Array, x_support: jax.Array,
                   block_q: Optional[int], block_l: Optional[int],
                   block_m: Optional[int]) -> Tuple[int, int, int]:
    """Fill unspecified tile sizes from the autotune table (fallback: the
    historical 128x128x512); explicit kwargs always win."""
    if block_q is None or block_l is None or block_m is None:
        tiles = get_tiles(op, (x_query.shape[0], x_support.shape[0],
                               x_query.shape[1]), x_query.dtype)
        block_q = block_q or tiles["block_q"]
        block_l = block_l or tiles["block_l"]
        block_m = block_m or tiles["block_m"]
    return block_q, block_l, block_m


def _prepare_operands(spec: KernelSpec, x_query: jax.Array,
                      x_support: jax.Array, gamma: Optional[jax.Array],
                      block_q: int, block_l: int, block_m: int
                      ) -> Tuple[jax.Array, ...]:
    """Shared preamble: gamma resolution, sq-norm/self-kernel precompute,
    block-size adaptation for small problems, and query/support padding.

    Returns (xq_pad, xs_pad, sq_pad, ss_pad, gamma, bq, bl, bm).
    """
    b_n, m = x_query.shape
    l = x_support.shape[0]
    if spec.kind == "rbf":
        g = resolve_gamma(spec, x_support) if gamma is None \
            else jnp.asarray(gamma)
        sq = jnp.sum(x_query.astype(jnp.float32) ** 2, axis=-1)
        ss = jnp.sum(x_support.astype(jnp.float32) ** 2, axis=-1)
    else:
        g = jnp.zeros((), jnp.float32)
        sq = _self_k(spec, x_query.astype(jnp.float32))
        ss = _self_k(spec, x_support.astype(jnp.float32))

    # adapt block sizes for small problems (interpret/test shapes)
    bq = min(block_q, _round_up(b_n, 8))
    bl = min(block_l, _round_up(l, 8))
    bm = min(block_m, _round_up(m, 128))

    xq = _pad_to(_pad_to(x_query, bm, 1), bq, 0)
    xs = _pad_to(_pad_to(x_support, bm, 1), bl, 0)
    sqp = _pad_to(sq, bq, 0)
    ssp = _pad_to(ss, bl, 0)
    return xq, xs, sqp, ssp, g, bq, bl, bm


def project_op(spec: KernelSpec, x_query: jax.Array, x_support: jax.Array,
               coefs: jax.Array,
               row_mean_coef: Optional[jax.Array] = None,
               bias: Optional[jax.Array] = None,
               gamma: Optional[jax.Array] = None,
               block_q: Optional[int] = None, block_l: Optional[int] = None,
               block_m: Optional[int] = None,
               interpret: Optional[bool] = None) -> jax.Array:
    """scores = K(x_query, x_support) @ coefs + rowmean(K) * c + b, fused.

    Args:
      spec: kernel specification (kind/gamma/degree/... — static metadata).
      x_query: (B, M) query batch.
      x_support: (L, M) support set (training samples or landmarks).
      coefs: (L, C) dual coefficients, one column per component.
      row_mean_coef: (C,) weight of mean_l K(x', x_l) in the score; default
        zeros (raw uncentered projection).
      bias: (C,) constant score offset; default zeros.
      gamma: () RBF bandwidth; resolved from ``spec``/median heuristic on
        ``x_support`` when None.
      block_q/block_l/block_m: Pallas tile sizes over the query/support/
        feature axes (auto-shrunk for small problems).
      interpret: force Pallas interpret mode; default: interpret everywhere
        except real TPU.

    Returns:
      (B, C) float32 scores. Matches
      ``repro.kernels.project.ref.project_reference`` (tested across shapes
      in tests/test_oos_projection.py).

    The row-mean needed for the centering term rides along as one extra
    all-ones column of the coefficient matrix (the "ones-column trick", see
    ``repro.kernels.project.project``), so the (B, L) kernel block is formed
    once and never materialized in HBM.
    """
    if interpret is None:
        interpret = not _on_tpu()
    block_q, block_l, block_m = _resolve_tiles(
        "project", x_query, x_support, block_q, block_l, block_m)
    b_n, m = x_query.shape
    l, c = coefs.shape
    assert x_support.shape == (l, m), (x_query.shape, x_support.shape,
                                       coefs.shape)
    if row_mean_coef is None:
        row_mean_coef = jnp.zeros((c,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((c,), jnp.float32)

    xq, xs, sqp, ssp, g, bq, bl, bm = _prepare_operands(
        spec, x_query, x_support, gamma, block_q, block_l, block_m)
    cp = _round_up(c + 1, 128)

    # A extended with the row-sum ones-column at index c (zero on padded
    # support rows), then padded to (L_pad, CP).
    ones = jnp.ones((l, 1), jnp.float32)
    a_ext = jnp.concatenate([coefs.astype(jnp.float32), ones], axis=1)
    a_ext = _pad_to(_pad_to(a_ext, cp, 1), bl, 0)
    c_ext = _pad_to(row_mean_coef.astype(jnp.float32), cp, 0)
    b_ext = _pad_to(bias.astype(jnp.float32), cp, 0)

    out = project_tiles(
        xq, xs, a_ext, sqp, ssp,
        jnp.reshape(g, (1,)).astype(jnp.float32),
        jnp.full((1,), 1.0 / l, jnp.float32), c_ext, b_ext,
        kind=spec.kind, degree=spec.degree, coef=spec.coef, scale=spec.scale,
        normalize=spec.normalize, block_q=bq, block_l=bl, block_m=bm,
        sum_col=c, interpret=interpret)
    return out[:b_n, :c]


def project_partial_op(spec: KernelSpec, x_query: jax.Array,
                       x_support: jax.Array, coefs_ext: jax.Array,
                       gamma: Optional[jax.Array] = None,
                       block_q: Optional[int] = None,
                       block_l: Optional[int] = None,
                       block_m: Optional[int] = None,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Per-shard partial scores: K(x_query, x_support) @ coefs_ext, raw.

    Args:
      spec: kernel specification (static metadata).
      x_query: (B, M) query batch (replicated across shards).
      x_support: (L_j, M) THIS shard's slice of the support set (possibly
        zero-padded to a common per-shard length).
      coefs_ext: (L_j, C+1) this shard's dual-coefficient rows with one
        extra indicator column at index C: 1.0 on valid support rows, 0.0 on
        shard-padding rows. The indicator column makes the output's last
        column the raw kernel row-sum over exactly the valid rows.
      gamma: () RBF bandwidth; must be the fit-time value for sharded
        serving (per-shard median heuristics would disagree across shards).
      block_q/block_l/block_m, interpret: as in ``project_op``.

    Returns:
      (B, C+1) float32: columns :C are the partial scores
      sum_{l in shard} K(x_q, x_l) coefs[l, c]; column C is the partial raw
      row-sum sum_{l in shard} K(x_q, x_l). NO centering epilogue is applied
      — the global row-mean/bias terms depend on the FULL support set, so
      callers ``psum`` the (B, C+1) partials over the shard axis and apply
      them exactly once after the reduction (``repro.core.oos
      .finalize_partial_scores``).
    """
    if interpret is None:
        interpret = not _on_tpu()
    block_q, block_l, block_m = _resolve_tiles(
        "project_partial", x_query, x_support, block_q, block_l, block_m)
    b_n, m = x_query.shape
    l, cp1 = coefs_ext.shape
    assert x_support.shape == (l, m), (x_query.shape, x_support.shape,
                                       coefs_ext.shape)

    xq, xs, sqp, ssp, g, bq, bl, bm = _prepare_operands(
        spec, x_query, x_support, gamma, block_q, block_l, block_m)
    cp = _round_up(cp1, 128)
    a_ext = _pad_to(_pad_to(coefs_ext.astype(jnp.float32), cp, 1), bl, 0)
    zeros = jnp.zeros((cp,), jnp.float32)

    # row_mean_coef/bias are all-zero, so the kernel's in-tile centering
    # epilogue is the identity and every output column comes out raw.
    out = project_tiles(
        xq, xs, a_ext, sqp, ssp,
        jnp.reshape(g, (1,)).astype(jnp.float32),
        jnp.ones((1,), jnp.float32), zeros, zeros,
        kind=spec.kind, degree=spec.degree, coef=spec.coef, scale=spec.scale,
        normalize=spec.normalize, block_q=bq, block_l=bl, block_m=bm,
        sum_col=cp1 - 1, interpret=interpret)
    return out[:b_n, :cp1]
