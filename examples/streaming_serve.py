"""Streaming alpha: serve projections FROM A STILL-RUNNING ADMM fit.

    PYTHONPATH=src python examples/streaming_serve.py

The chunked solver driver (repro.core.solver.run_chunked) yields its live
state every few iterations; each snapshot is rebuilt into a servable
FittedKpca with the cached kernel-mean statistics (no Gram re-formation)
and atomically published into the engine's ModelHandle. Queries keep
flowing the whole time — each flush serves one consistent model version —
and the served scores sharpen chunk by chunk as the consensus converges."""

import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, build_setup, oos, solver
from repro.core.admm import initial_alpha
from repro.core.topology import ring
from repro.data import node_dataset
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle


def main():
    nodes, pooled = node_dataset(n_nodes=8, n_per_node=40, m=24, seed=0)
    spec = KernelSpec(kind="rbf")
    setup = build_setup(jnp.asarray(nodes), ring(8, hops=2), spec)

    # seed the handle from the warm-start alpha, start serving immediately
    a0 = initial_alpha(setup, "local")
    handle = ModelHandle(oos.from_decentralized(
        nodes, a0, spec, gamma=setup.gamma, center=True))
    engine = KpcaEngine(handle, KpcaServeConfig(max_batch=32, min_bucket=8))

    xq = np.random.default_rng(1).normal(size=(16, 24)).astype(np.float32)
    gold = oos.project(oos.fit_central(jnp.asarray(pooled), spec, 1,
                                       gamma=setup.gamma), jnp.asarray(xq))
    gold = np.asarray(gold)[:, 0]

    print("chunk  iter  version  primal-res  corr(served, central-fit)")
    for i, chunk in enumerate(
            solver.run_chunked(setup, n_iters=24, chunk=4, tol=1e-3)):
        version = handle.refresh(chunk.state.alpha)   # publish live coefs
        scores = engine.project_many([xq])[0][:, 0]   # serve on new version
        corr = float(np.corrcoef(scores, gold)[0, 1])
        print(f"{i + 1:5d}  {int(chunk.state.t):4d}  {version:7d}  "
              f"{float(chunk.primal_residual[-1]):10.2e}  {abs(corr):.4f}")

    stats = engine.stats
    print(f"served {stats.n_queries} queries across {stats.n_requests} "
          f"requests while fitting; final model version {handle.version}")


if __name__ == "__main__":
    main()
