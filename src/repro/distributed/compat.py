"""jax version compatibility for SPMD primitives.

``jax.shard_map`` (with the ``check_vma`` kwarg) only exists on newer jax;
older releases ship ``jax.experimental.shard_map.shard_map`` whose
equivalent kwarg is ``check_rep``. Resolve one callable with the NEW
surface (mesh/in_specs/out_specs/check_vma keywords) for all call sites.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:
    # Older jax has no varying-mesh-axes tracking; pvary is bookkeeping
    # only, so identity is exact.
    def pvary(x, axis_name):
        del axis_name
        return x

__all__ = ["pvary", "shard_map"]
