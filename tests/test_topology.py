"""Tests for the consensus-graph module (paper Assumption 1 + fault tolerance)."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.topology import (complete, from_adjacency, random_connected,
                                 reknit, ring, ring_shifts)


class TestRing:
    def test_paper_setting_four_neighbors(self):
        g = ring(20, hops=2)  # the paper's "4 closest neighbors"
        assert (g.degrees == 4).all()
        assert g.nbr[0] == (18, 19, 1, 2)

    def test_shift_order_matches_slots(self):
        g = ring(10, hops=3)
        shifts = ring_shifts(3)
        for j in range(10):
            assert list(g.nbr[j]) == [(j + s) % 10 for s in shifts]

    def test_rev_slots(self):
        g = ring(8, 2)
        for j in range(8):
            for d, l in enumerate(g.nbr[j]):
                assert g.nbr[l][g.rev[j][d]] == j

    def test_too_small_raises(self):
        with pytest.raises(ValueError):
            ring(4, hops=2)

    @settings(max_examples=15, deadline=None)
    @given(j=st.integers(5, 40), hops=st.integers(1, 2))
    def test_property_connected_regular(self, j, hops):
        g = ring(j, hops)
        assert g.connected() and g.is_regular


class TestOtherGraphs:
    def test_complete(self):
        g = complete(5)
        assert (g.degrees == 4).all()

    def test_random_connected(self):
        for seed in range(5):
            g = random_connected(12, 0.3, seed)
            assert g.connected()

    def test_from_adjacency_asymmetric_raises(self):
        a = np.zeros((3, 3), bool)
        a[0, 1] = True
        with pytest.raises(ValueError):
            from_adjacency(a)

    def test_neighbor_array_masking(self):
        g = random_connected(9, 0.2, seed=3)
        ids, rev, mask = g.neighbor_array()
        assert mask.sum() == g.degrees.sum()
        for j in range(9):
            assert list(ids[j][mask[j]]) == list(g.nbr[j])


class TestReknit:
    def test_single_failure(self):
        g = ring(12, 2)
        g2, survivors = reknit(g, [5])
        assert g2.n_nodes == 11
        assert g2.connected()
        assert 5 not in survivors

    def test_adjacent_block_failure(self):
        g = ring(16, 2)
        g2, survivors = reknit(g, [3, 4, 5, 6])
        assert g2.n_nodes == 12
        assert g2.connected()

    def test_cut_vertex_path_graph(self):
        # path-ish graph where removing the middle disconnects
        adj = np.zeros((5, 5), bool)
        for i in range(4):
            adj[i, i + 1] = adj[i + 1, i] = True
        g = from_adjacency(adj)
        g2, _ = reknit(g, [2])
        assert g2.connected()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50))
    def test_property_survivors_connected(self, seed):
        rng = np.random.default_rng(seed)
        g = ring(14, 2)
        dead = rng.choice(14, size=3, replace=False)
        g2, survivors = reknit(g, dead)
        assert g2.connected()
        assert len(survivors) == 11
