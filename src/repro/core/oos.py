"""Out-of-sample projection: the fitted-model artifact for serving kPCA.

The product of the whole fitting pipeline (central eigensolve, Alg.-1 ADMM
consensus, or top-k deflation) is a set of dual coefficient vectors; what a
*serving* system needs is the centered out-of-sample score (paper §1):

    score_c(x') = (w*)^T phi_c(x')
                = sum_i alpha_i [K(x_i, x') - m(x') - m_i + mu_bar]

with m(x') = mean_t K(x', t) over the training set, m_i = mean_t K(x_i, t)
and mu_bar the grand mean (the same ``kernel_mean_stats`` quantities the
decentralized fit centers with). Grouping terms, every model this module
produces — centered, uncentered, or landmark-compressed — serves through ONE
formula:

    score(x') = K(x', X_s) @ coefs + mean_l K(x', x_l) * row_mean_coef + bias

i.e. a single (B, L) kernel block against the support set X_s with a fused
row-mean + bias epilogue. ``repro.kernels.project`` implements exactly this
contract as a tiled Pallas kernel; this module is the numerical ground truth
and the artifact container.

Landmark compression (``compress``) projects each component w = Phi(X) a_eff
onto span{phi(z_l)} of L landmarks (Nystrom, in the spirit of Balcan et
al.'s communication-efficient distributed kPCA): beta = K_ZZ^+ K_ZX a_eff.
Because it is an orthogonal projection in the RKHS, the reconstruction error
||w - w_hat||_H is computable exactly at compress time (returned alongside
the model) and is monotonically non-increasing in L for nested landmark
sets, which ``landmark_schedule``'s fixed-seed prefixes guarantee.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .kernels_math import KernelSpec, gram, resolve_gamma


@dataclasses.dataclass(frozen=True)
class FittedKpca:
    """Servable kPCA model: support set + dual coefficients + centering.

    x_support:     (L, M) training samples or landmarks.
    coefs:         (L, C) dual coefficients, one column per component.
    row_mean_coef: (C,) weight of mean_l K(x', x_l) in the score
                   (``-sum_i alpha_i`` for a centered fit; 0 otherwise).
    bias:          (C,) constant score offset (``mu_bar sum_i alpha_i
                   - m . alpha`` for a centered fit; 0 otherwise).
    gamma:         () resolved RBF bandwidth actually used at fit time.
    spec:          kernel spec (static pytree metadata).
    """

    x_support: jax.Array
    coefs: jax.Array
    row_mean_coef: jax.Array
    bias: jax.Array
    gamma: jax.Array
    spec: KernelSpec = KernelSpec()

    @property
    def n_support(self) -> int:
        return self.x_support.shape[0]

    @property
    def n_features(self) -> int:
        return self.x_support.shape[1]

    @property
    def n_components(self) -> int:
        return self.coefs.shape[1]


def _flatten(m: FittedKpca):
    return ((m.x_support, m.coefs, m.row_mean_coef, m.bias, m.gamma),
            m.spec)


def _unflatten(spec, leaves):
    return FittedKpca(*leaves, spec=spec)


jax.tree_util.register_pytree_node(FittedKpca, _flatten, _unflatten)


def _as_2d(alpha: jax.Array) -> jax.Array:
    alpha = jnp.asarray(alpha)
    return alpha[:, None] if alpha.ndim == 1 else alpha


def from_dual(x_train: jax.Array, alpha: jax.Array, spec: KernelSpec,
              gamma: Optional[jax.Array] = None,
              center: bool = True) -> FittedKpca:
    """Build the artifact from any dual solution alpha (N,) or (N, C).

    For ``center=True`` the *uncentered* training Gram is formed once here
    (fit-time cost) to extract the kernel mean statistics the centered score
    needs; serving never touches the training Gram again.
    """
    x_train = jnp.asarray(x_train)
    alpha = _as_2d(alpha).astype(jnp.float32)
    g = resolve_gamma(spec, x_train) if gamma is None else jnp.asarray(gamma)
    c = alpha.shape[1]
    if center:
        k_raw = gram(spec, x_train, gamma=g)
        m = jnp.mean(k_raw, axis=1)                       # (N,)
        mu_bar = jnp.mean(k_raw)
        alpha_sum = jnp.sum(alpha, axis=0)                # (C,)
        row_mean_coef = -alpha_sum
        bias = mu_bar * alpha_sum - m @ alpha
    else:
        row_mean_coef = jnp.zeros((c,), jnp.float32)
        bias = jnp.zeros((c,), jnp.float32)
    return FittedKpca(x_support=x_train, coefs=alpha,
                      row_mean_coef=row_mean_coef, bias=bias,
                      gamma=g.astype(jnp.float32), spec=spec)


def fit_central(x: jax.Array, spec: KernelSpec, n_components: int = 1,
                center: bool = True,
                gamma: Optional[jax.Array] = None) -> FittedKpca:
    """Fit central kPCA (paper problem (2)) and package it for serving."""
    from .central import central_kpca
    x = jnp.asarray(x)
    g = resolve_gamma(spec, x) if gamma is None else jnp.asarray(gamma)
    alpha, _, _ = central_kpca(x, spec, n_components, center=center, gamma=g)
    return from_dual(x, alpha, spec, gamma=g, center=center)


def from_decentralized(x_nodes: jax.Array,
                       alpha: Union[jax.Array, Sequence[jax.Array]],
                       spec: KernelSpec, gamma: Optional[jax.Array] = None,
                       center: bool = True) -> FittedKpca:
    """Package an Alg.-1 consensus solution for serving.

    x_nodes: (J, N, M); alpha: (J, N) from ``run_admm`` or a list of (J, N)
    from ``run_admm_topk``. At consensus every node's w_j = phi(X_j) alpha_j
    approximates the same global component, so the pooled dual vector
    concat_j(alpha_j) / J represents their average on the pooled support
    set. ``center=True`` matches fits built with ``build_setup(...,
    center="global")`` (same global kernel-mean statistics).
    """
    x_nodes = jnp.asarray(x_nodes)
    j, n, m = x_nodes.shape
    if not isinstance(alpha, (list, tuple)):
        alpha = [alpha]
    pooled_alpha = jnp.stack(
        [jnp.reshape(a, (j * n,)) for a in alpha], axis=1) / j
    return from_dual(x_nodes.reshape(j * n, m), pooled_alpha, spec,
                     gamma=gamma, center=center)


def project(model: FittedKpca, x_query: jax.Array,
            use_pallas: bool = False,
            interpret: Optional[bool] = None) -> jax.Array:
    """Centered out-of-sample scores for a query batch: (B, M) -> (B, C)."""
    x_query = jnp.asarray(x_query)
    if use_pallas:
        from ..kernels.project import project_op
        return project_op(model.spec, x_query, model.x_support, model.coefs,
                          row_mean_coef=model.row_mean_coef, bias=model.bias,
                          gamma=model.gamma, interpret=interpret)
    k = gram(model.spec, x_query, model.x_support, gamma=model.gamma)
    return (k @ model.coefs
            + jnp.mean(k, axis=1, keepdims=True) * model.row_mean_coef[None]
            + model.bias[None, :])


def effective_coefs(model: FittedKpca) -> jax.Array:
    """Fold the row-mean term into the dual coefficients:
    mean_l K(x', x_l) * c == K(x', X_s) @ (c/L * 1), so
    w = Phi(X_s) @ (coefs + row_mean_coef / L). Used by compression."""
    return model.coefs + model.row_mean_coef[None, :] / model.n_support


def landmark_schedule(n_support: int, seed: int = 0) -> np.ndarray:
    """Fixed random permutation of the support set; taking prefixes of it
    yields NESTED landmark sets, so compression error is monotone in L."""
    return np.random.default_rng(seed).permutation(n_support)


def compress(model: FittedKpca, n_landmarks: int,
             seed: int = 0, rel_thresh: float = 1e-7
             ) -> Tuple[FittedKpca, jax.Array]:
    """Nystrom landmark compression of the support set.

    Projects each component w = Phi(X_s) a_eff onto span{phi(z_l)} of
    ``n_landmarks`` support points: beta = K_ZZ^+ K_ZX a_eff. Serving cost
    per query drops from O(L_full * M) to O(n_landmarks * M).

    Returns (compressed model, rel_err (C,)) with
    rel_err_c = ||w_c - w_hat_c||_H / ||w_c||_H, exact (computed from the
    Pythagorean identity for the RKHS projection).
    """
    l_full = model.n_support
    if not 0 < n_landmarks <= l_full:
        raise ValueError(f"n_landmarks={n_landmarks} not in [1, {l_full}]")
    idx = landmark_schedule(l_full, seed)[:n_landmarks]
    z = model.x_support[jnp.asarray(idx)]
    a_eff = effective_coefs(model)

    kzz = gram(model.spec, z, gamma=model.gamma)
    kzx = gram(model.spec, z, model.x_support, gamma=model.gamma)
    t = kzx @ a_eff                                      # (L, C) = Phi(Z)^T w
    lam, v = jnp.linalg.eigh(kzz)
    inv = jnp.where(lam > rel_thresh * jnp.maximum(lam[-1], 1e-30),
                    1.0 / lam, 0.0)
    beta = v @ (inv[:, None] * (v.T @ t))                # K_ZZ^+ Phi(Z)^T w

    kxx = gram(model.spec, model.x_support, gamma=model.gamma)
    w2 = jnp.sum(a_eff * (kxx @ a_eff), axis=0)          # ||w||_H^2
    wh2 = jnp.sum(beta * (kzz @ beta), axis=0)           # ||w_hat||_H^2
    rel_err = jnp.sqrt(jnp.clip(w2 - wh2, 0.0) / jnp.maximum(w2, 1e-30))

    compressed = FittedKpca(
        x_support=z, coefs=beta,
        row_mean_coef=jnp.zeros_like(model.row_mean_coef),
        bias=model.bias, gamma=model.gamma, spec=model.spec)
    return compressed, rel_err


# ---- persistence (repro.checkpoint layout) --------------------------------

def save_fitted(ckpt_dir: str, model: FittedKpca) -> str:
    """Write the artifact with the atomic checkpoint writer (step 0)."""
    from ..checkpoint import save_checkpoint
    tree = {"x_support": model.x_support, "coefs": model.coefs,
            "row_mean_coef": model.row_mean_coef, "bias": model.bias,
            "gamma": model.gamma}
    meta = {"kind": "fitted_kpca", "spec": dataclasses.asdict(model.spec)}
    return save_checkpoint(ckpt_dir, 0, tree, metadata=meta, keep_last=1)


def load_fitted(ckpt_dir: str) -> FittedKpca:
    from ..checkpoint import restore_checkpoint
    tree, meta, _ = restore_checkpoint(ckpt_dir)
    if meta.get("kind") != "fitted_kpca":
        raise ValueError(f"{ckpt_dir} is not a FittedKpca checkpoint: {meta}")
    spec = KernelSpec(**meta["spec"])
    return FittedKpca(x_support=tree["x_support"], coefs=tree["coefs"],
                      row_mean_coef=tree["row_mean_coef"],
                      bias=tree["bias"], gamma=tree["gamma"], spec=spec)


__all__ = [
    "FittedKpca", "compress", "effective_coefs", "fit_central", "from_dual",
    "from_decentralized", "landmark_schedule", "load_fitted", "project",
    "save_fitted",
]
