"""Projection-consensus gradient compression (PowerSGD-style low-rank with
error feedback).

Beyond-paper feature (DESIGN.md §4): the paper's core idea — agree on a
global low-dimensional subspace and communicate only projections onto it —
applied to data-parallel gradient aggregation. Per 2D+ parameter G (folded
to (m, n)):

    1. Q = orth(G^T P_prev)     one power-iteration step against the
    2. P = G Q                  previous consensus subspace (warm start)
    3. all-reduce P (and Q) instead of G:  m*r + n*r numbers vs m*n
    4. G_hat = P Q^T;  error e = G - G_hat is fed back into the next step.

``compress_allreduce`` performs the psum inside a shard_map over the data
axis; ``compress_local`` exposes the pure math for tests. 1D params are
aggregated exactly (they are tiny)."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def _fold(g: jax.Array) -> jax.Array:
    """Fold to 2D: leading dims (incl. layer stacks) merge into rows."""
    if g.ndim == 1:
        return g[None, :]
    return g.reshape(-1, g.shape[-1])


def _orthonormalize(q: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR (r is small)."""
    qq, _ = jnp.linalg.qr(q)
    return qq


def init_compression_state(params: Dict[str, jax.Array], rank: int = 4,
                           seed: int = 0):
    """Per-param error-feedback buffer + warm-start P."""
    state = {}
    key = jax.random.PRNGKey(seed)
    for k, v in params.items():
        if v.ndim < 2:
            continue
        g2 = _fold(jnp.zeros(v.shape, jnp.float32))
        key, sub = jax.random.split(key)
        state[k] = {
            "err": jnp.zeros(g2.shape, jnp.float32),
            "p": jax.random.normal(sub, (g2.shape[0], rank), jnp.float32),
        }
    return state


def compress_local(g: jax.Array, err: jax.Array, p_prev: jax.Array):
    """One PowerSGD round on a single worker's gradient (no psum).
    Returns (p, q, new_err) with g_hat = p @ q.T."""
    g2 = _fold(g.astype(jnp.float32)) + err
    q = _orthonormalize(g2.T @ p_prev)            # (n, r)
    p = g2 @ q                                    # (m, r)
    g_hat = p @ q.T
    return p, q, g2 - g_hat


def compressed_psum_grads(grads: Dict[str, jax.Array], state, mesh,
                          data_axes=("data",)):
    """All-reduce gradients across the data axis with low-rank compression.

    grads are per-shard (un-psummed) values inside a shard_map over
    ``data_axes``. Returns (aggregated grads, new state). Compression math
    follows PowerSGD: psum(P) with the SAME Q on every worker approximates
    psum(G) projected onto span(Q)."""
    new_grads, new_state = {}, {}
    n_shards = 1
    for a in data_axes:
        n_shards *= mesh.shape[a]
    for k, g in grads.items():
        if k not in state:
            new_grads[k] = jax.lax.pmean(g.astype(jnp.float32), data_axes) \
                .astype(g.dtype)
            continue
        st = state[k]
        g2 = _fold(g.astype(jnp.float32)) + st["err"]
        # consensus subspace: everyone uses the SAME p_prev (replicated),
        # so q is identical across workers after the psum below.
        q = _orthonormalize(jax.lax.pmean(g2.T @ st["p"], data_axes))
        p = jax.lax.pmean(g2 @ q, data_axes)       # the compressed psum
        g_hat = p @ q.T
        new_state[k] = {"err": g2 - g_hat, "p": p}
        new_grads[k] = g_hat.reshape(g.shape).astype(g.dtype)
    return new_grads, new_state


def compression_ratio(params: Dict[str, jax.Array], rank: int) -> float:
    """Communication volume ratio: compressed / dense."""
    dense = comp = 0
    for k, v in params.items():
        n = v.size
        dense += n
        if v.ndim < 2:
            comp += n
        else:
            g2 = _fold(v)
            comp += rank * (g2.shape[0] + g2.shape[1])
    return comp / dense
