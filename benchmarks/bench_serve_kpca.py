"""Serving benchmark: batched kPCA projection engine vs per-query dispatch.

Reports queries/s throughput and p50/p99 request latency as a function of
(a) engine batch width and (b) landmark count after Nystrom compression.
The acceptance bar for the subsystem is >= 2x throughput for the batched
engine vs one-query-at-a-time projection at batch 64 (on CPU the win is
dispatch amortization; on TPU it is additionally MXU utilization — a (1, L)
kernel row leaves 127/128 MXU lanes idle).

Timing validity: every engine row is WALL-CLOCKED around the blocking
``project_many`` call, whose returned arrays are host numpy (the futures
resolve only after device->host transfer) — so the timed region provably
contains the work. Earlier revisions divided by the engine's device-time
accounting instead, which reported ns-scale "per-call" numbers while the
caller was actually waiting on the queue; ``tools.lint``'s
untimed-device-call rule now rejects that pattern in benchmarks/.
Every row carries a ``compiles=`` field: after mandatory warmup it must
be 0, otherwise the row timed compilation, not serving.
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, oos
from repro.data import kpca_dataset
from repro.serve import KpcaEngine, KpcaServeConfig
from repro.serve.batching import format_latency

SPEC = KernelSpec(kind="rbf")


def _fit(n=512, m=128, c=2, seed=0):
    x = jnp.asarray(kpca_dataset(n, m=m, seed=seed))
    return oos.fit_central(x, SPEC, n_components=c, center=True)


def _queries(n, m, seed=1):
    return kpca_dataset(n, m=m, seed=seed)


def _per_query_baseline(model, queries, n_probe=64):
    """One jitted projection call per single query (B=1 serving)."""
    proj = jax.jit(lambda mm, xq: oos.project(mm, xq))
    jax.block_until_ready(proj(model, jnp.asarray(queries[:1])))  # compile
    t0 = time.perf_counter()
    for i in range(n_probe):
        jax.block_until_ready(proj(model, jnp.asarray(queries[i:i + 1])))
    dt = time.perf_counter() - t0
    return n_probe / dt, dt / n_probe * 1e6       # qps, us/query


def bench_serve_kpca(m: int = 128):
    rows = []
    n_train, n_queries = 512, 1024
    model = _fit(n=n_train, m=m)
    queries = _queries(n_queries, m)

    qps_b1, us_b1 = _per_query_baseline(model, queries)
    rows.append(("serve/per_query", us_b1, f"qps={qps_b1:.0f};batch=1"))

    # ---- throughput & latency vs engine batch width ----------------------
    for batch in (16, 64, 128):
        cfg = KpcaServeConfig(max_batch=batch, min_bucket=8)
        eng = KpcaEngine(model, cfg)
        eng.warmup()                                  # compile every bucket
        eng.stats = type(eng.stats)()                 # steady-state stats
        # request mix: many small requests (latency) + bulk (throughput)
        rng = np.random.default_rng(batch)
        sizes = rng.integers(1, 17, size=64).tolist() + [256, 256]
        off, reqs = 0, []
        for q in sizes:
            reqs.append(np.take(queries, range(off, off + q), axis=0,
                                mode="wrap"))
            off += q
        n_rows = sum(r.shape[0] for r in reqs)
        t0 = time.perf_counter()
        out = eng.project_many(reqs)                  # returns HOST numpy
        wall = time.perf_counter() - t0
        assert all(isinstance(o, np.ndarray) for o in out)
        st = eng.stats
        p50, p99 = st.latency_percentiles()
        qps = n_rows / wall
        speedup = qps / max(qps_b1, 1e-9)
        rows.append((f"serve/batch{batch}", wall / n_rows * 1e6,
                     f"qps={qps:.0f};p50={format_latency(p50)};"
                     f"p99={format_latency(p99)};speedup_vs_per_query="
                     f"{speedup:.1f}x;compiles={st.n_compiles};"
                     f"zero_copy={st.n_zero_copy_slabs}/{st.n_flushes}"))

    # ---- throughput & accuracy vs landmark count -------------------------
    bulk = [queries]                                  # one big request
    for n_l in (64, 128, 256, n_train):
        cm, err = oos.compress(model, n_l, seed=0)
        eng = KpcaEngine(cm, KpcaServeConfig(max_batch=64, min_bucket=8))
        eng.project_many(bulk)                        # compile
        eng.stats = type(eng.stats)()                 # reset after warmup
        t0 = time.perf_counter()
        out = eng.project_many(bulk)                  # returns HOST numpy
        wall = time.perf_counter() - t0
        qps = n_queries / wall
        rows.append((f"serve/landmarks{n_l}", wall / n_queries * 1e6,
                     f"qps={qps:.0f};rel_err={float(np.max(err)):.1e};"
                     f"support={n_l}/{n_train};"
                     f"compiles={eng.stats.n_compiles}"))
    return rows


def _fit_dual(n, m, c=2, seed=0):
    """N-row support model without the O(N^3) eigensolve: random dual
    coefficients through ``oos.from_dual``. Serving cost per query row is
    identical to a real fit — only the eigenvector VALUES differ — so the
    large-support rows time exactly what production serving would."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(kpca_dataset(n, m=m, seed=seed))
    alpha = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    return oos.from_dual(x, alpha, SPEC, center=True)


def bench_serve_sharded(m: int = 128):
    """Shard-count x per-shard-landmark x batch sweep for sharded serving.

    Every engine routes adaptively (``KpcaServeConfig.routing="auto"``):
    per drain the ``ShardedRouter`` picks model-parallel psum ("mp"),
    query-sharded data-parallel ("dp") or the single-device reduction
    ("single") from the (rows, support) crossover table. Each row records
    the policies actually taken (``routing=``) plus the max overlapped
    drain depth (``depth=``; >0 only on the started, pipelined engines).
    ``err_bound`` is the aggregate relative RKHS error bound of per-shard
    Nystrom compression; 0 means no compression. ``--host-devices`` in
    ``benchmarks/run.py`` controls the CPU device count.
    """
    rows = []
    n_train, n_queries = 512, 512
    model = _fit(n=n_train, m=m)
    bulk = [jnp.asarray(_queries(n_queries, m))]
    n_dev = jax.device_count()
    for n_shards in (1, 2, 4):
        for n_l in (None, 128, 64):
            sharded, bound = oos.shard_fitted(model, n_shards,
                                              landmarks_per_shard=n_l)
            eng = KpcaEngine(sharded,
                             KpcaServeConfig(max_batch=128, min_bucket=8))
            eng.warmup()                              # compile every bucket
            eng.stats = type(eng.stats)()
            t0 = time.perf_counter()
            eng.project_many(bulk)                    # returns HOST numpy
            wall = time.perf_counter() - t0
            qps = n_queries / wall
            st = eng.stats
            lm = "full" if n_l is None else str(n_l)
            rows.append((
                f"serve/shards{n_shards}_lm{lm}", wall / n_queries * 1e6,
                f"qps={qps:.0f};routing={st.routing_summary()};"
                f"depth={st.max_inflight_drains};"
                f"err_bound={float(np.max(bound)):.1e};"
                f"support={sharded.n_support};"
                f"devices={min(n_shards, n_dev)};"
                f"compiles={st.n_compiles}"))

    # ---- forced model-parallel at small support --------------------------
    # The router deliberately picks "single" for shards4_lmfull (support 512
    # fits one device; psum + 4-way dispatch only adds overhead on a host
    # CPU). This row pins what forcing "mp" costs there, and — against the
    # pre-router baseline in BENCH_9 — what cached per-version placement
    # bought the mp path itself.
    sharded, _ = oos.shard_fitted(model, 4)
    eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=128, min_bucket=8,
                                              routing="mp"))
    eng.warmup()
    eng.stats = type(eng.stats)()
    t0 = time.perf_counter()
    eng.project_many(bulk)
    wall = time.perf_counter() - t0
    st = eng.stats
    rows.append((
        "serve/shards4_lmfull_mp", wall / n_queries * 1e6,
        f"qps={n_queries / wall:.0f};routing={st.routing_summary()};"
        f"placements={eng._router.n_placements};"
        f"compiles={st.n_compiles}"))

    # ---- large support: where sharding actually wins ---------------------
    # support 4096 x batch {1024, 4096}, shards {1, 4}, streamed through a
    # STARTED engine so consecutive slab drains overlap (pipeline_depth).
    # The router takes mp at 1024 rows and dp at 4096 rows; shards4_b4096
    # is the honest shards>1-beats-shards1 row (per-device kernel tiles
    # stay cache-resident under dp).
    n_big, n_reqs = 4096, 8
    big = _fit_dual(n_big, m)

    def _stream(eng, reqs, n_threads=2):
        """Submit ``reqs`` from ``n_threads`` threads, each waiting on its
        own result before resubmitting — so while one drain is on the
        device the other thread's rows are already queued, and the flusher
        dispatches the next drain without waiting (overlap depth 2)."""
        errs = []

        def submitter(tid):
            try:
                for i in range(tid, len(reqs), n_threads):
                    r = eng.submit(reqs[i]).result(timeout=300.0)
                    assert isinstance(r, np.ndarray)
            except Exception as e:
                errs.append(e)

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        if errs:
            raise errs[0]
        return wall

    for n_shards in (1, 4):
        shb, _ = oos.shard_fitted(big, n_shards)
        for b in (1024, 4096):
            qbig = _queries(b, m, seed=3)
            reqs = [qbig] * n_reqs
            n_rows = b * n_reqs
            eng = KpcaEngine(shb, KpcaServeConfig(
                max_batch=b, min_bucket=b, flush_max_wait_s=0.0))
            eng.warmup()                              # one bucket: b
            eng.stats = type(eng.stats)()
            with eng:
                wall = _stream(eng, reqs)
            st = eng.stats
            rows.append((
                f"serve/shards{n_shards}_N4096_b{b}", wall / n_rows * 1e6,
                f"qps={n_rows / wall:.0f};routing={st.routing_summary()};"
                f"depth={st.max_inflight_drains};support={n_big};"
                f"devices={min(n_shards, n_dev)};"
                f"compiles={st.n_compiles}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_serve_kpca():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
