"""FaultyComm: transport-level fault injection for the ADMM solver.

Wraps any object satisfying the ``Communicator`` protocol
(``core/solver.py``: ``local`` / ``exchange`` / ``all_sum`` / ``all_max``)
and censors undelivered messages by zeroing the received columns for
masked slots. Composes with both backends:

- ``DenseComm``: received block is ``(J, S, N)`` and the mask is
  ``(J, S)`` — receiver j, slot s.
- ``RingComm`` (inside ``shard_map``): received block is ``(S, N)`` per
  node and the mask is ``(S,)`` for THIS node's slots.

Zeroing alone is only half the semantics: the solver must also drop the
censored slots from the consensus weights so ``rho_bar`` renormalizes
over slots actually heard and the matching duals freeze (rho = 0 ⇒ the
dual update is a no-op). That half lives in ``admm_step(slot_mask=...)``;
this wrapper guarantees that whatever DID arrive on a dead link can never
leak into the update, even if a future refactor forgets a mask multiply.
Defense in depth — the chaos tests pin both layers.

The wrapper is reused across iterations via :meth:`with_mask`, which
returns a cheap re-bound view (no per-call tracer or metric objects —
the obs disabled-path test in ``tests/test_obs.py`` holds this to the
same zero-retention contract as the rest of the hot path). Fault
*accounting* (``faults_injected_total`` etc.) is host-side in the driver,
never inside traced code.
"""

from __future__ import annotations

from typing import Any, Callable, Optional


class FaultyComm:
    """A ``Communicator`` that censors exchanged columns by a slot mask."""

    __slots__ = ("base", "mask")

    def __init__(self, base: Any, mask: Optional[Any] = None):
        self.base = base
        self.mask = mask

    def with_mask(self, mask: Any) -> "FaultyComm":
        """Re-bind to this iteration's ``(J, S)`` / ``(S,)`` slot mask."""
        return FaultyComm(self.base, mask)

    # -- Communicator protocol --------------------------------------------

    def local(self, fn: Callable) -> Any:
        return self.base.local(fn)

    def exchange(self, cols: Any) -> Any:
        recv = self.base.exchange(cols)
        if self.mask is None:
            return recv
        return recv * self.mask[..., None]

    def all_sum(self, x: Any) -> Any:
        return self.base.all_sum(x)

    def all_max(self, x: Any) -> Any:
        return self.base.all_max(x)

    @property
    def ledger(self):
        return getattr(self.base, "ledger", None)


__all__ = ["FaultyComm"]
