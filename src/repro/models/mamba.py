"""Mamba state-space layers.

mamba1 (falcon-mamba): selective scan h_t = exp(dt A) h_{t-1} + dt B_t x_t,
y_t = C_t h_t + D x_t. TPU adaptation: time is processed in chunks —
``associative_scan`` *within* a chunk (parallel, materializes only
(B, chunk, d_inner, N) transients) and ``lax.scan`` carrying the (B, d_inner,
N) state *across* chunks. This bounds live memory to one chunk of states
while keeping the MXU/VPU busy, instead of a 4k-step sequential scan.

mamba2 (zamba2): SSD (state-space duality) chunked algorithm — intra-chunk
attention-like quadratic term via matmuls + inter-chunk low-rank state
passing; the standard TPU-friendly formulation (all MXU matmuls).

Both provide O(1)-state decode steps (conv ring buffer + ssm state), which is
what makes the 500k long-context decode shape run at constant memory.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ParamCollector


# ----------------------------------------------------------------------
# shared: causal depthwise conv (explicit shifts; decode keeps a ring buffer)
# ----------------------------------------------------------------------

def _causal_conv(x, w, bias=None):
    """x (B, L, C); w (K, C) depthwise taps (tap k multiplies x[t-K+1+k])."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i][None, None, :]
    if bias is not None:
        out = out + bias[None, None, :]
    return out


def _conv_step(state, x_t, w, bias=None):
    """state (B, K-1, C) past inputs; x_t (B, C). Returns (y_t, new_state)."""
    full = jnp.concatenate([state, x_t[:, None]], axis=1)       # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", full, w)
    if bias is not None:
        y = y + bias[None, :]
    return y, full[:, 1:]


# ----------------------------------------------------------------------
# mamba1
# ----------------------------------------------------------------------

class Mamba1State(NamedTuple):
    conv: jax.Array    # (B, K-1, d_inner)
    ssm: jax.Array     # (B, d_inner, N)


def init_mamba1(col: ParamCollector, cfg: ArchConfig, prefix: str = "mamba"):
    e, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    col.param(f"{prefix}/w_in", (e, 2 * di), ("embed", "inner"))
    col.param(f"{prefix}/conv_w", (cfg.d_conv, di), ("conv", "inner"),
              scale=0.5)
    col.param(f"{prefix}/conv_b", (di,), ("inner",), init="zeros")
    col.param(f"{prefix}/w_x", (di, dtr + 2 * n), ("inner", None))
    col.param(f"{prefix}/w_dt", (dtr, di), (None, "inner"))
    col.param(f"{prefix}/dt_bias", (di,), ("inner",), init="zeros")
    col.param(f"{prefix}/a_log", (di, n), ("inner", "state"), init="zeros")
    col.param(f"{prefix}/d", (di,), ("inner",), init="ones")
    col.param(f"{prefix}/w_out", (di, e), ("inner", "embed"))


def _mamba1_inputs(p, cfg, x):
    """Shared projections: returns (xz gate z, u (conv'd), dt, B, C)."""
    di, n, dtr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = jnp.einsum("ble,ei->bli", x, p["w_in"].astype(x.dtype))
    u, z = jnp.split(xz, 2, axis=-1)
    return u, z


def _mamba1_ssm_params(p, cfg, u):
    dtr, n = cfg.dt_rank, cfg.ssm_state
    proj = jnp.einsum("bli,ir->blr", u, p["w_x"].astype(u.dtype))
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("blr,ri->bli", dt_in, p["w_dt"].astype(u.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))        # (di, N), negative
    return dt, a, b_in.astype(jnp.float32), c_in.astype(jnp.float32)


def mamba1_forward(p, cfg: ArchConfig, x, return_state: bool = False):
    """x (B, L, E) -> (B, L, E). Chunked associative scan over time.
    With return_state: also returns Mamba1State for decode continuation
    (the parallel-prefill path)."""
    b, l, _ = x.shape
    di, n, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_chunk
    u, z = _mamba1_inputs(p, cfg, x)
    u_raw = u
    u = jax.nn.silu(_causal_conv(u, p["conv_w"].astype(u.dtype),
                                 p["conv_b"].astype(u.dtype)))
    dt, a, b_in, c_in = _mamba1_ssm_params(p, cfg, u)

    ck = min(ck, l)
    while l % ck:
        ck //= 2
    nchunks = l // ck
    uf = u.astype(jnp.float32)
    # decay factors and inputs: adt (B,L,di,N), bx (B,L,di,N)
    rs = lambda t: t.reshape(b, nchunks, ck, *t.shape[2:])
    dt_c, u_c, b_c, c_c = rs(dt), rs(uf), rs(b_in), rs(c_in)

    def chunk_step(h, inp):
        dt_k, u_k, b_k, c_k = inp                       # (B,ck,...)
        adt = jnp.exp(dt_k[..., None] * a[None, None])  # (B,ck,di,N)
        bx = (dt_k * u_k)[..., None] * b_k[:, :, None, :]

        def combine(l_, r_):
            al, bl = l_
            ar, br = r_
            return al * ar, bl * ar + br

        a_acc, h_in = jax.lax.associative_scan(combine, (adt, bx), axis=1)
        hs = h_in + a_acc * h[:, None]                  # add carried state
        y_k = jnp.einsum("bldn,bln->bld", hs, c_k)
        return hs[:, -1], y_k

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_fin, ys = jax.lax.scan(
        lambda h, i: chunk_step(h, jax.tree.map(lambda t: t[:, i], (dt_c, u_c, b_c, c_c))),
        h0, jnp.arange(nchunks), unroll=cfg.unroll_scans)
    y = ys.transpose(1, 0, 2, 3).reshape(b, l, di)
    y = y + uf * p["d"].astype(jnp.float32)[None, None]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bli,ie->ble", y, p["w_out"].astype(x.dtype))
    if return_state:
        km1 = cfg.d_conv - 1
        tail = u_raw[:, -km1:]                         # pre-conv inputs
        tail = jnp.pad(tail, ((0, 0), (max(km1 - l, 0), 0), (0, 0)))
        return out, Mamba1State(tail, h_fin)
    return out


def mamba1_decode(p, cfg: ArchConfig, x, state: Mamba1State):
    """Single-token step: x (B, 1, E) -> (y (B,1,E), new state)."""
    u, z = _mamba1_inputs(p, cfg, x)
    u1, conv_state = _conv_step(state.conv, u[:, 0],
                                p["conv_w"].astype(u.dtype),
                                p["conv_b"].astype(u.dtype))
    u1 = jax.nn.silu(u1)[:, None]                        # (B,1,di)
    dt, a, b_in, c_in = _mamba1_ssm_params(p, cfg, u1)
    adt = jnp.exp(dt[:, 0, :, None] * a[None])           # (B,di,N)
    bx = (dt[:, 0] * u1[:, 0].astype(jnp.float32))[..., None] \
        * b_in[:, 0, None, :]
    h = state.ssm * adt + bx
    y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])
    y = y + u1[:, 0].astype(jnp.float32) * p["d"].astype(jnp.float32)[None]
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    out = jnp.einsum("bli,ie->ble", y, p["w_out"].astype(x.dtype))
    return out, Mamba1State(conv_state, h)


def mamba1_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return Mamba1State(
        conv=jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        ssm=jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))


# ----------------------------------------------------------------------
# mamba2 (SSD) — zamba2 backbone
# ----------------------------------------------------------------------

class Mamba2State(NamedTuple):
    conv: jax.Array    # (B, K-1, d_inner + 2*N)
    ssm: jax.Array     # (B, H, hd, N)


def init_mamba2(col: ParamCollector, cfg: ArchConfig, prefix: str = "mamba"):
    e, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    conv_dim = di + 2 * n
    col.param(f"{prefix}/w_in", (e, 2 * di + 2 * n + nh), ("embed", "inner"))
    col.param(f"{prefix}/conv_w", (cfg.d_conv, conv_dim), ("conv", None),
              scale=0.5)
    col.param(f"{prefix}/conv_b", (conv_dim,), (None,), init="zeros")
    col.param(f"{prefix}/dt_bias", (nh,), (None,), init="zeros")
    col.param(f"{prefix}/a_log", (nh,), (None,), init="zeros")
    col.param(f"{prefix}/d", (nh,), (None,), init="ones")
    col.param(f"{prefix}/norm_w", (di,), ("inner",), init="ones")
    col.param(f"{prefix}/w_out", (di, e), ("inner", "embed"))


def _mamba2_split(p, cfg, x):
    di, n = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    zxbcdt = jnp.einsum("ble,ei->bli", x, p["w_in"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt  # xbc goes through conv; dt (B,L,nh)


def _ssd_chunked(xh, b_in, c_in, dt, a, chunk: int, h0=None, unroll=1):
    """SSD scan. xh (B,L,H,hd); b_in/c_in (B,L,N); dt (B,L,H) (softplus'd);
    a (H,) negative. Returns (y (B,L,H,hd), final state (B,H,hd,N))."""
    b, l, h, hd = xh.shape
    n = b_in.shape[-1]
    ck = min(chunk, l)
    while l % ck:
        ck //= 2
    nc = l // ck
    rs = lambda t: t.reshape(b, nc, ck, *t.shape[2:])
    xc, bc, cc, dtc = rs(xh.astype(jnp.float32)), rs(b_in), rs(c_in), rs(dt)

    def chunk_fn(state, i):
        x_k = xc[:, i]                                   # (B,ck,H,hd)
        b_k, c_k = bc[:, i], cc[:, i]                    # (B,ck,N)
        dt_k = dtc[:, i]                                 # (B,ck,H)
        da = dt_k * a[None, None]                        # (B,ck,H) log-decay
        cum = jnp.cumsum(da, axis=1)                     # (B,ck,H)
        # intra-chunk (attention-like) term
        seg = cum[:, :, None, :] - cum[:, None, :, :]    # (B,ck,ck,H) l-m
        causal = jnp.tril(jnp.ones((ck, ck), bool))
        decay = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bln,bmn->blm", c_k, b_k)        # (B,ck,ck)
        w = cb[..., None] * decay * dt_k[:, None, :, :]  # (B,l,m,H)
        y = jnp.einsum("blmh,bmhd->blhd", w, x_k)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("bln,blh,bhdn->blhd", c_k, jnp.exp(cum), state)
        # next state: decay whole chunk + accumulate inputs
        rev = cum[:, -1:, :] - cum                       # decay to chunk end
        contrib = jnp.einsum("bln,blh,blhd->bhdn",
                             b_k, jnp.exp(rev) * dt_k, x_k)
        state = state * jnp.exp(cum[:, -1])[..., None, None] + contrib
        return state, y

    state0 = h0 if h0 is not None else jnp.zeros((b, h, hd, n), jnp.float32)
    state, ys = jax.lax.scan(chunk_fn, state0, jnp.arange(nc), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, l, h, hd)
    return y, state


def mamba2_forward(p, cfg: ArchConfig, x, return_state: bool = False):
    b, l, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    z, xbc, dt = _mamba2_split(p, cfg, x)
    xbc_raw = xbc
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                   p["conv_b"].astype(x.dtype)))
    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, l, nh, hd)
    y, _ssm_state = _ssd_chunked(xh, b_in.astype(jnp.float32),
                                 c_in.astype(jnp.float32), dt, a,
                                 cfg.ssm_chunk, unroll=cfg.unroll_scans)
    y = y + xh.astype(jnp.float32) * p["d"].astype(jnp.float32)[None, None, :,
                                                                None]
    y = y.reshape(b, l, di).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    from .common import rms_norm
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bli,ie->ble", y, p["w_out"].astype(x.dtype))
    if return_state:
        km1 = cfg.d_conv - 1
        tail = xbc_raw[:, -km1:]
        tail = jnp.pad(tail, ((0, 0), (max(km1 - l, 0), 0), (0, 0)))
        return out, Mamba2State(tail, _ssm_state)
    return out


def mamba2_decode(p, cfg: ArchConfig, x, state: Mamba2State):
    b = x.shape[0]
    di, n = cfg.d_inner, cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = di // hd
    z, xbc, dt = _mamba2_split(p, cfg, x)
    xbc1, conv_state = _conv_step(state.conv, xbc[:, 0],
                                  p["conv_w"].astype(x.dtype),
                                  p["conv_b"].astype(x.dtype))
    xbc1 = jax.nn.silu(xbc1)
    xs, b_in, c_in = jnp.split(xbc1, [di, di + n], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(b, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt1 * a[None])                               # (B,nh)
    h = state.ssm * decay[..., None, None] + jnp.einsum(
        "bhd,bn,bh->bhdn", xh, b_in.astype(jnp.float32), dt1)
    y = jnp.einsum("bhdn,bn->bhd", h, c_in.astype(jnp.float32))
    y = y + xh * p["d"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(x.dtype) * jax.nn.silu(z[:, 0])
    from .common import rms_norm
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)[:, None]
    out = jnp.einsum("bli,ie->ble", y, p["w_out"].astype(x.dtype))
    return out, Mamba2State(conv_state, h)


def mamba2_init_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    nh = cfg.d_inner // cfg.ssm_head_dim
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32))
