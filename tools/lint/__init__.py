"""repro-lint: concurrency- and JAX-aware static analysis for this repo.

``python -m tools.lint src tests`` walks the given files/directories and
runs every registered rule over each Python file's AST. The rule catalog,
the pragma syntax (``# repro-lint: disable=RULE``), and the source
annotations the concurrency rules consume (``# guarded-by: <lock>``,
``# holds-lock: <lock>``) are documented in docs/STATIC_ANALYSIS.md.

Stdlib-only by design: the analyzer never imports jax (or anything from
src/), so the CI job runs on a bare Python with no wheel cache.
"""

from .engine import (FileContext, Finding, Rule, all_rules, lint_file,
                     lint_source, register)

__all__ = ["FileContext", "Finding", "Rule", "all_rules", "lint_file",
           "lint_source", "register"]
