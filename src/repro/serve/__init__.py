from .engine import DecodeEngine, ServeConfig
from .kpca_engine import (EngineStats, KpcaEngine, KpcaServeConfig,
                          RequestStats)
from .publisher import ModelHandle, stream_chunks
from .sharded import project_sharded

__all__ = ["DecodeEngine", "EngineStats", "KpcaEngine", "KpcaServeConfig",
           "ModelHandle", "RequestStats", "ServeConfig", "project_sharded",
           "stream_chunks"]
