"""Tests for the streaming-serving seam: ``oos.refresh_coefficients``
(cached kernel-mean statistics), the versioned ``ModelHandle``, the
engine's read-through/version-isolation semantics, and the end-to-end
train -> refresh -> publish -> serve loop over the chunked driver."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, build_setup, oos, solver
from repro.core.topology import ring
from repro.data import node_dataset
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle, \
    stream_chunks

SPEC = KernelSpec(kind="rbf", gamma=0.25)


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def fitted():
    x = jnp.asarray(_rand((48, 10), seed=0))
    return x, oos.fit_central(x, SPEC, n_components=2, center=True)


class TestRefreshCoefficients:
    def test_matches_full_refit(self, fitted):
        """Refreshing with new alpha == rebuilding from scratch with
        from_dual (which re-forms the Gram), to fp32 resolution."""
        x, model = fitted
        alpha2 = jnp.asarray(_rand((48, 2), seed=1))
        got = oos.refresh_coefficients(model, alpha2)
        want = oos.from_dual(x, alpha2, SPEC, gamma=model.gamma, center=True)
        xq = jnp.asarray(_rand((9, 10), seed=2))
        np.testing.assert_allclose(np.asarray(oos.project(got, xq)),
                                   np.asarray(oos.project(want, xq)),
                                   rtol=1e-5, atol=1e-5)

    def test_node_major_alpha_pools_like_from_decentralized(self):
        nodes = jnp.asarray(_rand((6, 8, 10), seed=3))
        a1 = jnp.asarray(_rand((6, 8), seed=4))
        model = oos.from_decentralized(nodes, a1, SPEC, gamma=0.3,
                                       center=True)
        a2 = jnp.asarray(_rand((6, 8), seed=5))
        got = oos.refresh_coefficients(model, a2)
        want = oos.from_decentralized(nodes, a2, SPEC, gamma=0.3,
                                      center=True)
        xq = jnp.asarray(_rand((7, 10), seed=6))
        np.testing.assert_allclose(np.asarray(oos.project(got, xq)),
                                   np.asarray(oos.project(want, xq)),
                                   rtol=1e-5, atol=1e-5)

    def test_uncentered_model_refreshes_to_zero_centering(self):
        x = jnp.asarray(_rand((20, 6), seed=7))
        model = oos.fit_central(x, SPEC, 1, center=False)
        new = oos.refresh_coefficients(model, jnp.asarray(_rand((20,), 8)))
        assert not np.any(np.asarray(new.row_mean_coef))
        assert not np.any(np.asarray(new.bias))

    def test_rejects_mismatched_support(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            oos.refresh_coefficients(model, jnp.ones((7, 2)))

    def test_rejects_centered_model_without_cache(self, fitted):
        _, model = fitted
        stripped = dataclasses.replace(model, k_row_mean=None,
                                       k_grand_mean=None)
        with pytest.raises(ValueError):
            oos.refresh_coefficients(stripped, model.coefs)

    def test_cache_survives_save_load(self, fitted, tmp_path):
        x, model = fitted
        oos.save_fitted(str(tmp_path / "ck"), model)
        back = oos.load_fitted(str(tmp_path / "ck"))
        assert back.k_row_mean is not None
        alpha2 = jnp.asarray(_rand((48, 2), seed=9))
        np.testing.assert_allclose(
            np.asarray(oos.refresh_coefficients(back, alpha2).bias),
            np.asarray(oos.refresh_coefficients(model, alpha2).bias),
            rtol=1e-6, atol=1e-6)


class TestModelHandle:
    def test_publish_bumps_version_atomically(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        assert h.version == 0
        m2 = oos.refresh_coefficients(model, model.coefs * 2.0)
        assert h.publish(m2) == 1
        got, v = h.get()
        assert v == 1 and got is m2

    def test_rejects_kind_change(self, fitted):
        _, model = fitted
        sharded, _ = oos.shard_fitted(model, 2)
        h = ModelHandle(model)
        with pytest.raises(TypeError):
            h.publish(sharded)

    def test_sharded_handle_pins_shard_count(self, fitted):
        """The engine's mesh is compiled against the initial shard count,
        so a re-sharded publish must be rejected up front."""
        _, model = fitted
        two, _ = oos.shard_fitted(model, 2)
        four, _ = oos.shard_fitted(model, 4)
        h = ModelHandle(two)
        with pytest.raises(ValueError):
            h.publish(four)
        two_b, _ = oos.shard_fitted(
            oos.refresh_coefficients(model, model.coefs * 2.0), 2)
        assert h.publish(two_b) == 1       # same layout: fine

    def test_refresh_rejects_sharded_models(self, fitted):
        _, model = fitted
        sharded, _ = oos.shard_fitted(model, 2)
        h = ModelHandle(sharded)
        with pytest.raises(TypeError):
            h.refresh(model.coefs)

    def test_refresh_publishes_new_coefficients(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        alpha2 = jnp.asarray(_rand((48, 2), seed=10))
        assert h.refresh(alpha2) == 1
        np.testing.assert_allclose(np.asarray(h.current().coefs),
                                   np.asarray(alpha2), rtol=1e-6, atol=1e-6)


class TestEngineVersionIsolation:
    def test_inflight_flush_finishes_on_old_version(self, fitted):
        """A publish landing MID-FLUSH (between slabs) must not leak into
        that flush: all its slabs score on the snapshot taken at flush
        start; the next flush sees the new version."""
        _, model = fitted
        h = ModelHandle(model)
        eng = KpcaEngine(h, KpcaServeConfig(max_batch=8, min_bucket=8))
        m2 = oos.refresh_coefficients(model, model.coefs * 2.0)

        x = _rand((20, 10), seed=11)           # 3 slabs at max_batch=8
        rid = eng.submit(x)
        run_slab = eng._run_slab
        fired = dict(n=0)

        def publish_after_first_slab(mdl, slab):
            out = run_slab(mdl, slab)
            if fired["n"] == 0:
                h.publish(m2)                  # lands between slab 0 and 1
            fired["n"] += 1
            return out

        eng._run_slab = publish_after_first_slab
        out = eng.flush()
        eng._run_slab = run_slab
        assert fired["n"] == 3
        np.testing.assert_allclose(
            out[rid], np.asarray(oos.project(model, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.stats.per_request[-1].model_version == 0

        rid2 = eng.submit(x)                   # next batch: new version
        out2 = eng.flush()
        np.testing.assert_allclose(
            out2[rid2], np.asarray(oos.project(m2, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.stats.per_request[-1].model_version == 1

    def test_plain_model_still_works(self, fitted):
        _, model = fitted
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=8, min_bucket=8))
        x = _rand((5, 10), seed=12)
        out = eng.project_many([x])
        np.testing.assert_allclose(
            out[0], np.asarray(oos.project(model, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.model is model


class TestStreamingEndToEnd:
    def test_driver_publishes_and_engine_serves_live(self):
        """The acceptance loop: chunked ADMM driver -> refresh_coefficients
        -> ModelHandle.publish -> KpcaEngine, with the engine serving
        between chunks and the final served scores matching an offline fit
        of the final alpha."""
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=12, m=8, seed=0)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)

        # seed model from the warm-start alpha (iteration 0)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        handle = ModelHandle(oos.from_decentralized(
            nodes, a0, spec, gamma=setup.gamma, center=True))
        eng = KpcaEngine(handle, KpcaServeConfig(max_batch=8, min_bucket=8))
        xq = _rand((5, 8), seed=13)

        versions = []
        driver = solver.run_chunked(setup, n_iters=12, chunk=3, alpha0=a0)
        for chunk in driver:
            handle.refresh(chunk.state.alpha)
            eng.submit(xq)
            eng.flush()
            versions.append(eng.stats.per_request[-1].model_version)
        assert versions == [1, 2, 3, 4]        # one publish per chunk

        final_alpha = chunk.state.alpha
        want = oos.project(
            oos.from_decentralized(nodes, final_alpha, spec,
                                   gamma=setup.gamma, center=True),
            jnp.asarray(xq))
        got = eng.project_many([xq])[0]
        np.testing.assert_allclose(got, np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_stream_chunks_validates_every(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            stream_chunks(iter([]), ModelHandle(model), every=0)

    def test_stream_chunks_glue(self):
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=10, m=8, seed=1)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        handle = ModelHandle(oos.from_decentralized(
            nodes, a0, spec, gamma=setup.gamma, center=True))
        last = stream_chunks(
            solver.run_chunked(setup, n_iters=10, chunk=4, alpha0=a0),
            handle, every=2)
        # 3 chunks (4+4+2): publishes after chunk 2 and at the tail chunk
        assert handle.version == 2
        np.testing.assert_allclose(
            np.asarray(handle.current().coefs).reshape(6, 10) * 6,
            np.asarray(last.state.alpha), rtol=1e-6, atol=1e-6)
