"""Exactness test: the dual-space (kernel-trick) ADMM iteration must match a
naive PRIMAL implementation that materializes w_j, z_m, eta explicitly.

With a linear kernel, phi(x) = x, so the paper's updates can be evaluated
directly in R^M — an independent oracle for the slot/gather/scaling algebra
of ``repro.core.admm.admm_iteration`` (this catches message-routing bugs the
convergence tests cannot)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, build_setup
from repro.core.admm import admm_iteration
from repro.core.topology import random_connected, ring


def primal_reference(X, graph, rho1, rho2, include_self, alpha0, n_steps):
    """Naive primal implementation of the generalized Alg. 1."""
    J, N, M = X.shape
    ids, rev, nmask = graph.neighbor_array()
    S = ids.shape[1] + 1
    src = np.concatenate([np.arange(J)[:, None], ids], 1)
    rsl = np.concatenate([np.zeros((J, 1), int), rev + 1], 1)
    mask = np.concatenate([np.full((J, 1), include_self), nmask], 1)
    K = np.einsum("jnm,jkm->jnk", X, X)
    Kinv = np.stack([np.linalg.inv(K[j]) for j in range(J)])
    P = np.stack([X[j].T @ Kinv[j] @ X[j] for j in range(J)])
    rho_s = np.where(mask, np.where(np.arange(S)[None, :] == 0, rho1, rho2),
                     0.0)
    rho_bar = rho_s.sum(1)

    alpha = alpha0.copy()
    eta = np.zeros((J, M, S))
    for _ in range(n_steps):
        zhat = np.zeros((J, M))
        for m in range(J):
            acc = np.zeros(M)
            for i in range(S):
                if not mask[m, i]:
                    continue
                jsrc, slot = src[m, i], rsl[m, i]
                acc += P[jsrc] @ eta[jsrc, :, slot] \
                    + rho_s[m, i] * (X[jsrc].T @ alpha[jsrc])
            zhat[m] = acc / rho_bar[m]
        nz = np.linalg.norm(zhat, axis=1)
        z = np.where((nz > 1)[:, None],
                     zhat / np.maximum(nz, 1e-30)[:, None], zhat)
        G = np.zeros((J, N, S))
        for j in range(J):
            for s in range(S):
                if mask[j, s]:
                    G[j, :, s] = X[j] @ z[src[j, s]]
        alpha_n = np.zeros_like(alpha)
        for j in range(J):
            amat = rho_bar[j] * K[j] - 2 * K[j] @ K[j]
            rhs = ((rho_s[j][None, :] * G[j] - (X[j] @ eta[j]))
                   * mask[j][None, :]).sum(1)
            alpha_n[j] = np.linalg.solve(amat, rhs)
        for j in range(J):
            for s in range(S):
                if mask[j, s]:
                    eta[j, :, s] += rho_s[j, s] * (
                        X[j].T @ alpha_n[j] - P[j] @ z[src[j, s]])
        alpha = alpha_n
    B = np.einsum("jnm,jms->jns", X, eta) * mask[:, None, :]
    return alpha, B


@pytest.mark.parametrize("include_self", [True, False])
@pytest.mark.parametrize("graph_kind", ["ring", "random"])
def test_dual_matches_primal(include_self, graph_kind):
    np.random.seed(0)
    J, N, M = 5, 6, 12
    X = np.random.randn(J, N, M).astype(np.float32)
    graph = ring(J, 2) if graph_kind == "ring" else \
        random_connected(J, 0.4, seed=1)
    rho1, rho2 = 60.0, 50.0  # Assumption-2-valid for this scale
    alpha0 = np.random.default_rng(1).normal(size=(J, N)).astype(np.float32)

    spec = KernelSpec(kind="linear", normalize=False)
    setup = build_setup(jnp.asarray(X), graph, spec, center="none",
                        include_self=include_self)
    a_d = jnp.asarray(alpha0)
    b_d = jnp.zeros((J, N, setup.n_slots), jnp.float32)
    n_steps = 4
    for _ in range(n_steps):
        a_d, b_d, _, _ = admm_iteration(
            setup, a_d, b_d,
            rho1 if include_self else 0.0, rho2)
    a_p, b_p = primal_reference(X.astype(np.float64), graph,
                                rho1 if include_self else 0.0, rho2,
                                include_self, alpha0.astype(np.float64),
                                n_steps)
    np.testing.assert_allclose(np.asarray(a_d), a_p, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(b_d), b_p, rtol=2e-3, atol=2e-3)
