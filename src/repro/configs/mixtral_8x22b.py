"""mixtral-8x22b [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA [arXiv:2401.04088; hf]."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, head_dim=128,
        attn_kind="swa", window=4096,
        n_experts=8, top_k=2, d_ff_expert=16384, rope_theta=1000000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        attn_kind="swa", window=16,
        n_experts=4, top_k=2, d_ff_expert=128, rope_theta=1000000.0,
        remat="none")
