"""Tests for the async request pipeline: futures-based ``KpcaEngine``
(background flusher, size-or-deadline triggers), admission control, and
version consistency of concurrent requests against per-shard publishes.

Every test that starts a thread joins it on teardown (the engine fixture
closes the flusher; publishers are context-managed), so a deadlock shows
up as a pytest-timeout failure, not a hung CI job.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, oos
from repro.serve import (KpcaEngine, KpcaServeConfig, ModelHandle,
                         QueueFullError, ShedError)
from repro.serve.sharded import project_sharded

SPEC = KernelSpec(kind="rbf", gamma=0.25)
WAIT = 30.0                                    # generous future timeout

# Instrument every serve-layer lock and fail on a recorded AB/BA
# acquisition cycle (tests/helpers/lockcheck.py).
pytestmark = pytest.mark.lockcheck


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def model():
    x = jnp.asarray(_rand((48, 12), seed=0))
    return oos.fit_central(x, SPEC, n_components=2, center=True)


@pytest.fixture
def engine(model, request):
    """Engine factory that guarantees flusher-thread teardown."""
    engines = []

    def make(cfg):
        eng = KpcaEngine(model, cfg)
        engines.append(eng)
        return eng

    yield make
    for eng in engines:
        eng.close(drain=False)


class TestAsyncExactness:
    def test_concurrent_futures_bitwise_vs_sync(self, model, engine):
        """Aligned requests (one full slab each) from concurrent submitter
        threads must resolve to BITWISE-identical scores vs serving each
        request alone through the synchronous path: same bucket shape =>
        same compiled program => same floats, regardless of how the
        flusher interleaved the batches."""
        cfg = KpcaServeConfig(max_batch=16, min_bucket=16,
                              flush_max_wait_s=0.002)
        eng = engine(cfg).start()
        sync_eng = KpcaEngine(model, cfg)      # never started: sync path
        reqs = [_rand((16, 12), seed=100 + i) for i in range(12)]

        futs = [None] * len(reqs)

        def submitter(lo, hi):
            for i in range(lo, hi):
                futs[i] = eng.submit(reqs[i])

        threads = [threading.Thread(target=submitter, args=(i * 4, i * 4 + 4))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=WAIT)
        got = [f.result(timeout=WAIT) for f in futs]
        for r, g in zip(reqs, got):
            want = sync_eng.project_many([r])[0]
            np.testing.assert_array_equal(g, want)   # bitwise

    def test_mixed_sizes_concurrent_vs_oracle(self, model, engine):
        """Arbitrary request sizes across concurrent submitters: packing
        may split requests across slab boundaries, so pin to float32
        resolution against the unbatched oracle (same bar as the sync
        engine's own exactness test)."""
        eng = engine(KpcaServeConfig(max_batch=16, min_bucket=4,
                                     flush_max_wait_s=0.002)).start()
        sizes = [1, 3, 5, 17, 31, 33, 2, 8]
        reqs = [_rand((q, 12), seed=200 + q) for q in sizes]
        futs = [None] * len(reqs)

        def submitter(idx):
            futs[idx] = eng.submit(reqs[idx])

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(len(reqs))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=WAIT)
        for r, f in zip(reqs, futs):
            want = np.asarray(oos.project(model, jnp.asarray(r)))
            np.testing.assert_allclose(f.result(timeout=WAIT), want,
                                       rtol=1e-6, atol=1e-7)

    def test_deadline_trigger_resolves_small_batch(self, engine):
        """A lone sub-batch request must not wait for a full slab: the
        deadline trigger flushes it within flush_max_wait_s."""
        eng = engine(KpcaServeConfig(max_batch=128, min_bucket=8,
                                     flush_max_wait_s=0.01)).start()
        fut = eng.submit(_rand((3, 12), seed=1))
        assert fut.result(timeout=WAIT).shape == (3, 2)
        assert eng.stats.per_request[-1].queue_wait_s < WAIT

    def test_size_trigger_beats_deadline(self, engine):
        """A full max_batch of queued rows flushes immediately even under
        an absurdly long deadline."""
        eng = engine(KpcaServeConfig(max_batch=8, min_bucket=8,
                                     flush_max_wait_s=60.0)).start()
        futs = [eng.submit(_rand((4, 12), seed=2 + i)) for i in range(2)]
        for f in futs:
            assert f.result(timeout=WAIT).shape == (4, 2)


class TestLifecycle:
    def test_context_manager_drains_on_exit(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=64, min_bucket=8, flush_max_wait_s=30.0))
        with eng:
            assert eng.running
            fut = eng.submit(_rand((5, 12), seed=3))
        assert not eng.running                 # thread joined
        assert fut.result(timeout=0).shape == (5, 2)

    def test_close_without_drain_cancels(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=64, min_bucket=8, flush_max_wait_s=30.0))
        fut = eng.submit(_rand((5, 12), seed=4))
        eng.close(drain=False)
        assert fut.cancelled()

    def test_start_is_idempotent_and_restartable(self, model, engine):
        eng = engine(KpcaServeConfig(max_batch=8, min_bucket=8,
                                     flush_max_wait_s=0.005))
        assert eng.start() is eng.start()
        eng.close()
        assert not eng.running
        eng.start()                            # fresh thread after close
        fut = eng.submit(_rand((2, 12), seed=5))
        assert fut.result(timeout=WAIT).shape == (2, 2)

    def test_failed_async_batch_fails_only_its_futures(self, model, engine):
        """A flusher-side failure must fail exactly that batch's futures
        (no silent retry loop) and keep the engine serving."""
        eng = engine(KpcaServeConfig(max_batch=8, min_bucket=8,
                                     flush_max_wait_s=0.005))
        run_slab = eng._run_slab
        boom = dict(armed=True)

        def maybe_boom(mdl, version, slab):
            if boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("injected")
            return run_slab(mdl, version, slab)

        eng._run_slab = maybe_boom
        eng.start()
        bad = eng.submit(_rand((3, 12), seed=6))
        with pytest.raises(RuntimeError):
            bad.result(timeout=WAIT)
        good = eng.submit(_rand((3, 12), seed=7))
        assert good.result(timeout=WAIT).shape == (3, 2)


class TestAdmissionControl:
    def test_reject_policy_and_counter(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=8, min_bucket=8, queue_factor=2))  # 16-row bound
        eng.submit(_rand((10, 12), seed=8))
        eng.submit(_rand((6, 12), seed=9))     # exactly at capacity
        with pytest.raises(QueueFullError):
            eng.submit(_rand((1, 12), seed=10))
        assert eng.stats.n_rejected == 1
        out = eng.flush()                      # draining frees capacity
        assert len(out) == 2
        eng.submit(_rand((1, 12), seed=10))    # admitted now
        eng.flush()

    def test_shed_policy_fails_oldest_future(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=8, min_bucket=8, queue_factor=1, admission="shed"))
        old = eng.submit(_rand((6, 12), seed=11))
        new = eng.submit(_rand((5, 12), seed=12))   # sheds `old`
        with pytest.raises(ShedError):
            old.result(timeout=0)
        assert eng.stats.n_shed == 1
        eng.flush()
        assert new.result(timeout=0).shape == (5, 2)

    def test_oversize_request_rejected_up_front(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=8, min_bucket=8, queue_factor=1, admission="shed"))
        keep = eng.submit(_rand((2, 12), seed=13))
        with pytest.raises(QueueFullError):    # 9 rows > 8-row capacity
            eng.submit(_rand((9, 12), seed=14))
        assert eng.stats.n_rejected == 1 and eng.stats.n_shed == 0
        eng.flush()
        assert keep.result(timeout=0).shape == (2, 2)

    def test_queue_factor_validation(self, model):
        with pytest.raises(ValueError):
            KpcaEngine(model, KpcaServeConfig(max_batch=8, queue_factor=0))


class TestVersionConsistencyUnderRefresh:
    def test_per_shard_publishes_never_mix_within_a_request(self, model):
        """Requests racing a stream of per-shard coefficient publishes must
        each observe EXACTLY one published model version — the scores must
        bitwise-match a direct projection through the version recorded in
        that request's stats, for every request."""
        sharded, _ = oos.shard_fitted(model, 3)
        handle = ModelHandle(sharded)
        cfg = KpcaServeConfig(max_batch=16, min_bucket=16,
                              flush_max_wait_s=0.002)
        eng = KpcaEngine(handle, cfg)
        versions = [sharded]                   # version v -> model
        xq = _rand((16, 12), seed=15)

        futs = []
        try:
            eng.start()
            rng = np.random.default_rng(16)
            for i in range(10):
                futs.append(eng.submit(xq))
                shard = i % sharded.n_shards
                a = rng.normal(size=(sharded.shard_sizes[shard], 2)) \
                    .astype(np.float32)
                handle.refresh_shard(shard, jnp.asarray(a))
                versions.append(handle.current())
            results = [f.result(timeout=WAIT) for f in futs]
        finally:
            eng.close(drain=False)

        by_rid = {s.request_id: s for s in eng.stats.per_request}
        assert len(by_rid) == len(futs)
        # Same program the router's auto policy compiles for this model
        # (support 48 -> "single"), minus donation — the bitwise oracle.
        ref = jax.jit(lambda m, q: project_sharded(m, q, policy="single"))
        seen = set()
        for f, got in zip(futs, results):
            v = by_rid[f.request_id].model_version
            seen.add(v)
            want = np.asarray(ref(versions[v], jnp.asarray(xq)))
            np.testing.assert_array_equal(got, want)
        assert seen                            # every request attributed