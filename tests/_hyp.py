"""Optional-``hypothesis`` shim for the test suite.

The property-based tests use hypothesis when it is installed (see
requirements-dev.txt); without it, a small DETERMINISTIC fallback engine
runs instead — each ``@given`` test executes ``max_examples`` seeded
random examples (seed derived from the test's qualified name, so runs
are reproducible and order-independent) rather than being skipped.
The fallback implements just the strategy surface this suite uses
(``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists``,
``tuples``, ``just``); anything fancier belongs behind a real
hypothesis install. Import from here instead of hypothesis:

    from _hyp import given, settings, st
"""

import functools
import random
import zlib

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _St:
        """The ``strategies`` surface the suite uses, seeded-RNG backed."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def just(value):
            return _Strategy(lambda rng: value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strategies))

    st = _St()

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args):
                # max_examples: @settings may sit above (attribute lands on
                # this wrapper) or below @given (attribute lands on fn).
                n = getattr(wrapper, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for i in range(n):
                    kw = {k: s.example(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kw)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (run {i} of "
                            f"{fn.__qualname__}): {kw!r}") from e
            # pytest resolves fixture names through __wrapped__'s signature;
            # the strategy kwargs must NOT look like fixtures.
            del wrapper.__wrapped__
            return wrapper
        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kwargs):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
