"""deepseek-v2-236b [moe] 60L d_model=5120 128H d_ff=1536 vocab=102400,
MoE 160e top-6 — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe", n_layers=60, d_model=5120,
        n_heads=128, n_kv_heads=128, d_ff=12288, vocab=102400,
        head_dim=192,                      # qk_nope 128 + qk_rope 64
        attn_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
        first_k_dense=1, d_ff_dense=12288, rope_theta=10000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=512, head_dim=24,
        attn_kind="mla", q_lora_rank=32, kv_lora_rank=32,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        n_experts=4, top_k=2, n_shared_experts=1, d_ff_expert=32,
        first_k_dense=1, d_ff_dense=128, rope_theta=10000.0, remat="none")
