"""Batched kPCA projection-serving engine (fit once, serve many).

The serving workload is the mirror image of ``DecodeEngine``: stateless
per-query math instead of a KV cache, so the engine's whole job is shaping
traffic for the compiled step. Variable-size requests are packed head-to-
tail into fixed-width slabs and padded up to POWER-OF-TWO shape buckets, so
a bounded set of compiled programs (log2(max_batch) of them) serves any
request mix with zero recompiles in steady state — the classic bucketing
trick from LM serving applied to kernel projection.

Guarantees and knobs:
  * results are exactly what ``repro.core.oos.project`` returns for each
    request alone — padding rows are sliced off and row-wise kernel math
    makes valid rows independent of them (asserted to float32 resolution in
    tests/test_kpca_engine.py; the only packing residue is XLA choosing a
    different gemm code path per slab shape, <= 4e-9 observed);
  * ``use_pallas`` routes through the fused Pallas projection kernel;
  * ``query_dtype=jnp.bfloat16`` halves query-slab HBM traffic (accumulation
    stays fp32 inside the kernel) for throughput-bound fleets;
  * per-request latency and queries/s accounting built in (served straight
    into benchmarks/bench_serve_kpca.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import oos
from ..core.oos import FittedKpca, ShardedFittedKpca
from .publisher import ModelHandle


@dataclasses.dataclass
class KpcaServeConfig:
    max_batch: int = 128          # widest bucket = compiled slab width
    min_bucket: int = 8           # narrowest bucket (absorbs tiny tails)
    use_pallas: bool = False      # fused Pallas kernel (interpret off-TPU)
    query_dtype: Any = None       # e.g. jnp.bfloat16 for cheaper slabs
    interpret: Optional[bool] = None  # forwarded to the Pallas wrapper

    def buckets(self) -> List[int]:
        """Power-of-two widths: min_bucket, 2*min_bucket, ..., max_batch."""
        if not 0 < self.min_bucket <= self.max_batch:
            raise ValueError(
                f"need 0 < min_bucket <= max_batch, got "
                f"min_bucket={self.min_bucket} max_batch={self.max_batch}")
        out, b = [], self.min_bucket
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return out


@dataclasses.dataclass
class RequestStats:
    request_id: int
    n_queries: int
    latency_s: float              # wall time inside the engine for this req
    model_version: int = 0        # handle version this request was served at


@dataclasses.dataclass
class EngineStats:
    n_requests: int = 0
    n_queries: int = 0
    n_padded: int = 0             # wasted pad rows actually computed
    n_compiles: int = 0           # distinct (bucket) programs built
    total_time_s: float = 0.0
    per_request: List[RequestStats] = dataclasses.field(default_factory=list)

    @property
    def queries_per_s(self) -> float:
        return self.n_queries / self.total_time_s if self.total_time_s else 0.0

    def latency_percentiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Per-request latency percentiles in seconds, one per entry of
        ``qs`` (default p50/p99); (0.0, ...) before any request is served."""
        lat = [r.latency_s for r in self.per_request] or [0.0]
        return tuple(float(np.percentile(lat, q)) for q in qs)


class KpcaEngine:
    """Micro-batching projection server over a fitted kPCA artifact.

    Accepts either a single-device ``FittedKpca`` (scored via
    ``repro.core.oos.project``) or a multi-device ``ShardedFittedKpca``
    (scored via ``repro.serve.sharded.project_sharded``: per-shard partials
    under shard_map, psum, global centering applied once post-reduction).
    The batching/bucketing layer is identical for both — slabs are
    replicated to every shard, so the engine's traffic shaping composes
    with device sharding unchanged.

    Live updates: the engine reads its model THROUGH a versioned
    ``repro.serve.publisher.ModelHandle`` (a bare model is wrapped in a
    private one). Each flush snapshots (model, version) once, so every
    slab of that flush — and therefore every in-flight request — is scored
    against one consistent version even if a publish lands mid-flush; the
    next flush picks up the new version. ``RequestStats.model_version``
    records which version served each request.
    """

    def __init__(self,
                 model: Union[FittedKpca, ShardedFittedKpca, ModelHandle],
                 cfg: KpcaServeConfig = None, mesh=None):
        """Args:
          model: servable artifact (plain or sharded) or a ``ModelHandle``
            wrapping one (live-publishable).
          cfg: batching/bucketing/backend knobs (``KpcaServeConfig``).
          mesh: for sharded models only — 1-D device mesh with
            ``model.n_shards`` devices; None builds one over local devices
            (or falls back to a same-math single-device reduction).
        """
        self.handle = model if isinstance(model, ModelHandle) \
            else ModelHandle(model)
        model = self.handle.current()
        self.cfg = cfg or KpcaServeConfig()
        self._buckets = self.cfg.buckets()
        self._compiled_shapes = set()
        self._queue: List[Tuple[int, np.ndarray]] = []
        self._next_id = 0
        self.stats = EngineStats()

        if isinstance(model, ShardedFittedKpca):
            from .sharded import project_sharded
            from ..launch.mesh import make_serving_mesh
            if mesh is None:
                mesh = make_serving_mesh(model.n_shards)

            def _proj(m, xq):
                return project_sharded(m, xq, mesh=mesh,
                                       use_pallas=self.cfg.use_pallas,
                                       interpret=self.cfg.interpret)
        else:
            if mesh is not None:
                raise ValueError("mesh is only meaningful for a "
                                 "ShardedFittedKpca model")

            def _proj(m, xq):
                return oos.project(m, xq, use_pallas=self.cfg.use_pallas,
                                   interpret=self.cfg.interpret)

        self._proj = jax.jit(_proj)

    @property
    def model(self):
        """The live model (read through the handle)."""
        return self.handle.current()

    # ---- request API -----------------------------------------------------

    def submit(self, x_query) -> int:
        """Enqueue one request.

        Args:
          x_query: (Q, M) array-like, M = model.n_features; cast to fp32
            host-side (the engine re-casts per ``cfg.query_dtype`` at slab
            build time).

        Returns:
          Integer request id, the key of this request's (Q, C) scores in
          the dict returned by the next ``flush``.
        """
        x = np.asarray(x_query, np.float32)
        if x.ndim != 2 or x.shape[1] != self.model.n_features:
            raise ValueError(
                f"request must be (Q, {self.model.n_features}), "
                f"got {x.shape}")
        rid = self._next_id
        self._next_id += 1
        self._queue.append((rid, x))
        return rid

    def flush(self) -> dict:
        """Serve every queued request; returns {request_id: (Q, C) scores}.

        On failure the queued requests are restored (ahead of anything
        submitted meanwhile), so a crashed flush can simply be retried.
        """
        queue, self._queue = self._queue, []
        if not queue:
            return {}
        try:
            return self._serve(queue)
        except BaseException:
            self._queue = queue + self._queue
            raise

    def _serve(self, queue) -> dict:
        # One consistent (model, version) snapshot for the whole flush:
        # in-flight slabs finish on it even if a publish lands mid-flush.
        model, version = self.handle.get()
        results = {rid: [] for rid, _ in queue}
        touched = {rid: 0.0 for rid, _ in queue}
        sizes = {rid: x.shape[0] for rid, x in queue}

        # Head-to-tail packing: one flat stream of (rid, row-range) spans.
        stream = np.concatenate([x for _, x in queue], axis=0)
        owners = np.concatenate(
            [np.full(x.shape[0], rid, np.int64) for rid, x in queue])

        # Accumulate stats locally and commit only after every slab served,
        # so a failed-then-retried flush doesn't double-count its slabs.
        total_dt, padded = 0.0, 0
        pos = 0
        while pos < stream.shape[0]:
            take = min(self.cfg.max_batch, stream.shape[0] - pos)
            bucket = self._bucket_for(take)
            slab = np.zeros((bucket, stream.shape[1]), np.float32)
            slab[:take] = stream[pos:pos + take]
            t0 = time.perf_counter()
            scores = np.asarray(self._run_slab(model, slab))
            dt = time.perf_counter() - t0
            padded += bucket - take
            total_dt += dt
            span_owners = owners[pos:pos + take]
            for rid in np.unique(span_owners):
                sel = span_owners == rid
                results[rid].append(scores[:take][sel])
                touched[rid] += dt
            pos += take

        self.stats.n_padded += padded
        self.stats.total_time_s += total_dt
        self.stats.n_requests += len(queue)
        self.stats.n_queries += stream.shape[0]
        for rid, _ in queue:
            self.stats.per_request.append(
                RequestStats(rid, sizes[rid], touched[rid], version))
        empty = np.zeros((0, model.n_components), np.float32)
        return {rid: np.concatenate(parts, axis=0) if parts else empty
                for rid, parts in results.items()}

    def project_many(self, requests: Sequence[Any]) -> List[np.ndarray]:
        """Convenience: submit + flush a list of (Q_i, M) arrays; returns
        the per-request (Q_i, C) score arrays in submission order."""
        rids = [self.submit(x) for x in requests]
        out = self.flush()
        return [out[rid] for rid in rids]

    # ---- internals -------------------------------------------------------

    def _bucket_for(self, size: int) -> int:
        for b in self._buckets:
            if size <= b:
                return b
        return self._buckets[-1]

    def _run_slab(self, model, slab: np.ndarray) -> jax.Array:
        xq = jnp.asarray(slab)
        if self.cfg.query_dtype is not None:
            xq = xq.astype(self.cfg.query_dtype)
        if xq.shape not in self._compiled_shapes:
            self._compiled_shapes.add(xq.shape)
            self.stats.n_compiles += 1
        return self._proj(model, xq)
