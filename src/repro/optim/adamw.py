"""AdamW with global-norm clipping. Functional, pytree-based; moments are
kept in fp32 regardless of param dtype (bf16 params + fp32 moments is the
memory layout assumed by the dry-run/roofline analysis)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Callable[[jax.Array], jax.Array] = None  # step -> lr scale


def adamw_init(params: Dict[str, jax.Array]) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": {k: zeros(v) for k, v in params.items()},
        "v": {k: zeros(v) for k, v in params.items()},
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule else 1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_p, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * scale
        m = cfg.b1 * state["m"][k] + (1 - cfg.b1) * g
        v = cfg.b2 * state["v"][k] + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # no decay on norms/bias
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_m[k] = m
        new_v[k] = v
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
