"""Pallas TPU kernel: fused Gram-matrix centering (paper §6.1).

K_c = K - rowmean - colmean + totalmean, tiled so each output block is read
and written exactly once (single HBM pass; the naive jnp version makes XLA
materialize broadcasted mean matrices under some fusion decisions). Means
are cheap O(n^2) reductions computed by the wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _center_kernel(row_ref, col_ref, tot_ref, k_ref, o_ref):
    r = row_ref[...].astype(jnp.float32)     # (bn,) row means
    c = col_ref[...].astype(jnp.float32)     # (bk,) col means
    o_ref[...] = (k_ref[...].astype(jnp.float32)
                  - r[:, None] - c[None, :] + tot_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("block_n", "block_k", "interpret"))
def center_tiles(k: jax.Array, row_mean: jax.Array, col_mean: jax.Array,
                 tot_mean: jax.Array, *, block_n: int = 256,
                 block_k: int = 256, interpret: bool = False) -> jax.Array:
    n, m = k.shape
    assert n % block_n == 0 and m % block_k == 0
    grid = (n // block_n, m // block_k)
    return pl.pallas_call(
        _center_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j: (i,)),
            pl.BlockSpec((block_k,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(row_mean, col_mean, tot_mean, k)
