"""Rotary position embeddings (supports partial-dim rotary for MLA)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(dim: int, theta: float) -> jax.Array:
    """(dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, dim) or (..., seq, dim); positions: (..., seq)."""
    dim = x.shape[-1]
    inv = rope_freqs(dim, theta)                       # (dim/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., seq, dim/2)
    if x.ndim == ang.ndim + 1:                         # heads axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
