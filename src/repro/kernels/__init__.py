# Pallas TPU kernels for the paper's compute hot spots. Each subpackage has
# <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd public wrapper with
# padding/dispatch) and ref.py (pure-jnp oracle used by the allclose tests).
# On non-TPU backends the wrappers run the kernels in interpret mode.
from .gram import gram_op, gram_reference
from .centering import center_op, center_reference
from .admm_step import admm_local_update_op, admm_local_update_reference
from .project import (project_op, project_partial_op,
                      project_partial_reference, project_reference)

__all__ = [
    "gram_op", "gram_reference", "center_op", "center_reference",
    "admm_local_update_op", "admm_local_update_reference",
    "project_op", "project_partial_op", "project_partial_reference",
    "project_reference",
]
