"""Fault-tolerant ADMM driver: survive what the plan injects.

Wraps the chunked reference driver (``core/solver.run_chunked``) in
fault semantics read off a :class:`~repro.faults.plan.FaultPlan`:

- **Link loss / delay / straggler stalls** compile to a per-iteration
  link mask (``plan.link_mask``) that ``run_chunked`` threads into every
  ``admm_step`` — the COKE-style censored update: received columns are
  zeroed at the transport (``FaultyComm``), ``rho_bar`` renormalizes
  over the slots actually heard, and censored duals freeze. No restart,
  no topology change.

- **Node dropout at iteration t** is detected at a chunk boundary: the
  driver clamps the running segment at t, re-knits the topology
  (``core/topology.reknit``), shrinks the live ``AdmmState`` to the
  survivors (:func:`shrink_state` — the carried (alpha, B) IS the warm
  z-start; ``t`` keeps counting), rebuilds the Gram setup on survivor
  data with the ORIGINAL gamma pinned, and continues. The survivors'
  consensus then converges to the survivor-pooled central solution
  without refitting from scratch — the property
  ``tests/test_fault_injection.py`` pins at >= 0.95 similarity.

Everything is host-side and single-threaded (the same concurrency
contract as ``run_chunked``); fault accounting — ``fault.injected``
instants, ``faults_injected_total`` / ``reknit_total`` counters,
``fault.recovery`` spans — happens here, never inside traced code.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import topology
from ..core.admm import build_setup, initial_alpha
from ..core.solver import AdmmState, ChunkResult, init_state, run_chunked
from ..obs import metrics, trace
from .plan import FaultPlan

# module-level cached handles: the hot loop must not allocate new metric
# identities per call (same contract as serve/kpca_engine.py)
_M_INJECTED_DROPOUT = metrics.counter(
    "faults_injected_total", "fault events activated", kind="dropout")
_M_INJECTED_LINK = metrics.counter(
    "faults_injected_total", "fault events activated", kind="link")
_M_INJECTED_STRAGGLER = metrics.counter(
    "faults_injected_total", "fault events activated", kind="straggler")
_M_REKNIT = metrics.counter(
    "reknit_total", "topology re-knits after node dropout")


@dataclasses.dataclass(frozen=True)
class FaultEventRecord:
    """Host-side record of one applied fault (for tests/reports)."""
    kind: str
    t: int
    detail: dict


def shrink_state(state: AdmmState, old_graph: topology.Graph,
                 new_graph: topology.Graph,
                 survivors: np.ndarray) -> AdmmState:
    """Map a live ``AdmmState`` onto the re-knit survivor topology.

    ``survivors[new_row] = old_row`` (``reknit``'s second return). The
    warm content carries over exactly where the constraint survived:

    - ``alpha``/``znorm2``: survivor rows, unchanged — the primal iterate
      is per-node and node data did not change.
    - ``b``/``g`` slot columns: survivor self slot 0 copies over; a
      neighbor slot copies iff that edge existed before the re-knit
      (matched by ORIGINAL node id); edges the re-knit invented start
      with zero dual/projection, exactly like iteration 0 of a fresh
      constraint.
    - ``rho``: zeroed — the driver refreshes per-slot rho every
      iteration from the schedule, so stale values must not leak.
    - ``t``: preserved. This is a continuation, not a restart.
    """
    surv = [int(v) for v in survivors]
    old_ids, _, old_mask = old_graph.neighbor_array()
    new_ids, _, new_mask = new_graph.neighbor_array()
    j2, d2 = new_ids.shape
    alpha_old = np.asarray(state.alpha)
    b_old = np.asarray(state.b)
    g_old = np.asarray(state.g)
    n = alpha_old.shape[1]
    dt = alpha_old.dtype

    alpha = alpha_old[surv]
    znorm2 = np.asarray(state.znorm2)[surv]
    b = np.zeros((j2, n, d2 + 1), dt)
    g = np.zeros((j2, n, d2 + 1), dt)
    for nj, o in enumerate(surv):
        b[nj, :, 0] = b_old[o, :, 0]
        g[nj, :, 0] = g_old[o, :, 0]
        old_slot = {int(old_ids[o, d]): d + 1
                    for d in range(old_ids.shape[1]) if old_mask[o, d]}
        for d in range(d2):
            if not new_mask[nj, d]:
                continue
            l_orig = surv[int(new_ids[nj, d])]
            s_old = old_slot.get(l_orig)
            if s_old is not None:
                b[nj, :, d + 1] = b_old[o, :, s_old]
                g[nj, :, d + 1] = g_old[o, :, s_old]
    return AdmmState(
        alpha=jnp.asarray(alpha), b=jnp.asarray(b), g=jnp.asarray(g),
        znorm2=jnp.asarray(znorm2), t=state.t,
        rho=jnp.zeros((j2, d2 + 1), dt))


class FaultTolerantRun:
    """Chunked ADMM run that survives a :class:`FaultPlan`.

    Iterate :meth:`chunks` exactly like ``run_chunked``; between the
    yielded chunks the driver applies dropout recovery. Inspect after
    (or during) the run:

    - ``node_ids``: original id of each current row (survivor mapping).
    - ``graph`` / ``setup`` / ``state``: the live topology and iterate.
    - ``events``: ordered :class:`FaultEventRecord` list.
    - ``n_reknits``: recovery count (== number of dropout instants).
    """

    def __init__(self, x_nodes, graph: topology.Graph, spec, plan: FaultPlan,
                 n_iters: int = 30, chunk: int = 10,
                 center: str = "global", include_self: bool = True,
                 rho1: float = 100.0, rho2=None, project: str = "ball",
                 init: str = "local", seed: int = 0, tol: float = 0.0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 1,
                 ledger=None):
        self.x_nodes = jnp.asarray(x_nodes)
        self.graph = graph
        self.spec = spec
        self.plan = plan
        self.n_iters = int(n_iters)
        self.chunk = int(chunk)
        self.kw = dict(rho1=rho1, rho2=rho2, project=project, tol=tol,
                       ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                       ledger=ledger)
        self.center = center
        self.include_self = include_self
        self.init = init
        self.seed = int(seed)
        self.node_ids = np.arange(graph.n_nodes, dtype=np.int64)
        self.events: List[FaultEventRecord] = []
        self.n_reknits = 0
        self.setup = build_setup(self.x_nodes, graph, spec, center=center,
                                 include_self=include_self)
        self.gamma = float(self.setup.gamma)
        self.state: Optional[AdmmState] = None
        sched = plan.dropout_schedule()
        bad = [t for t, _ in sched if not 0 < t < self.n_iters]
        if bad:
            raise ValueError(f"dropout instants {bad} outside (0, n_iters)")

    # -- internals ---------------------------------------------------------

    def _segments(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """``[(stop, nodes-dropping-at-stop), ...]`` covering [0, n_iters]."""
        segs = [(t, nodes) for t, nodes in self.plan.dropout_schedule()]
        segs.append((self.n_iters, ()))
        return segs

    def _segment_mask(self, stop: int) -> Optional[np.ndarray]:
        if not self.plan.has_link_faults(0, stop):
            return None
        return self.plan.link_mask(
            np.asarray(self.setup.src), np.asarray(self.setup.mask),
            0, stop, node_ids=self.node_ids)

    def _record(self, kind: str, t: int, counter, **detail) -> None:
        self.events.append(FaultEventRecord(kind=kind, t=t, detail=detail))
        counter.inc()
        if trace.is_enabled():
            trace.instant("fault.injected", kind=kind, t=t, **detail)

    def _recover(self, t: int, dead_ids: Tuple[int, ...]) -> None:
        """Re-knit + state shrink + setup rebuild — one recovery span."""
        t0 = time.perf_counter()
        dead_rows = [int(np.nonzero(self.node_ids == d)[0][0])
                     for d in dead_ids]
        old_graph = self.graph
        new_graph, surv_rows = topology.reknit(old_graph, dead_rows)
        self.state = shrink_state(self.state, old_graph, new_graph,
                                  surv_rows)
        self.node_ids = self.node_ids[np.asarray(surv_rows)]
        self.x_nodes = self.x_nodes[np.asarray(surv_rows)]
        self.graph = new_graph
        # Same gamma ⇒ same kernel operator on the survivor data; the
        # shrunk (alpha, B) is a warm z-start for the survivor consensus.
        self.setup = build_setup(self.x_nodes, new_graph, self.spec,
                                 center=self.center,
                                 include_self=self.include_self,
                                 gamma=self.gamma)
        self.n_reknits += 1
        _M_REKNIT.inc()
        if trace.is_enabled():
            trace.complete("fault.recovery", time.perf_counter() - t0,
                           kind="dropout", t=t, dead=list(dead_ids),
                           survivors=len(surv_rows))

    # -- the run -----------------------------------------------------------

    def chunks(self) -> Iterator[ChunkResult]:
        for lf in self.plan.links:
            self._record("link", lf.t0, _M_INJECTED_LINK, u=lf.u, v=lf.v,
                         t1=lf.t1, directed=lf.directed)
        for st_ev in self.plan.stragglers:
            self._record("straggler", st_ev.t0, _M_INJECTED_STRAGGLER,
                         node=st_ev.node, t1=st_ev.t1)
        if self.state is None:
            alpha0 = initial_alpha(self.setup, self.init, self.seed)
            self.state = init_state(alpha0, self.setup.n_slots)
        for stop, dead in self._segments():
            if int(self.state.t) < stop:
                for res in run_chunked(
                        self.setup, n_iters=stop, chunk=self.chunk,
                        state=self.state,
                        link_mask=self._segment_mask(stop), **self.kw):
                    self.state = res.state
                    yield res
                    if res.stopped:
                        return
            if dead:
                self._record("dropout", stop, _M_INJECTED_DROPOUT,
                             nodes=list(dead))
                self._recover(stop, dead)

    def __iter__(self) -> Iterator[ChunkResult]:
        return self.chunks()


def run_chunked_with_faults(x_nodes, graph, spec, plan,
                            **kw) -> FaultTolerantRun:
    """Convenience constructor mirroring ``run_chunked``'s shape."""
    return FaultTolerantRun(x_nodes, graph, spec, plan, **kw)


__all__ = ["FaultTolerantRun", "FaultEventRecord", "run_chunked_with_faults",
           "shrink_state"]
