"""Mixture-of-Experts layer (mixtral / deepseek-v2 style).

Production path (``moe_forward``): a ``shard_map`` region over the mesh —
experts are sharded along the "model" axis, activations stay replicated
across it (the dense-TP convention used throughout this repo). Each model
shard routes its data-shard's tokens, packs them into a capacity-bounded
(E, C, D) buffer (cumsum ranking + scatter — all per-shard, no cross-shard
traffic), computes ONLY its local experts' FFNs, scatters contributions back
to token order, and a single psum over "model" combines expert outputs —
the same collective a dense TP FFN needs, with active-expert FLOPs
(T * top_k * capacity_factor per token, not E *).

Reference path (``moe_forward_ref``): exact dense loop over experts (no
capacity drops) used by smoke tests to validate routing/combining math.

Capacity: C = max(1, ceil(T*k*cf/E)); when T*k <= 8*E (decode and test
shapes) we use C = T*k, which makes the layer exactly drop-free.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..distributed.compat import shard_map
from .common import ParamCollector, activation
from .mlp import init_mlp, mlp_forward


def init_moe(col: ParamCollector, cfg: ArchConfig, prefix: str = "moe"):
    e, f, ne = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    col.param(f"{prefix}/router", (e, ne), ("embed_nofsdp", None),
              dtype=jnp.float32)
    col.param(f"{prefix}/w_gate", (ne, e, f), ("expert", "embed", "expert_mlp"))
    col.param(f"{prefix}/w_up", (ne, e, f), ("expert", "embed", "expert_mlp"))
    col.param(f"{prefix}/w_down", (ne, f, e), ("expert", "expert_mlp", "embed"))
    if cfg.n_shared_experts:
        init_mlp(col, cfg, f"{prefix}/shared",
                 d_ff=cfg.n_shared_experts * cfg.d_ff_expert)


def _route(p, cfg: ArchConfig, x_flat):
    """x_flat (T, E) -> (ids (T,k), weights (T,k) renormalized)."""
    logits = x_flat.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balance auxiliary (Switch-style): E * mean(frac_tokens * mean_prob)
    dispatch = jnp.zeros_like(probs).at[
        jnp.arange(ids.shape[0])[:, None], ids].add(1.0)
    aux = cfg.n_experts * jnp.mean(jnp.mean(dispatch, 0) * jnp.mean(probs, 0))
    return ids, w.astype(x_flat.dtype), aux


def _capacity(t: int, cfg: ArchConfig) -> int:
    tk = t * cfg.top_k
    if tk <= 8 * cfg.n_experts:
        return tk  # exact (drop-free) — decode/smoke shapes
    return max(1, math.ceil(tk * cfg.capacity_factor / cfg.n_experts))


def _expert_ffn(w_gate, w_up, w_down, act, buf):
    """buf (E_loc, C, D) -> (E_loc, C, D)."""
    g = act(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", g * u, w_down)


def moe_forward(p, cfg: ArchConfig, x, mesh, model_axis: str = "model",
                batch_axes=None):
    """x (B, S, E) -> (y, aux_loss). shard_map over the full mesh."""
    if mesh is None:
        return moe_forward_ref(p, cfg, x)
    if batch_axes is None:
        from .common import batch_axes_of
        batch_axes = batch_axes_of(mesh)
    b, s, e = x.shape
    import numpy as _np
    dp = int(_np.prod([mesh.shape[a] for a in batch_axes]))
    if b % dp:
        # tiny-batch decode (e.g. long-context B=1): replicate tokens over
        # the data axes; every shard routes the same tokens, experts stay
        # model-sharded
        batch_axes = ()
    tp = mesh.shape[model_axis]
    ne = cfg.n_experts
    # virtual-expert splitting: when TP > n_experts (mixtral: 8e over a
    # 16-way model axis) each expert's FFN hidden dim is split across
    # repl = tp/ne shards; virtual expert v = real r * repl + replica. The
    # down-proj partial products are summed by the same psum that combines
    # experts — mathematically exact.
    repl = max(1, tp // ne)
    assert (ne * repl) % tp == 0, (ne, tp)
    assert cfg.d_ff_expert % repl == 0, (cfg.d_ff_expert, repl)

    router = p["router"]
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if repl > 1:
        f = cfg.d_ff_expert
        fr = f // repl
        wg = wg.reshape(ne, e, repl, fr).transpose(0, 2, 1, 3) \
            .reshape(ne * repl, e, fr)
        wu = wu.reshape(ne, e, repl, fr).transpose(0, 2, 1, 3) \
            .reshape(ne * repl, e, fr)
        wd = wd.reshape(ne, repl, fr, e).reshape(ne * repl, fr, e)
    ne_v = ne * repl
    all_axes = tuple(a for a in mesh.axis_names)

    def local(x_loc, router_w, wg_l, wu_l, wd_l):
        bl, sl, el = x_loc.shape
        t = bl * sl
        xf = x_loc.reshape(t, el)
        ids, w, aux = _route({"router": router_w}, cfg, xf)
        c = _capacity(t, cfg)
        ne_loc = ne_v // tp
        # rank of each (token, slot) within its REAL expert
        flat_ids = ids.reshape(-1)                          # (T*k,)
        oh = jax.nn.one_hot(flat_ids, ne, dtype=jnp.int32)  # (T*k, E)
        pos = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(t * cfg.top_k),
                                           flat_ids]        # (T*k,)
        keep = pos < c
        # pack into this shard's owned virtual experts
        m_idx = jax.lax.axis_index(model_axis)
        real_of_local = (m_idx * ne_loc + jnp.arange(ne_loc)) // repl
        match = flat_ids[:, None] == real_of_local[None, :]  # (T*k, ne_loc)
        local_e = jnp.argmax(match, axis=1)
        mine = jnp.any(match, axis=1) & keep
        src = jnp.repeat(xf, cfg.top_k, axis=0)             # (T*k, D)
        buf = jnp.zeros((ne_loc, c, el), x_loc.dtype)
        buf = buf.at[jnp.where(mine, local_e, 0),
                     jnp.where(mine, pos, 0)].add(
            src * mine[:, None].astype(src.dtype))
        out = _expert_ffn(wg_l.astype(x_loc.dtype), wu_l.astype(x_loc.dtype),
                          wd_l.astype(x_loc.dtype), activation(cfg.act), buf)
        # gather back to (T*k, D), weight, combine over slots
        vals = out[jnp.where(mine, local_e, 0), jnp.where(mine, pos, 0)]
        vals = vals * mine[:, None].astype(vals.dtype)
        y = jnp.sum((vals * w.reshape(-1, 1)).reshape(t, cfg.top_k, el),
                    axis=1)
        y = jax.lax.psum(y, model_axis)    # combine experts + ffn splits
        aux = jax.lax.pmean(aux, all_axes)
        return y.reshape(bl, sl, el), aux[None]

    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(batch_axes, None, None), P(None)),
        check_vma=False,
    )(x, router, wg, wu, wd)
    aux = aux[0]

    if cfg.n_shared_experts:
        shared = {k[len("shared/"):]: v for k, v in p.items()
                  if k.startswith("shared/")}
        y = y + mlp_forward(shared, cfg, x)
    return y, aux


def moe_forward_ref(p, cfg: ArchConfig, x):
    """Exact dense reference: loop over experts, no capacity drops."""
    b, s, e = x.shape
    xf = x.reshape(b * s, e)
    ids, w, aux = _route(p, cfg, xf)
    act = activation(cfg.act)
    y = jnp.zeros_like(xf)
    for ex in range(cfg.n_experts):
        g = act(xf @ p["w_gate"][ex].astype(xf.dtype))
        u = xf @ p["w_up"][ex].astype(xf.dtype)
        o = (g * u) @ p["w_down"][ex].astype(xf.dtype)
        gate = jnp.sum(jnp.where(ids == ex, w, 0.0), axis=-1)
        y = y + o * gate[:, None]
    y = y.reshape(b, s, e)
    if cfg.n_shared_experts:
        shared = {k[len("shared/"):]: v for k, v in p.items()
                  if k.startswith("shared/")}
        y = y + mlp_forward(shared, cfg, x)
    return y, aux
