"""Shared ``--trace-out`` / ``--metrics-out`` wiring for the launchers.

Every entry point that can produce a flight recording (``launch.serve_kpca``,
``launch.train``, ``benchmarks.run``) takes the same two flags:

    --trace-out trace.json      enable the span tracer; write Chrome-trace
                                JSON at exit (open in https://ui.perfetto.dev)
    --metrics-out metrics.json  write the final metrics-registry snapshot

Usage:

    add_obs_args(ap)
    args = ap.parse_args()
    with obs_session(args):
        ...                     # instrumented run
    # files written on exit (also on the exception path)
"""

from __future__ import annotations

import contextlib

from . import metrics, trace


def add_obs_args(ap) -> None:
    """Install the two observability flags on an ``ArgumentParser``."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing; write Chrome-trace JSON "
                         "(chrome://tracing / Perfetto) to PATH at exit")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics snapshot (JSON) to PATH "
                         "at exit")


@contextlib.contextmanager
def obs_session(args):
    """Enable tracing per ``args.trace_out`` around the body; export trace
    and metrics files on the way out (including the exception path, so a
    crashed run still leaves its recording behind)."""
    if args.trace_out:
        trace.enable()
    try:
        yield
    finally:
        if args.trace_out:
            n = trace.export(args.trace_out)
            print(f"wrote {n} trace events -> {args.trace_out}")
            trace.disable()
        if args.metrics_out:
            metrics.write_json(args.metrics_out)
            print(f"wrote metrics snapshot -> {args.metrics_out}")


__all__ = ["add_obs_args", "obs_session"]
