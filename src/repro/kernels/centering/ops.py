"""Jitted wrapper for the centering Pallas kernel (padding + dispatch).

``block`` defaults to the autotuner's table entry for this shape/dtype/
backend (``repro.kernels.autotune``), falling back to 256 when untuned."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..autotune import get_tiles
from .._util import _on_tpu, _pad_to, _round_up
from .centering import center_tiles


def center_op(k: jax.Array, block: Optional[int] = None,
              interpret: Optional[bool] = None) -> jax.Array:
    """Fused K_c = K - rowmean - colmean + totalmean (paper §6.1 formula)."""
    if interpret is None:
        interpret = not _on_tpu()
    if block is None:
        block = get_tiles("centering", k.shape, k.dtype)["block"]
    n, m = k.shape
    kf = k.astype(jnp.float32)
    row = jnp.mean(kf, axis=1)
    col = jnp.mean(kf, axis=0)
    tot = jnp.mean(kf)[None]
    bn = min(block, _round_up(n, 8))
    bk = min(block, _round_up(m, 128))
    kp = _pad_to(_pad_to(kf, bn, 0), bk, 1)
    rp = _pad_to(row, bn, 0)
    cp = _pad_to(col, bk, 0)
    out = center_tiles(kp, rp, cp, tot, block_n=bn, block_k=bk,
                       interpret=interpret)
    return out[:n, :m]
