"""Pure-jnp oracle for the projection Pallas kernel — same score contract
as ``repro.core.oos.project`` (single source of numerical truth)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.kernels_math import KernelSpec, gram


def project_reference(spec: KernelSpec, x_query: jax.Array,
                      x_support: jax.Array, coefs: jax.Array,
                      row_mean_coef: Optional[jax.Array] = None,
                      bias: Optional[jax.Array] = None,
                      gamma: Optional[jax.Array] = None) -> jax.Array:
    k = gram(spec, x_query, x_support, gamma=gamma)
    out = k @ coefs
    if row_mean_coef is not None:
        out = out + jnp.mean(k, axis=1, keepdims=True) * row_mean_coef[None]
    if bias is not None:
        out = out + bias[None, :]
    return out
