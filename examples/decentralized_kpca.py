"""The paper's experimental workflow end-to-end (Figs 3/4/5 regimes) plus
the fault-tolerance story (a node dies mid-run, the ring re-knits, ADMM
continues on the survivors) plus the serving story: the consensus solution
is packaged into a FittedKpca artifact, landmark-compressed, and served
from the batched projection engine.

    PYTHONPATH=src python examples/decentralized_kpca.py [--m 784]
"""

import argparse
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import (KernelSpec, build_setup, central_kpca, oos,
                        run_admm, similarity)
from repro.core.topology import reknit, ring
from repro.data import kpca_dataset, node_dataset
from repro.serve import KpcaEngine, KpcaServeConfig

SPEC = KernelSpec(kind="rbf")


def mean_sim(alphas, nodes, pooled, ag, gamma):
    return float(np.mean([
        float(similarity(alphas[j], jnp.asarray(nodes[j]), ag,
                         jnp.asarray(pooled), SPEC, gamma=gamma))
        for j in range(nodes.shape[0])]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=20)
    args = ap.parse_args()

    print(f"== decentralized kPCA: J={args.nodes}, N=100, M={args.m} ==")
    nodes, pooled = node_dataset(args.nodes, 100, m=args.m, seed=0)
    graph = ring(args.nodes, hops=2)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    ag, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1, gamma=setup.gamma)
    res = run_admm(setup, n_iters=30)
    for t in (1, 3, 7, 15, 29):
        print(f"  iter {t + 1:3d}: similarity = "
              f"{mean_sim(res.alpha_hist[t], nodes, pooled, ag[:, 0], setup.gamma):.4f}")

    print("== node failure: nodes 5 and 6 die; ring re-knits ==")
    g2, survivors = reknit(graph, [5, 6])
    nodes2 = nodes[survivors]
    pooled2 = nodes2.reshape(-1, nodes2.shape[-1])
    setup2 = build_setup(jnp.asarray(nodes2), g2, SPEC)
    ag2, _, _ = central_kpca(jnp.asarray(pooled2), SPEC, 1,
                             gamma=setup2.gamma)
    res2 = run_admm(setup2, n_iters=30)
    print(f"  survivors' similarity to the *surviving-data* central "
          f"solution: {mean_sim(res2.alpha, nodes2, pooled2, ag2[:, 0], setup2.gamma):.4f}")

    print("== serve: fit -> artifact -> compress -> batched engine ==")
    # Package the consensus solution for out-of-sample projection. The
    # artifact carries the global centering statistics the fit used, so
    # served scores match the centered feature space exactly.
    model = oos.from_decentralized(jnp.asarray(nodes), res.alpha, SPEC,
                                   gamma=setup.gamma, center=True)
    with tempfile.TemporaryDirectory() as d:
        oos.save_fitted(d, model)
        model = oos.load_fitted(d)        # round-trip through repro.checkpoint
    n_landmarks = model.n_support // 4
    compressed, err = oos.compress(model, n_landmarks, seed=0)
    print(f"  support {model.n_support} -> {n_landmarks} landmarks, "
          f"rel recon err {float(err[0]):.2e}")

    engine = KpcaEngine(compressed, KpcaServeConfig(max_batch=64,
                                                    min_bucket=8))
    requests = [kpca_dataset(q, m=args.m, seed=100 + q) for q in (3, 17, 64)]
    scores = engine.project_many(requests)
    direct = oos.project(compressed, jnp.asarray(requests[-1]))
    print(f"  served {engine.stats.n_queries} queries in "
          f"{len(requests)} requests at "
          f"{engine.stats.queries_per_s:,.0f} q/s "
          f"(compiles={engine.stats.n_compiles})")
    print(f"  engine vs direct max diff: "
          f"{float(np.max(np.abs(scores[-1] - np.asarray(direct)))):.1e}")


if __name__ == "__main__":
    main()
