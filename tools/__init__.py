# Repo tooling namespace (no runtime deps on src/; never imports jax).
