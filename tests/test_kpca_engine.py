"""Tests for the batched kPCA projection-serving engine."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, oos
from repro.serve import KpcaEngine, KpcaServeConfig

SPEC = KernelSpec(kind="rbf", gamma=0.25)


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def model():
    x = jnp.asarray(_rand((48, 12), seed=0))
    return oos.fit_central(x, SPEC, n_components=2, center=True)


class TestBuckets:
    def test_power_of_two_ladder(self):
        cfg = KpcaServeConfig(max_batch=64, min_bucket=8)
        assert cfg.buckets() == [8, 16, 32, 64]

    def test_non_pow2_max_is_widest(self):
        cfg = KpcaServeConfig(max_batch=48, min_bucket=8)
        assert cfg.buckets() == [8, 16, 32, 48]


class TestEngineCorrectness:
    def test_identical_to_direct_across_bucket_boundaries(self, model):
        """Request sizes straddling every bucket boundary (and slab
        boundaries) must give exactly the unbatched per-request scores."""
        cfg = KpcaServeConfig(max_batch=32, min_bucket=4)
        eng = KpcaEngine(model, cfg)
        sizes = [1, 3, 4, 5, 8, 9, 16, 17, 31, 32, 33, 64, 65]
        reqs = [_rand((q, 12), seed=100 + q) for q in sizes]
        got = eng.project_many(reqs)
        for r, g in zip(reqs, got):
            want = np.asarray(oos.project(model, jnp.asarray(r)))
            # row-wise kernel math is independent of batch packing; the only
            # residue is XLA picking a different gemm path per shape
            # (observed <= 4e-9), so pin to float32 resolution, not bits.
            np.testing.assert_allclose(g, want, rtol=1e-6, atol=1e-7)

    def test_empty_request_yields_empty_scores(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=16, min_bucket=4))
        r0 = eng.submit(np.zeros((0, 12), np.float32))
        r1 = eng.submit(_rand((4, 12), seed=8))
        eng.flush()
        assert r0.result().shape == (0, 2)
        want = np.asarray(oos.project(model, jnp.asarray(
            _rand((4, 12), seed=8))))
        np.testing.assert_allclose(r1.result(), want, rtol=1e-6, atol=1e-7)

    def test_interleaved_submit_flush(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=16, min_bucket=4))
        r1 = eng.submit(_rand((5, 12), seed=1))
        r2 = eng.submit(_rand((20, 12), seed=2))
        out = eng.flush()
        assert set(out) == {r1.request_id, r2.request_id}
        assert r1.result().shape == (5, 2) and r2.result().shape == (20, 2)
        assert eng.flush() == {}  # queue drained

    def test_compressed_model_serving(self, model):
        cm, _ = oos.compress(model, 24, seed=0)
        eng = KpcaEngine(cm, KpcaServeConfig(max_batch=16, min_bucket=4))
        xq = _rand((10, 12), seed=3)
        [got] = eng.project_many([xq])
        want = np.asarray(oos.project(cm, jnp.asarray(xq)))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_pallas_path(self, model):
        cfg = KpcaServeConfig(max_batch=16, min_bucket=8, use_pallas=True,
                              interpret=True)
        eng = KpcaEngine(cfg=cfg, model=model)
        xq = _rand((13, 12), seed=4)
        [got] = eng.project_many([xq])
        want = np.asarray(oos.project(model, jnp.asarray(xq)))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_poly_kernel_end_to_end(self):
        """Non-RBF spec (normalized poly, §3.1) through the full serving
        path: fit -> engine buckets/slabs -> fused Pallas kernel."""
        spec = KernelSpec(kind="poly", degree=2, scale=0.5)
        x = jnp.asarray(_rand((40, 8), seed=50))
        pmodel = oos.fit_central(x, spec, n_components=2, center=True)
        eng = KpcaEngine(pmodel, KpcaServeConfig(
            max_batch=16, min_bucket=4, use_pallas=True, interpret=True))
        reqs = [_rand((q, 8), seed=51 + q) for q in (3, 16, 21)]
        got = eng.project_many(reqs)
        for r, g in zip(reqs, got):
            want = np.asarray(oos.project(pmodel, jnp.asarray(r)))
            np.testing.assert_allclose(g, want, rtol=2e-4, atol=2e-4)

    def test_bf16_query_cast(self, model):
        cfg = KpcaServeConfig(max_batch=16, min_bucket=8,
                              query_dtype=jnp.bfloat16)
        eng = KpcaEngine(model, cfg)
        xq = _rand((6, 12), seed=5)
        [got] = eng.project_many([xq])
        want = np.asarray(oos.project(model, jnp.asarray(xq)))
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


class TestEngineAccounting:
    def test_bucket_reuse_bounds_compiles(self, model):
        """Any request mix compiles at most len(buckets) programs."""
        cfg = KpcaServeConfig(max_batch=16, min_bucket=4)
        eng = KpcaEngine(model, cfg)
        for seed, q in enumerate([1, 2, 3, 5, 7, 11, 13, 16, 20, 40, 6, 9]):
            eng.submit(_rand((q, 12), seed=200 + seed))
        eng.flush()
        assert eng.stats.n_compiles <= len(cfg.buckets())
        assert eng.stats.n_queries == sum([1, 2, 3, 5, 7, 11, 13, 16, 20,
                                           40, 6, 9])
        assert eng.stats.n_requests == 12

    def test_failed_flush_restores_queue(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=8, min_bucket=8))
        fut = eng.submit(_rand((3, 12), seed=8))

        def boom(_model, _version, _slab):
            raise RuntimeError("injected")

        run_slab, eng._run_slab = eng._run_slab, boom
        with pytest.raises(RuntimeError):
            eng.flush()
        assert not fut.done()                  # sync failure keeps it queued
        eng._run_slab = run_slab
        eng.flush()                            # retry serves the request
        assert fut.result().shape == (3, 2)
        # the failed attempt must not contaminate the accounting
        assert eng.stats.n_requests == 1
        assert len(eng.stats.per_request) == 1

    def test_rejects_bad_shapes_and_config(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=8, min_bucket=8))
        with pytest.raises(ValueError):
            eng.submit(_rand((12,), seed=9))        # 1-D
        with pytest.raises(ValueError):
            eng.submit(_rand((3, 7), seed=9))       # wrong feature width
        with pytest.raises(ValueError):
            KpcaServeConfig(max_batch=4, min_bucket=8).buckets()

    def test_latency_stats_populated(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=8, min_bucket=8))
        eng.project_many([_rand((3, 12), seed=6), _rand((9, 12), seed=7)])
        assert len(eng.stats.per_request) == 2
        p50, p99 = eng.stats.latency_percentiles()
        assert 0 < p50 <= p99
        assert eng.stats.queries_per_s > 0
