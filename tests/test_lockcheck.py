"""Tests for the runtime lock-order detector (tests/helpers/lockcheck.py):
graph edge recording, cycle detection on a deliberately-introduced AB/BA
interleaving, Condition integration, and end-to-end instrumentation of the
real serving objects."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from helpers.lockcheck import (LockOrderGraph, OrderedLock,
                               instrument_serving_locks)
from repro.core import KernelSpec, oos
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle

SPEC = KernelSpec(kind="rbf", gamma=0.25)


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


def _run_threads(*fns):
    threads = [threading.Thread(target=fn) for fn in fns]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


class TestGraph:
    def test_nested_acquisition_records_edge(self):
        g = LockOrderGraph()
        a, b = OrderedLock("A", g), OrderedLock("B", g)
        with a:
            with b:
                pass
        assert g.edges == {"A": {"B"}}
        assert g.find_cycle() is None

    def test_sequential_acquisition_records_no_edge(self):
        g = LockOrderGraph()
        a, b = OrderedLock("A", g), OrderedLock("B", g)
        with a:
            pass
        with b:
            pass
        assert g.edges == {}

    def test_detects_deliberate_ab_ba_cycle(self):
        """The acceptance case: two threads that take the same two locks
        in opposite orders are flagged even though the interleaving
        happened NOT to deadlock (the threads ran back to back)."""
        g = LockOrderGraph()
        a, b = OrderedLock("A", g), OrderedLock("B", g)

        def t_ab():
            with a:
                with b:
                    pass

        def t_ba():
            with b:
                with a:
                    pass

        _run_threads(t_ab)
        assert g.find_cycle() is None          # one order alone is fine
        _run_threads(t_ba)
        cycle = g.find_cycle()
        assert cycle is not None
        assert cycle[0] == cycle[-1]           # closed path
        assert set(cycle) == {"A", "B"}

    def test_three_lock_cycle(self):
        g = LockOrderGraph()
        locks = {n: OrderedLock(n, g) for n in "ABC"}

        def chain(x, y):
            def fn():
                with locks[x]:
                    with locks[y]:
                        pass
            return fn

        _run_threads(chain("A", "B"), chain("B", "C"))
        assert g.find_cycle() is None
        _run_threads(chain("C", "A"))
        assert g.find_cycle() is not None

    def test_reacquire_same_name_is_not_a_cycle(self):
        """Two distinct locks sharing a name (lockdep-style lock classes)
        must not self-edge."""
        g = LockOrderGraph()
        a1, a2 = OrderedLock("A", g), OrderedLock("A", g)
        with a1:
            with a2:
                pass
        assert g.find_cycle() is None

    def test_per_thread_held_stacks_are_independent(self):
        g = LockOrderGraph()
        a, b = OrderedLock("A", g), OrderedLock("B", g)
        ready = threading.Event()
        done = threading.Event()

        def holder():
            with a:
                ready.set()
                done.wait(5.0)

        t = threading.Thread(target=holder)
        t.start()
        assert ready.wait(5.0)
        with b:                   # main thread holds nothing else: no edge
            pass
        done.set()
        t.join()
        assert g.edges == {}


class TestConditionIntegration:
    def test_condition_wait_notify_roundtrip(self):
        """``threading.Condition(OrderedLock(...))`` must behave like a
        plain condition (wait releases, notify wakes) while recording
        edges for locks held AROUND the condition."""
        g = LockOrderGraph()
        outer = OrderedLock("outer", g)
        cond = threading.Condition(OrderedLock("cond", g))
        state = {"go": False, "seen": False}

        def waiter():
            with cond:
                while not state["go"]:
                    cond.wait(5.0)
                state["seen"] = True

        t = threading.Thread(target=waiter)
        t.start()
        with outer:
            with cond:
                state["go"] = True
                cond.notify_all()
        t.join(5.0)
        assert not t.is_alive() and state["seen"]
        assert g.edges == {"outer": {"cond"}}
        assert g.find_cycle() is None


class TestServingInstrumentation:
    def test_async_engine_records_edges_and_no_cycle(self):
        """End-to-end: a live flusher + publisher run under instrumented
        locks records a non-trivial acquisition graph with no cycle."""
        x = jnp.asarray(_rand((32, 8), seed=0))
        model = oos.fit_central(x, SPEC, n_components=2, center=True)
        graph = LockOrderGraph()
        with instrument_serving_locks(graph):
            handle = ModelHandle(model)
            eng = KpcaEngine(handle, KpcaServeConfig(
                max_batch=8, min_bucket=8, flush_max_wait_s=0.002))
            with eng:
                futs = [eng.submit(_rand((3, 8), seed=i))
                        for i in range(8)]
                for f in futs:
                    assert f.result(timeout=30.0).shape == (3, 2)
            handle.refresh(model.coefs * 2.0)
        names = set(graph.edges) | {v for vs in graph.edges.values()
                                    for v in vs}
        assert any("_refresh_lock" in n for n in names)   # refresh -> lock
        assert graph.find_cycle() is None

    def test_instrumentation_is_removed_on_exit(self):
        import repro.serve.batching as batching
        graph = LockOrderGraph()
        with instrument_serving_locks(graph):
            assert batching.threading is not threading
        assert batching.threading is threading


class TestFixtureWiring:
    @pytest.mark.lockcheck
    def test_guard_fixture_provides_graph(self, lock_order_guard):
        """Marked tests receive the active graph; serve objects built here
        are instrumented."""
        assert isinstance(lock_order_guard, LockOrderGraph)
        from repro.serve.batching import RequestQueue
        q = RequestQueue()
        q.put(np.zeros((1, 2), np.float32), n=1)
        assert len(q.drain()) == 1
        # the queue's condition was built through the shim: its lock is an
        # OrderedLock named after the creating assignment
        assert isinstance(q._cond._lock, OrderedLock)
        assert q._cond._lock.name == "batching._cond"

    def test_unmarked_test_gets_none(self, lock_order_guard):
        assert lock_order_guard is None
