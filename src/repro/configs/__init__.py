"""Architecture registry: the 10 assigned architectures + the paper's own
DKPCA workload config."""

from importlib import import_module

from .base import ArchConfig
from .shapes import SHAPES, ShapeSpec, applicable, concrete_train_batch, \
    decode_specs, train_batch_specs

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "llama3-405b": "llama3_405b",
    "qwen3-32b": "qwen3_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mixtral-8x22b": "mixtral_8x22b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "zamba2-1.2b": "zamba2_1_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    mod = import_module(f".{_MODULES[name]}", __package__)
    return mod.smoke_config() if smoke else mod.config()


__all__ = ["ARCH_NAMES", "ArchConfig", "SHAPES", "ShapeSpec", "applicable",
           "concrete_train_batch", "decode_specs", "get_config",
           "train_batch_specs"]
