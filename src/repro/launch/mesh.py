"""Production mesh definition (per assignment spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).

``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer jax; on older releases every mesh axis is implicitly
Auto, so omitting the kwarg is equivalent.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _axis_kw(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # older jax: all axes are Auto by default
    def _axis_kw(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples / elastic restore)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))
