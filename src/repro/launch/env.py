"""Process environment/config layer — applied BEFORE jax imports.

jax freezes its backend the first time it initializes, and XLA reads its
flags from the environment at that moment: platform selection, x64 mode,
host device count, and the GPU latency-hiding/async-collective flags are
all silently ignored if set after ``import jax`` has run its course. This
module owns that footgun in ONE place (the bayespec ``config.py`` pattern,
SNIPPETS.md §1): launchers and benchmarks call ``apply`` (or
``apply_from_environ``) at the very top of the file, before any import
that pulls jax in.

This module is deliberately stdlib-only — importing it never initializes
any backend.

Environment variables understood by ``apply_from_environ`` (all optional;
explicit ``EnvConfig`` fields win over them):

  * ``REPRO_PLATFORM``      -> ``JAX_PLATFORMS`` (cpu/gpu/tpu)
  * ``REPRO_X64``           -> ``JAX_ENABLE_X64`` (1/true/0/false)
  * ``REPRO_HOST_DEVICES``  -> ``--xla_force_host_platform_device_count``
  * ``REPRO_TILE_TABLE``    -> consumed by ``repro.kernels.autotune``
    directly; listed here because this layer is where deployments set it.
"""

from __future__ import annotations

import dataclasses
import os
import sys
import warnings
from typing import Dict, Optional, Tuple

# GPU flags from the bayespec exemplar: overlap collective communication
# with compute (latency-hiding scheduler + async collectives). Harmless
# no-ops for XLA:CPU/TPU — they are only read by the GPU backend.
GPU_XLA_FLAGS: Tuple[str, ...] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
)

_TRUTHY = ("1", "true", "yes", "on")


@dataclasses.dataclass
class EnvConfig:
    """What to pin before backend init; None fields are left untouched."""

    platform: Optional[str] = None       # "cpu" | "gpu" | "tpu"
    enable_x64: Optional[bool] = None    # float64/int64 as default widths
    host_devices: Optional[int] = None   # fake host devices (shard tests);
    #                                      0/None = leave XLA_FLAGS alone
    gpu_flags: bool = False              # append GPU_XLA_FLAGS
    preallocate_gpu: Optional[bool] = None  # XLA client memory strategy
    extra_xla_flags: Tuple[str, ...] = ()


def _merge_xla_flags(existing: str, new_flags: Tuple[str, ...]) -> str:
    """Append flags not already present (by --flag-name prefix), so a
    user's explicit setting always wins over ours."""
    parts = existing.split()
    have = {p.split("=", 1)[0] for p in parts}
    for flag in new_flags:
        if flag.split("=", 1)[0] not in have:
            parts.append(flag)
    return " ".join(parts)


def apply(cfg: EnvConfig) -> Dict[str, str]:
    """Pin ``cfg`` into ``os.environ``; returns the variables written.

    Warns (rather than raises) when jax is already imported — the
    settings may or may not stick at that point, and the caller should
    move the ``apply`` above its jax-importing imports.
    """
    if "jax" in sys.modules:
        warnings.warn(
            "repro.launch.env.apply() called AFTER jax was imported - "
            "backend/platform/x64/XLA flags may be ignored. Call it at "
            "the top of the launcher, before jax-importing imports.",
            RuntimeWarning, stacklevel=2)
    written: Dict[str, str] = {}
    if cfg.platform is not None:
        written["JAX_PLATFORMS"] = cfg.platform
    if cfg.enable_x64 is not None:
        written["JAX_ENABLE_X64"] = "1" if cfg.enable_x64 else "0"
    if cfg.preallocate_gpu is not None:
        written["XLA_PYTHON_CLIENT_PREALLOCATE"] = \
            "true" if cfg.preallocate_gpu else "false"
    xla_new: Tuple[str, ...] = ()
    if cfg.host_devices:
        xla_new += (
            f"--xla_force_host_platform_device_count={cfg.host_devices}",)
    if cfg.gpu_flags:
        xla_new += GPU_XLA_FLAGS
    xla_new += tuple(cfg.extra_xla_flags)
    if xla_new:
        written["XLA_FLAGS"] = _merge_xla_flags(
            os.environ.get("XLA_FLAGS", ""), xla_new)
    os.environ.update(written)
    return written


def apply_from_environ() -> Dict[str, str]:
    """``apply`` driven purely by ``REPRO_*`` variables — the one-liner
    for launchers whose argparse runs after jax-importing imports."""
    cfg = EnvConfig()
    if os.environ.get("REPRO_PLATFORM"):
        cfg.platform = os.environ["REPRO_PLATFORM"]
    if "REPRO_X64" in os.environ:
        cfg.enable_x64 = os.environ["REPRO_X64"].lower() in _TRUTHY
    if os.environ.get("REPRO_HOST_DEVICES"):
        cfg.host_devices = int(os.environ["REPRO_HOST_DEVICES"])
    return apply(cfg)


__all__ = ["EnvConfig", "GPU_XLA_FLAGS", "apply", "apply_from_environ"]
