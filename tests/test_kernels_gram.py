"""Per-kernel allclose tests: Pallas kernels (interpret mode on CPU) vs.
their pure-jnp oracles, swept across shapes and dtypes."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import KernelSpec
from repro.kernels import (admm_local_update_op, admm_local_update_reference,
                           center_op, center_reference, gram_op,
                           gram_reference)


def _rand(shape, seed=0, dtype=np.float32):
    return np.random.default_rng(seed).normal(size=shape).astype(dtype)


SHAPES = [(8, 4), (17, 9), (64, 64), (100, 37), (130, 128), (256, 300)]


class TestGramKernel:
    @pytest.mark.parametrize("n,m", SHAPES)
    @pytest.mark.parametrize("kind", ["rbf", "linear", "poly"])
    def test_allclose_square(self, n, m, kind):
        spec = KernelSpec(kind=kind, gamma=0.3, degree=2, scale=0.1)
        x = jnp.asarray(_rand((n, m), seed=n + m))
        got = np.asarray(gram_op(spec, x, interpret=True))
        want = np.asarray(gram_reference(spec, x))
        # fp32 accumulation order differs between the tiled kernel and the
        # one-shot oracle; at m >= 300 the exp epilogue amplifies the
        # difference to ~1.5e-4. Keep the tight gate below that.
        tol = 2e-4 if m >= 300 else 2e-5
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    @pytest.mark.parametrize("nk", [(8, 120), (120, 8), (77, 33)])
    def test_allclose_rect(self, nk):
        n, k = nk
        spec = KernelSpec(kind="rbf", gamma=0.7)
        x = jnp.asarray(_rand((n, 24), seed=1))
        y = jnp.asarray(_rand((k, 24), seed=2))
        got = np.asarray(gram_op(spec, x, y, interpret=True))
        want = np.asarray(gram_reference(spec, x, y))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        spec = KernelSpec(kind="rbf", gamma=0.5)
        x = jnp.asarray(_rand((40, 16), seed=3)).astype(dtype)
        got = np.asarray(gram_op(spec, x, interpret=True))
        want = np.asarray(gram_reference(spec, x.astype(jnp.float32)))
        tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_custom_blocks(self):
        spec = KernelSpec(kind="rbf", gamma=0.2)
        x = jnp.asarray(_rand((96, 200), seed=4))
        got = np.asarray(gram_op(spec, x, block_n=32, block_k=64,
                                 block_m=128, interpret=True))
        want = np.asarray(gram_reference(spec, x))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 50), m=st.integers(1, 40), seed=st.integers(0, 9))
    def test_property_matches_oracle(self, n, m, seed):
        spec = KernelSpec(kind="rbf", gamma=0.4)
        x = jnp.asarray(_rand((n, m), seed=seed))
        got = np.asarray(gram_op(spec, x, interpret=True))
        want = np.asarray(gram_reference(spec, x))
        np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


class TestCenteringKernel:
    @pytest.mark.parametrize("n,m", [(8, 8), (50, 70), (256, 256), (100, 300)])
    def test_allclose(self, n, m):
        k = jnp.asarray(_rand((n, m), seed=n))
        got = np.asarray(center_op(k, interpret=True))
        want = np.asarray(center_reference(k))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_composes_with_gram(self):
        spec = KernelSpec(kind="rbf", gamma=0.3)
        x = jnp.asarray(_rand((60, 20), seed=7))
        got = np.asarray(center_op(gram_op(spec, x, interpret=True),
                                   interpret=True))
        want = np.asarray(center_reference(gram_reference(spec, x)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestAdmmStepKernel:
    @pytest.mark.parametrize("j,n,s", [(1, 16, 3), (4, 32, 5), (2, 128, 5),
                                       (1, 256, 9)])
    def test_allclose(self, j, n, s):
        rng = np.random.default_rng(n + s)
        v = rng.normal(size=(j, n, n)).astype(np.float32)
        invd = rng.uniform(0.1, 1.0, size=(j, n, 1)).astype(np.float32)
        k = rng.normal(size=(j, n, n)).astype(np.float32)
        b = rng.normal(size=(j, n, s)).astype(np.float32)
        g = rng.normal(size=(j, n, s)).astype(np.float32)
        rho = rng.uniform(0.0, 2.0, size=(j, 1, s)).astype(np.float32)
        got_a, got_b = admm_local_update_op(*(jnp.asarray(t) for t in
                                              (v, invd, k, b, g, rho)),
                                            interpret=True)
        want_a, want_b = admm_local_update_reference(
            *(jnp.asarray(t) for t in (v, invd, k, b, g, rho)))
        np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                                   rtol=2e-4, atol=2e-4)

    def test_vmem_guard(self):
        with pytest.raises(ValueError, match="VMEM"):
            z = jnp.zeros((1, 2048, 2048))
            admm_local_update_op(z, jnp.zeros((1, 2048, 1)), z,
                                 jnp.zeros((1, 2048, 3)),
                                 jnp.zeros((1, 2048, 3)),
                                 jnp.zeros((1, 1, 3)), interpret=True)

    def test_matches_admm_iteration_algebra(self):
        """The fused kernel must reproduce the alpha/B update inside
        repro.core.admm.admm_iteration (same rhs/solve/eta algebra)."""
        from repro.core import KernelSpec as KS, build_setup
        from repro.core.admm import _slot_rho, admm_iteration
        from repro.core.topology import ring
        from repro.data import node_dataset
        import jax

        nodes, _ = node_dataset(5, 16, 8, seed=0)
        graph = ring(5, 1)
        setup = build_setup(jnp.asarray(nodes), graph, KS("rbf", 0.5))
        alpha = jax.random.normal(jax.random.PRNGKey(0), (5, 16))
        b = jnp.zeros((5, 16, setup.n_slots))
        # run one reference iteration to obtain g, then replay alpha/B update
        a_ref, b_ref, g, _ = admm_iteration(setup, alpha, b, 100.0, 10.0)
        rho_slots = _slot_rho(setup, 100.0, 10.0)
        rho_bar = jnp.sum(rho_slots, axis=1)
        lam = setup.lam
        den = rho_bar[:, None] * lam - 2.0 * lam * lam
        inv = jnp.where(lam > 1e-5 * lam[:, -1:],
                        1.0 / jnp.maximum(den, 1e-6 * lam), 0.0)
        got_a, got_b = admm_local_update_op(
            setup.vec, inv[..., None], setup.k,
            b * setup.mask[:, None, :], g, rho_slots[:, None, :],
            interpret=True)
        np.testing.assert_allclose(np.asarray(got_a[..., 0]),
                                   np.asarray(a_ref), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got_b * setup.mask[:, None, :]),
                                   np.asarray(b_ref), rtol=2e-4, atol=2e-4)
