"""Batched decode engine with fixed-slot continuous batching.

A fixed number of slots share one KV cache; finished sequences are replaced
from the queue without recompiling (cache_len is per-engine uniform for the
compiled step — slot-level positions are tracked with masks). Greedy or
temperature sampling. The prompt queue and wave packing come from the
shared batching layer (``repro.serve.batching``): prompts flow through a
``RequestQueue`` and are packed per wave with ``left_pad_pack``, the same
machinery the kPCA projection engine builds its async pipeline on."""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from .batching import RequestQueue, left_pad_pack


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: int = -1              # -1: never stop early
    seed: int = 0


class DecodeEngine:
    def __init__(self, model, params, batch_slots: int, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.cfg = cfg
        self._step = jax.jit(
            lambda p, c, t, l: model.decode_step(p, c, t, l))
        self._rng = np.random.default_rng(cfg.seed)

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.cfg.temperature <= 0:
            return logits.argmax(-1)
        z = logits / self.cfg.temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(row), p=row) for row in p])

    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Serve all prompts with continuous slot reuse; returns generated
        token lists (prompt excluded)."""
        cfg = self.cfg
        queue = RequestQueue()
        futs = [queue.put(p, n=len(p))[0] for p in prompts]

        # uniform-length prefill per wave (pad prompts to the same length)
        while len(queue):
            wave = queue.take(self.slots)
            toks, plen = left_pad_pack([e.payload for e in wave], self.slots)
            results = [[] for _ in wave]
            cache = self.model.init_cache(self.slots, cfg.max_len)
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(toks),
                                       jnp.asarray(0, jnp.int32))
            cache_len = plen
            nxt = self._sample(np.asarray(logits, np.float32))
            done = [False] * len(wave)
            for t in range(cfg.max_new_tokens):
                for i in range(len(wave)):
                    if not done[i]:
                        results[i].append(int(nxt[i]))
                        if int(nxt[i]) == cfg.eos_id:
                            done[i] = True
                if all(done) or cache_len + 1 >= cfg.max_len:
                    break
                logits, cache = self._step(
                    self.params, cache,
                    jnp.asarray(nxt[:, None].astype(np.int32)),
                    jnp.asarray(cache_len, jnp.int32))
                cache_len += 1
                nxt = self._sample(np.asarray(logits, np.float32))
            for e, out in zip(wave, results):
                e.future.set_result(out)
        return [f.result() for f in futs]
