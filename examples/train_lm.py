"""End-to-end driver: train a ~100M-param llama-family model on the
synthetic Markov token stream for a few hundred steps, with checkpointing,
NaN-guard, straggler monitoring, and (optionally) the DKPCA activation
probe. Loss drops well below log(V) as the model learns the bigram
structure.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 50 --tiny   # quick CI

Restart-resume: re-running with the same --ckpt-dir continues where the
previous run stopped (kill it mid-run and re-launch to see)."""

import argparse
import logging

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig, cosine_with_warmup
from repro.train import TrainConfig, train


def model_100m() -> ArchConfig:
    # ~100M params: 12L x 768 with llama-style GQA + SwiGLU
    return ArchConfig(
        name="llama-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=8192, head_dim=64,
        tie_embeddings=True, remat="none", param_dtype="float32",
        compute_dtype="float32")


def model_tiny() -> ArchConfig:
    return ArchConfig(
        name="llama-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=1024, head_dim=32,
        tie_embeddings=True, remat="none", param_dtype="float32",
        compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = model_tiny() if args.tiny else model_100m()
    n = cfg.n_params()
    print(f"arch {cfg.name}: {n / 1e6:.1f}M params")
    model = build_model(cfg)
    data = TokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                       seed=0)
    opt = AdamWConfig(lr=1e-3, schedule=cosine_with_warmup(
        max(args.steps // 20, 1), args.steps))
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=100, log_every=20)
    state, hist = train(model, opt, data, tcfg)
    import numpy as np
    first = float(np.mean(hist["loss"][:5]))
    last = float(np.mean(hist["loss"][-5:]))
    print(f"loss: {first:.3f} -> {last:.3f}  (log V = "
          f"{np.log(cfg.vocab):.3f}); straggler flags: "
          f"{hist['straggler_flags']}")
    assert last < first


if __name__ == "__main__":
    main()
