"""Paper-table benchmarks (Figs 3-5 + runtime §6.2).

Each function mirrors one figure of the paper on the synthetic
digits-manifold dataset (MNIST regime: M=784, 4 classes) and returns CSV
rows ``name,us_per_call,derived``."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (KernelSpec, build_setup, central_kpca, local_kpca,
                        neighborhood_kpca, run_admm, similarity)
from repro.core.topology import ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf")


def _mean_sim(alphas, nodes, pooled, alpha_gt, gamma):
    j = nodes.shape[0]
    return float(np.mean([
        float(similarity(alphas[i], jnp.asarray(nodes[i]), alpha_gt,
                         jnp.asarray(pooled), SPEC, gamma=gamma))
        for i in range(j)]))


def _solve(nodes, pooled, hops=2, n_iters=30):
    graph = ring(nodes.shape[0], hops=hops)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1,
                                  gamma=setup.gamma)
    t0 = time.perf_counter()
    res = run_admm(setup, n_iters=n_iters)
    jax.block_until_ready(res.alpha)
    dt = time.perf_counter() - t0
    sim = _mean_sim(res.alpha, nodes, pooled, alpha_gt[:, 0], setup.gamma)
    return res, sim, dt, setup, alpha_gt[:, 0]


def bench_similarity_vs_nodes(m: int = 784):
    """Fig 3: 100 samples/node, |Omega|=4, J = 10..80."""
    rows = []
    for j in (10, 20, 40, 80):
        nodes, pooled = node_dataset(j, 100, m=m, seed=j)
        _, sim, dt, _, _ = _solve(nodes, pooled)
        rows.append((f"fig3/similarity_J{j}", dt * 1e6 / 30,
                     f"sim={sim:.4f}"))
    return rows


def bench_similarity_vs_samples(m: int = 784):
    """Fig 4: 20-node network, |Omega|=4, N_j = 40..300, vs local baseline."""
    rows = []
    for n in (40, 100, 200, 300):
        nodes, pooled = node_dataset(20, n, m=m, seed=n)
        _, sim, dt, setup, ag = _solve(nodes, pooled)
        loc = local_kpca(jnp.asarray(nodes), SPEC, gamma=setup.gamma)
        lsim = _mean_sim(loc[..., 0], nodes, pooled, ag, setup.gamma)
        rows.append((f"fig4/similarity_N{n}", dt * 1e6 / 30,
                     f"sim={sim:.4f};local={lsim:.4f}"))
    return rows


def bench_similarity_vs_neighbors(m: int = 784):
    """Fig 5: 20 nodes x 100 samples; |Omega| = 2..12; per-iteration curve +
    the gather-all-neighbor-data baseline (alpha_Nei)."""
    rows = []
    nodes, pooled = node_dataset(20, 100, m=m, seed=5)
    for omega in (2, 4, 8, 12):
        graph = ring(20, hops=omega // 2)
        setup = build_setup(jnp.asarray(nodes), graph, SPEC)
        alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1,
                                      gamma=setup.gamma)
        t0 = time.perf_counter()
        # sparse rings (|Omega|=2) mix information slowly (ring diameter
        # J/2 hops): run 60 iterations and report the trajectory
        res = run_admm(setup, n_iters=60)
        jax.block_until_ready(res.alpha)
        dt = time.perf_counter() - t0
        sims = [
            _mean_sim(res.alpha_hist[t], nodes, pooled, alpha_gt[:, 0],
                      setup.gamma) for t in (3, 7, 29, 59)]
        nb = neighborhood_kpca(jnp.asarray(nodes), graph, SPEC,
                               gamma=setup.gamma)
        nsim = float(np.mean([
            float(similarity(a[:, 0], xc, alpha_gt[:, 0],
                             jnp.asarray(pooled), SPEC, gamma=setup.gamma))
            for a, xc in nb]))
        rows.append((f"fig5/omega{omega}", dt * 1e6 / 60,
                     f"sim@4={sims[0]:.3f};@8={sims[1]:.3f};"
                     f"@30={sims[2]:.3f};@60={sims[3]:.3f};nei={nsim:.3f}"))
    return rows


def bench_runtime_vs_central(m: int = 784):
    """§6.2 runtime: per-node ADMM cost vs central kPCA (O(N^2 J^2) gram +
    O(N^3 J^3) eig) as the network grows. Central includes gathering all
    data; decentralized is per-iteration analytic updates."""
    rows = []
    for j in (10, 20, 40):
        nodes, pooled = node_dataset(j, 100, m=m, seed=j + 1)
        # central
        t0 = time.perf_counter()
        alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1)
        jax.block_until_ready(alpha_gt)
        t_central = time.perf_counter() - t0
        # decentralized (30 iterations, includes setup)
        graph = ring(j, hops=2)
        t0 = time.perf_counter()
        setup = build_setup(jnp.asarray(nodes), graph, SPEC)
        res = run_admm(setup, n_iters=30)
        jax.block_until_ready(res.alpha)
        t_dkpca = time.perf_counter() - t0
        rows.append((f"runtime/J{j}", t_dkpca * 1e6,
                     f"central_us={t_central * 1e6:.0f};"
                     f"speedup={t_central / t_dkpca:.2f}x"))
    return rows
