"""Jitted wrapper for the fused ADMM local update kernel."""

from __future__ import annotations

from typing import Optional

from .._util import _on_tpu
from .admm_step import admm_local_update


def admm_local_update_op(v, inv_den, k, b, g, rho_slots,
                         interpret: Optional[bool] = None):
    if interpret is None:
        interpret = not _on_tpu()
    n = v.shape[-1]
    if n > 1024:
        raise ValueError(
            f"admm_step kernel keeps V and K (2 x {n}^2 fp32) resident in "
            "VMEM; N_j > 1024 exceeds the 16 MB budget — fall back to the "
            "jnp reference (repro.kernels.admm_step.ref)")
    return admm_local_update(v, inv_den, k, b, g, rho_slots,
                             interpret=interpret)
