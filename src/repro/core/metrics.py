"""Similarity metric from the paper's §6.1.

Similarity(w_j, w_gt) = w_j^T w_gt / (||w_j|| ||w_gt||)
  = alpha_j^T K(X_j, X) alpha_gt / sqrt((alpha_j^T K_j alpha_j)(alpha_gt^T K alpha_gt))

computed entirely in the dual. Eigenvector sign is arbitrary, so we report
|similarity| (the paper's plots are all positive).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .kernels_math import KernelSpec, center_gram, center_gram_global, gram


def similarity(alpha_j: jnp.ndarray, x_j: jnp.ndarray,
               alpha_gt: jnp.ndarray, x_gt: jnp.ndarray,
               spec: KernelSpec, center: bool = True,
               gamma: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Cosine similarity of w_j = phi(X_j) alpha_j and w = phi(X) alpha_gt."""
    k_j = gram(spec, x_j, gamma=gamma)
    k_g = gram(spec, x_gt, gamma=gamma)
    k_cross = gram(spec, x_j, x_gt, gamma=gamma)
    if center:
        # Center every block consistently w.r.t. the global dataset so that
        # all vectors live in the same (centered) feature space.
        k_cross = center_gram_global(k_cross, k_cross, k_g, k_g)
        k_j = center_gram(k_j)
        k_g = center_gram(k_g)
    num = alpha_j @ k_cross @ alpha_gt
    den = jnp.sqrt(jnp.maximum((alpha_j @ k_j @ alpha_j)
                               * (alpha_gt @ k_g @ alpha_gt), 1e-24))
    return jnp.clip(jnp.abs(num) / den, 0.0, 1.0)


def pairwise_direction_similarity(alpha_a, x_a, alpha_b, x_b, spec,
                                  gamma=None, center: bool = True):
    """Similarity between two dual-represented directions on different data."""
    return similarity(alpha_a, x_a, alpha_b, x_b, spec, center=center,
                      gamma=gamma)


def subspace_alignment(alphas_j, x_j, alphas_gt, x_gt, spec, gamma=None):
    """Mean principal angle cosine between two k-dim component subspaces
    (used by the beyond-paper top-k deflation). alphas: (N, k)."""
    k_cross = gram(spec, x_j, x_gt, gamma=gamma)
    k_j = gram(spec, x_j, gamma=gamma)
    k_g = gram(spec, x_gt, gamma=gamma)
    # Gram-normalize each side, then SVD of the cross-correlation.
    aj = _orthonormalize(alphas_j, k_j)
    ag = _orthonormalize(alphas_gt, k_g)
    c = aj.T @ k_cross @ ag
    s = jnp.linalg.svd(c, compute_uv=False)
    return jnp.mean(jnp.clip(s, 0.0, 1.0))


def _orthonormalize(alpha, k):
    """Make columns of phi(X) alpha orthonormal: alpha^T K alpha = I."""
    m = alpha.T @ k @ alpha
    lam, v = jnp.linalg.eigh(m)
    lam = jnp.maximum(lam, 1e-12)
    return alpha @ v / jnp.sqrt(lam)[None, :]
