"""Jitted public wrapper around the projection Pallas kernel.

Handles padding to block multiples (features zero-pad exactly; padded
support rows carry zero coefficients AND a zero entry in the fused ones-
column, so they contribute nothing to scores or row-means; padded query
rows are sliced off), sq-norm/self-kernel precomputation, component-axis
padding to the 128-lane boundary, gamma resolution and backend dispatch
(interpret=True everywhere except real TPU)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.kernels_math import KernelSpec, resolve_gamma, _self_k
from ..gram.ops import _on_tpu, _pad_to, _round_up
from .project import project_tiles


def project_op(spec: KernelSpec, x_query: jax.Array, x_support: jax.Array,
               coefs: jax.Array,
               row_mean_coef: Optional[jax.Array] = None,
               bias: Optional[jax.Array] = None,
               gamma: Optional[jax.Array] = None,
               block_q: int = 128, block_l: int = 128, block_m: int = 512,
               interpret: Optional[bool] = None) -> jax.Array:
    """scores = K(x_query, x_support) @ coefs + rowmean(K) * c + b, fused.

    x_query (B, M); x_support (L, M); coefs (L, C); row_mean_coef/bias (C,)
    (default zero: raw uncentered projection). Returns (B, C) float32.
    Matches ``repro.kernels.project.ref.project_reference`` (tested across
    shapes in tests/test_oos_projection.py).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b_n, m = x_query.shape
    l, c = coefs.shape
    assert x_support.shape == (l, m), (x_query.shape, x_support.shape,
                                       coefs.shape)
    if row_mean_coef is None:
        row_mean_coef = jnp.zeros((c,), jnp.float32)
    if bias is None:
        bias = jnp.zeros((c,), jnp.float32)

    if spec.kind == "rbf":
        g = resolve_gamma(spec, x_support) if gamma is None \
            else jnp.asarray(gamma)
        sq = jnp.sum(x_query.astype(jnp.float32) ** 2, axis=-1)
        ss = jnp.sum(x_support.astype(jnp.float32) ** 2, axis=-1)
    else:
        g = jnp.zeros((), jnp.float32)
        sq = _self_k(spec, x_query.astype(jnp.float32))
        ss = _self_k(spec, x_support.astype(jnp.float32))

    # adapt block sizes for small problems (interpret/test shapes)
    bq = min(block_q, _round_up(b_n, 8))
    bl = min(block_l, _round_up(l, 8))
    bm = min(block_m, _round_up(m, 128))
    cp = _round_up(c + 1, 128)

    xq = _pad_to(_pad_to(x_query, bm, 1), bq, 0)
    xs = _pad_to(_pad_to(x_support, bm, 1), bl, 0)
    sqp = _pad_to(sq, bq, 0)
    ssp = _pad_to(ss, bl, 0)
    # A extended with the row-sum ones-column at index c (zero on padded
    # support rows), then padded to (L_pad, CP).
    ones = jnp.ones((l, 1), jnp.float32)
    a_ext = jnp.concatenate([coefs.astype(jnp.float32), ones], axis=1)
    a_ext = _pad_to(_pad_to(a_ext, cp, 1), bl, 0)
    c_ext = _pad_to(row_mean_coef.astype(jnp.float32), cp, 0)
    b_ext = _pad_to(bias.astype(jnp.float32), cp, 0)

    out = project_tiles(
        xq, xs, a_ext, sqp, ssp,
        jnp.reshape(g, (1,)).astype(jnp.float32),
        jnp.full((1,), 1.0 / l, jnp.float32), c_ext, b_ext,
        kind=spec.kind, degree=spec.degree, coef=spec.coef, scale=spec.scale,
        normalize=spec.normalize, block_q=bq, block_l=bl, block_m=bm,
        sum_col=c, interpret=interpret)
    return out[:b_n, :c]
