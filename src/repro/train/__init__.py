from .loop import StragglerMonitor, TrainConfig, build_train_step, train
from .probes import activation_probe

__all__ = ["StragglerMonitor", "TrainConfig", "activation_probe",
           "build_train_step", "train"]
