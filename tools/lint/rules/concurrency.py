"""Concurrency rules for the threaded serving stack.

These rules turn the lock discipline of ``repro.serve`` into machine-checked
invariants:

  * ``guarded-by`` — an attribute annotated ``# guarded-by: <lock>`` on its
    ``__init__`` assignment may only be touched inside a matching
    ``with self.<lock>:`` scope (or a method annotated
    ``# holds-lock: <lock>``).
  * ``blocking-in-lock`` — no host/device synchronization
    (``block_until_ready``, ``np.asarray``/``jax.device_get``, ``.item()``,
    ``float(...)`` on computed values) inside a ``with <lock>:`` body; a
    device sync under a hot lock serializes every other thread behind the
    accelerator.
  * ``thread-join`` — every ``threading.Thread`` must have a reachable
    ``join`` in its module (or escape to the caller via ``return``).
  * ``lock-order`` — two locks nested in opposite orders anywhere in one
    file (the static AB/BA smell; the runtime companion is
    ``tests/helpers/lockcheck.py``).
  * ``bare-acquire`` — ``lock.acquire()`` outside a ``with`` (un-released
    on any exception path).

Scope discipline: a nested ``def`` inside a ``with lock:`` body is NOT
considered to run under the lock (it usually escapes to another thread);
a ``lambda`` IS (the dominant pattern is ``cond.wait_for(lambda: ...)``,
which the condition invokes while holding its lock).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..engine import FileContext, Finding, Rule, register

_LOCKISH = ("lock", "cond", "mutex", "sem")


def lock_name(expr: ast.AST) -> Optional[str]:
    """The short lock name of a with-item context expression:
    ``self._lock`` -> ``_lock``, ``lk`` -> ``lk``, ``self._queue._cond`` ->
    ``_cond``; None for anything that is not a name/attribute chain."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def is_lockish(name: Optional[str]) -> bool:
    return name is not None and any(s in name.lower() for s in _LOCKISH)


def _with_locks(node: ast.With) -> List[str]:
    """Lock-ish names entered by one ``with`` statement."""
    out = []
    for item in node.items:
        name = lock_name(item.context_expr)
        if is_lockish(name):
            out.append(name)
    return out


# ---------------------------------------------------------------------------


@register
class GuardedByRule(Rule):
    name = "guarded-by"
    summary = ("attributes annotated '# guarded-by: <lock>' may only be "
               "accessed under 'with self.<lock>:'")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            guards = {attr: lk for (cname, attr), lk in
                      ctx.guarded_by.items() if cname == cls.name}
            if not guards:
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name == "__init__":
                    continue  # construction precedes sharing
                held: Set[str] = set()
                lk = ctx.holds_lock.get(meth.lineno)
                if lk:
                    held.add(lk)
                yield from self._scan(ctx, meth.body, guards, held)

    def _scan(self, ctx, stmts, guards, held) -> Iterator[Finding]:
        for stmt in stmts:
            yield from self._scan_node(ctx, stmt, guards, held)

    def _scan_node(self, ctx, node, guards, held) -> Iterator[Finding]:
        if isinstance(node, ast.With):
            inner = held | set(_with_locks(node))
            for item in node.items:
                yield from self._scan_node(ctx, item.context_expr,
                                           guards, held)
            yield from self._scan(ctx, node.body, guards, inner)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def may run on another thread: lock NOT held inside
            lk = ctx.holds_lock.get(node.lineno)
            inner = {lk} if lk else set()
            yield from self._scan(ctx, node.body, guards, inner)
            return
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self" and node.attr in guards
                and guards[node.attr] not in held):
            yield self.finding(
                ctx, node,
                f"'self.{node.attr}' is guarded by "
                f"'{guards[node.attr]}' but accessed without "
                f"'with self.{guards[node.attr]}:'")
            return
        for child in ast.iter_child_nodes(node):
            yield from self._scan_node(ctx, child, guards, held)


# ---------------------------------------------------------------------------

_BLOCKING_METHODS = {"block_until_ready", "item"}
_BLOCKING_CALLS = {("np", "asarray"), ("numpy", "asarray"),
                   ("jax", "device_get"), ("jax", "block_until_ready")}


@register
class BlockingInLockRule(Rule):
    name = "blocking-in-lock"
    summary = ("no device synchronization (block_until_ready, np.asarray/"
               "jax.device_get, .item(), float(<computed>)) inside a "
               "'with <lock>:' body")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        yield from self._scan(ctx, ctx.tree.body, held=[])

    def _scan(self, ctx, stmts, held) -> Iterator[Finding]:
        for stmt in stmts:
            yield from self._scan_node(ctx, stmt, held)

    def _scan_node(self, ctx, node, held) -> Iterator[Finding]:
        if isinstance(node, ast.With):
            locks = _with_locks(node)
            for item in node.items:
                yield from self._scan_node(ctx, item.context_expr, held)
            yield from self._scan(ctx, node.body, held + locks)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            lk = ctx.holds_lock.get(node.lineno)
            yield from self._scan(ctx, node.body, [lk] if lk else [])
            return
        if held and isinstance(node, ast.Call):
            why = self._blocking(node)
            if why:
                yield self.finding(
                    ctx, node,
                    f"{why} while holding '{held[-1]}' — move the device "
                    f"sync outside the critical section")
        for child in ast.iter_child_nodes(node):
            yield from self._scan_node(ctx, child, held)

    @staticmethod
    def _blocking(call: ast.Call) -> Optional[str]:
        fn = call.func
        if isinstance(fn, ast.Attribute):
            if fn.attr in _BLOCKING_METHODS and not call.args:
                return f"'.{fn.attr}()' blocks on the device"
            if isinstance(fn.value, ast.Name) and \
                    (fn.value.id, fn.attr) in _BLOCKING_CALLS:
                return (f"'{fn.value.id}.{fn.attr}(...)' device-transfers "
                        f"(and synchronizes)")
        if isinstance(fn, ast.Name) and fn.id == "float" and call.args:
            arg = call.args[0]
            if isinstance(arg, (ast.Call, ast.Attribute, ast.Subscript)):
                return "'float(...)' on a computed value synchronizes"
        return None


# ---------------------------------------------------------------------------


@register
class ThreadJoinRule(Rule):
    name = "thread-join"
    summary = ("every threading.Thread needs a reachable .join() in its "
               "module (or must escape via return)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        creations = []           # (node, kind, name) kind in name/attr/None
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and self._is_thread_ctor(node.func):
                creations.append((node,) + self._binding(node))
        if not creations:
            return
        _, joined_attrs = self._joined(ctx.tree)
        for node, kind, name in creations:
            # name bindings are local: search the enclosing function only
            # (a join on a same-named variable elsewhere proves nothing);
            # self-attribute bindings are object-lifetime: search the file.
            scope = self._enclosing_scope(node, ctx.tree)
            joined_names, _ = self._joined(scope)
            if kind == "name" and (name in joined_names
                                   or name in self._returned_names(scope)):
                continue
            if kind == "attr" and name in joined_attrs:
                continue
            if kind == "return":
                continue
            target = f"'{name}'" if name else "an unbound thread"
            yield self.finding(
                ctx, node,
                f"threading.Thread bound to {target} is never joined in "
                f"this module — a leaked thread outlives the test/request "
                f"that started it")

    @staticmethod
    def _enclosing_scope(node: ast.AST, tree: ast.Module) -> ast.AST:
        while hasattr(node, "parent"):
            node = node.parent
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return node
        return tree

    @staticmethod
    def _is_thread_ctor(fn) -> bool:
        if isinstance(fn, ast.Attribute):
            return (fn.attr == "Thread" and isinstance(fn.value, ast.Name)
                    and fn.value.id == "threading")
        return isinstance(fn, ast.Name) and fn.id == "Thread"

    @staticmethod
    def _binding(call: ast.Call) -> Tuple[Optional[str], Optional[str]]:
        """How the Thread object is bound: walk ancestors until a
        statement. Returns (kind, name)."""
        node = call
        while hasattr(node, "parent"):
            parent = node.parent
            if isinstance(parent, ast.Return):
                return "return", None
            if isinstance(parent, ast.Assign):
                for t in parent.targets:
                    if isinstance(t, ast.Name):
                        return "name", t.id
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        return "attr", t.attr
                return None, None
            if isinstance(parent, ast.Call) and \
                    isinstance(parent.func, ast.Attribute) and \
                    parent.func.attr == "append" and \
                    isinstance(parent.func.value, ast.Name):
                return "name", parent.func.value.id   # L.append(Thread())
            if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Module, ast.ClassDef)):
                break
            node = parent
        return None, None

    @staticmethod
    def _joined(tree) -> Tuple[Set[str], Set[str]]:
        """Names/attrs with an ``X.join()`` call, plus loop/comprehension
        aliasing: ``for t in L: t.join()`` marks ``L`` joined."""
        names: Set[str] = set()
        attrs: Set[str] = set()
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "join"):
                continue
            base = node.func.value
            if isinstance(base, ast.Name):
                names.add(base.id)
            elif isinstance(base, ast.Attribute) and \
                    isinstance(base.value, ast.Name) and \
                    base.value.id == "self":
                attrs.add(base.attr)
        # loop aliasing: for v in L / [v.join() for v in L]
        for node in ast.walk(tree):
            iters = []
            if isinstance(node, ast.For) and isinstance(node.target,
                                                        ast.Name):
                iters.append((node.target.id, node.iter))
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if isinstance(gen.target, ast.Name):
                        iters.append((gen.target.id, gen.iter))
            for var, it in iters:
                if var in names and isinstance(it, ast.Name):
                    names.add(it.id)
        return names, attrs

    @staticmethod
    def _returned_names(tree) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Return) and node.value is not None:
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name):
                        out.add(sub.id)
        return out


# ---------------------------------------------------------------------------


@register
class LockOrderRule(Rule):
    name = "lock-order"
    summary = ("two locks nested in opposite orders in one file "
               "(static AB/BA deadlock smell)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        pairs: Dict[Tuple[str, str], ast.AST] = {}
        order: List[Tuple[str, str]] = []
        self._collect(ctx, ctx.tree.body, [], pairs, order)
        for a, b in order:
            if (b, a) in pairs and a != b:
                node = pairs[(a, b)]
                if (a, b) in pairs and \
                        pairs[(a, b)].lineno > pairs[(b, a)].lineno:
                    yield self.finding(
                        ctx, node,
                        f"lock '{b}' is taken inside '{a}' here, but "
                        f"'{a}' inside '{b}' at line "
                        f"{pairs[(b, a)].lineno} — inverse nesting can "
                        f"deadlock under contention")

    def _collect(self, ctx, stmts, held, pairs, order) -> None:
        for stmt in stmts:
            self._collect_node(ctx, stmt, held, pairs, order)

    def _collect_node(self, ctx, node, held, pairs, order) -> None:
        if isinstance(node, ast.With):
            locks = _with_locks(node)
            for outer in held:
                for inner in locks:
                    key = (outer, inner)
                    if key not in pairs:
                        pairs[key] = node
                        order.append(key)
            self._collect(ctx, node.body, held + locks, pairs, order)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            lk = ctx.holds_lock.get(node.lineno)
            self._collect(ctx, node.body, [lk] if lk else [], pairs, order)
            return
        for child in ast.iter_child_nodes(node):
            self._collect_node(ctx, child, held, pairs, order)


# ---------------------------------------------------------------------------


@register
class BareAcquireRule(Rule):
    name = "bare-acquire"
    summary = ("lock.acquire() outside 'with' leaks the lock on any "
               "exception path")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "acquire"
                    and is_lockish(lock_name(node.func.value))):
                name = lock_name(node.func.value)
                yield self.finding(
                    ctx, node,
                    f"bare '{name}.acquire()' — use 'with {name}:' so the "
                    f"lock is released on every exit path")
