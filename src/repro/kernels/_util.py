"""Shared helpers for the Pallas kernel wrappers.

Every ``ops.py`` wrapper (gram, centering, project, admm_step) needs the
same three pieces of plumbing: backend detection for interpret-mode
dispatch, zero-padding operands to block multiples, and rounding block
sizes. They live here so the wrappers do not reach into each other's
modules for private helpers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(a: jax.Array, mult: int, axis: int) -> jax.Array:
    """Zero-pad ``a`` along ``axis`` up to the next multiple of ``mult``."""
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


__all__ = ["_on_tpu", "_pad_to", "_round_up"]
