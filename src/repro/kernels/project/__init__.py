"""Fused out-of-sample kPCA projection kernel (the serving hot path).

One Pallas kernel (``project.project_tiles``) computes
``K(X_query, X_support) @ A`` without ever materializing the (B, L) kernel
block in HBM, exposed through two wrappers:

  * ``project_op(spec, xq, xs, coefs, row_mean_coef, bias)`` -> (B, C)
    centered scores, the single-device path. The centering term
    ``mean_l K(x', x_l) * row_mean_coef`` needs the kernel row-means; these
    are obtained with the *ones-column trick*: A is extended with one extra
    all-ones column (zeroed on padded support rows), so the row-sums of K
    accumulate as just another output column of the same matmul, and an
    in-kernel epilogue folds them into the scores on the last grid step.
  * ``project_partial_op(spec, xq, xs, coefs_ext)`` -> (B, C+1) raw
    per-shard partials for multi-device sharded serving: the same matmul
    with a caller-supplied indicator column and NO epilogue. Shards
    ``psum`` partials and apply the global centering exactly once after
    the reduction (see ``repro.serve.sharded``).

``ref.py`` holds the dense pure-jnp oracles both wrappers are tested
against (tests/test_oos_projection.py, tests/test_sharded_serving.py).
"""

from .ops import project_op, project_partial_op
from .ref import project_partial_reference, project_reference

__all__ = ["project_op", "project_partial_op", "project_partial_reference",
           "project_reference"]
