from .sharding import Rules, default_rules, sharding_for, spec_for

__all__ = ["Rules", "default_rules", "sharding_for", "spec_for"]
