"""Local baselines from the paper's experiments (§6.2).

- (alpha_j)_local : kPCA on the node's own data only (Fig 4 baseline).
- (alpha_j)_Nei   : kPCA on the union of the node's and its neighbors' data
                    (Fig 5 black line), evaluated on the node's own samples.
"""

from __future__ import annotations

import jax.numpy as jnp

from .central import central_kpca
from .kernels_math import KernelSpec
from .topology import Graph


def local_kpca(x_nodes, spec: KernelSpec, n_components: int = 1, gamma=None):
    """x_nodes: (J, N, M) -> per-node local solutions alpha (J, N, C)."""
    import jax
    fn = lambda x: central_kpca(x, spec, n_components, gamma=gamma)[0]
    return jax.vmap(fn)(x_nodes)


def neighborhood_kpca(x_nodes, graph: Graph, spec: KernelSpec,
                      n_components: int = 1, gamma=None):
    """(alpha_j)_Nei: for each node, run kPCA on [X_j, X_{Omega_j}] and keep
    the coefficients of node j's own samples (the direction is then
    phi([X_j X_nbr]) alpha_full, evaluated exactly; for the similarity metric
    we return the full coefficient vector plus the stacked data)."""
    n = x_nodes.shape[1]
    out = []
    for j in range(graph.n_nodes):
        ids = [j] + list(graph.nbr[j])
        xcat = jnp.concatenate([x_nodes[i] for i in ids], axis=0)
        alpha, _, _ = central_kpca(xcat, spec, n_components, gamma=gamma)
        out.append((alpha, xcat))
    return out
