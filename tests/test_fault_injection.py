"""Fault-injection chaos tests: the stack survives what the plan injects.

Four layers, all deterministic (seeded FaultPlan — same seed, same
trajectory, asserted bitwise):

- plan/compile: FaultPlan schema round-trip, seeded generation, link-mask
  compilation semantics (receiver-side censoring, self slot immune).
- solver: FaultyComm censoring, link-loss/straggler degradation, and the
  HEADLINE recovery property — dropping 2 of 12 nodes mid-ADMM re-knits,
  shrinks the state (warm carry, no restart) and still converges to the
  survivor-pooled central solution (>= 0.95 similarity, measured ~0.999).
- SPMD parity: the ring transport under the same link mask matches the
  dense path to fp32 tolerance.
- serving: shard loss under concurrent load resolves EVERY in-flight
  future (success or typed FaultError — zero hangs) with exactly one
  atomic re-balance publish; per-request deadlines; publisher crashes;
  bounded retry-with-backoff. Runs under the lockcheck plugin with
  recovery spans visible in the exported Chrome trace.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers.chaos import (hammer_submit, make_sharded_handle, run_to_end,
                           settle, survivor_similarities)
from repro.core import KernelSpec, build_setup, oos
from repro.core.solver import DenseComm, init_state, load_state, run_chunked
from repro.core.topology import reknit, ring
from repro.data import node_dataset
from repro.faults import (CrashingHandle, DeadlineExceededError, FaultError,
                          FaultPlan, FaultTolerantRun, FaultyComm,
                          InjectedCrashError, LinkFault, NodeDropout,
                          PublisherCrash, ShardLoss, ShardLossInjector,
                          ShardLostError, ShardRebalancer, StragglerStall,
                          link_delay, shrink_state, transient_faults)
from repro.obs import trace
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle
from repro.serve.publisher import BackgroundPublisher

SPEC = KernelSpec(kind="rbf")
WAIT = 30.0


@pytest.fixture(autouse=True)
def _no_global_tracer():
    yield
    trace.disable()


# ---------------------------------------------------------------------------
# fault plans


class TestFaultPlan:
    def test_random_is_deterministic(self):
        kw = dict(n_nodes=12, n_iters=40, n_dropouts=2, n_link_faults=3,
                  n_stragglers=1)
        a = FaultPlan.random(7, **kw)
        b = FaultPlan.random(7, **kw)
        assert a == b
        assert a != FaultPlan.random(8, **kw)

    def test_random_respects_survivor_floor_and_protection(self):
        plan = FaultPlan.random(3, n_nodes=6, n_dropouts=3, n_iters=20,
                                protect=[0, 1])
        dropped = {d.node for d in plan.dropouts}
        assert len(dropped) == 3 and not dropped & {0, 1}
        with pytest.raises(ValueError):
            FaultPlan.random(0, n_nodes=4, n_dropouts=3, n_iters=10)

    def test_dict_round_trip(self):
        plan = FaultPlan(
            seed=5,
            dropouts=(NodeDropout(t=3, node=1),),
            links=(LinkFault(t0=2, t1=6, u=0, v=2, directed=True),),
            stragglers=(StragglerStall(t0=1, t1=4, node=3),),
            shard_losses=(ShardLoss(at_dispatch=2, shard=1),),
            publisher_crashes=(PublisherCrash(at_job=0),))
        assert FaultPlan.from_dict(plan.to_dict()) == plan
        assert FaultPlan.from_dict(json.loads(json.dumps(plan.to_dict()))) \
            == plan

    def test_link_delay_is_censoring_window(self):
        lf = link_delay(4, 3, u=1, v=2)
        assert (lf.t0, lf.t1) == (4, 7) and not lf.directed

    def test_link_mask_censors_receiver_side_slots(self):
        graph = ring(6, hops=1)
        setup = build_setup(
            jnp.asarray(node_dataset(6, 8, m=4, seed=0)[0]), graph, SPEC)
        src = np.asarray(setup.src)
        mask = np.asarray(setup.mask)
        plan = FaultPlan(links=(LinkFault(t0=2, t1=4, u=0, v=1,
                                          directed=True),))
        lm = plan.link_mask(src, mask, 0, 5)
        assert lm.shape == (5, 6, src.shape[1])
        # directed u <- v: only node 0's slot sourcing node 1 is censored,
        # only for t in [2, 4)
        slot01 = np.nonzero(src[0, 1:] == 1)[0] + 1
        assert slot01.size == 1
        assert (lm[2:4, 0, slot01] == 0.0).all()
        assert (lm[:2, 0, slot01] == 1.0).all() and lm[4, 0, slot01] == 1.0
        # the reverse direction (1 <- 0) stays up
        slot10 = np.nonzero(src[1, 1:] == 0)[0] + 1
        assert (lm[:, 1, slot10] == 1.0).all()
        # self slots are never censored
        assert (lm[:, :, 0] == 1.0).all()

    def test_straggler_censors_all_incident_links_both_ways(self):
        graph = ring(6, hops=1)
        setup = build_setup(
            jnp.asarray(node_dataset(6, 8, m=4, seed=0)[0]), graph, SPEC)
        src = np.asarray(setup.src)
        plan = FaultPlan(stragglers=(StragglerStall(t0=1, t1=3, node=2),))
        lm = plan.link_mask(src, np.asarray(setup.mask), 0, 4)
        for u in (1, 3):                        # ring neighbors of node 2
            s_in = np.nonzero(src[u, 1:] == 2)[0] + 1
            assert (lm[1:3, u, s_in] == 0.0).all()
            s_out = np.nonzero(src[2, 1:] == u)[0] + 1
            assert (lm[1:3, 2, s_out] == 0.0).all()
        assert (lm[0] == 1.0).all() and (lm[3] == 1.0).all()


# ---------------------------------------------------------------------------
# transport censoring


class TestFaultyComm:
    def test_exchange_zeroes_masked_slots_and_delegates(self):
        # 3-node complete-ish routing: src[j, s] built by hand
        src = np.array([[0, 1, 2], [1, 2, 0], [2, 0, 1]], np.int32)
        rsl = np.zeros((3, 3), np.int32)
        base = DenseComm(src, rsl)
        cols = jnp.asarray(
            np.arange(3 * 3 * 4, dtype=np.float32).reshape(3, 3, 4))
        mask = jnp.asarray([[1.0, 0.0, 1.0],
                            [1.0, 1.0, 1.0],
                            [1.0, 1.0, 0.0]])
        fc = FaultyComm(base, mask)
        out = np.asarray(fc.exchange(cols))
        ref = np.asarray(base.exchange(cols))
        assert (out[0, 1] == 0.0).all() and (out[2, 2] == 0.0).all()
        keep = np.asarray(mask, bool)
        assert (out[keep] == ref[keep]).all()
        # unmasked view is a pass-through; with_mask rebinds cheaply
        assert (np.asarray(FaultyComm(base).exchange(cols)) == ref).all()
        assert FaultyComm(base).with_mask(mask).mask is mask
        assert fc.ledger is None


# ---------------------------------------------------------------------------
# solver-side recovery (the headline)


def _headline_run(chunk=5, n_iters=40):
    nodes, _ = node_dataset(12, 40, m=24, seed=4)
    plan = FaultPlan(seed=7, dropouts=(NodeDropout(t=15, node=3),
                                       NodeDropout(t=15, node=7)))
    return FaultTolerantRun(nodes, ring(12, hops=2), SPEC, plan,
                            n_iters=n_iters, chunk=chunk)


class TestAdmmDropoutRecovery:
    def test_mid_admm_dropout_recovers_without_refit(self):
        """Drop 2 of 12 nodes at t=15 of 40: the survivors re-knit, carry
        their warm state (no restart — t keeps counting) and converge to
        the survivor-pooled central solution."""
        run = _headline_run()
        chunks = run_to_end(run)
        assert int(run.state.t) == 40          # 40 total, NOT 15 + 40
        assert run.n_reknits == 1
        assert sorted(run.node_ids) == [0, 1, 2, 4, 5, 6, 8, 9, 10, 11]
        assert run.state.alpha.shape == (10, 40)
        kinds = [e.kind for e in run.events]
        assert kinds == ["dropout"]
        sims = survivor_similarities(run, SPEC)
        assert np.mean(sims) >= 0.95, sims
        assert np.min(sims) >= 0.95, sims
        # chunk boundaries: the dropout instant clamps the running chunk
        assert sum(int(c.alpha_hist.shape[0]) for c in chunks) == 40

    def test_same_seed_same_trajectory_bitwise(self):
        a = _headline_run()
        run_to_end(a)
        b = _headline_run()
        run_to_end(b)
        assert (np.asarray(a.state.alpha) == np.asarray(b.state.alpha)).all()
        assert (np.asarray(a.state.b) == np.asarray(b.state.b)).all()

    def test_chunk_size_does_not_change_detection_point(self):
        """Detection happens at the fault instant regardless of chunk size
        (the driver clamps the running chunk), so the trajectory is
        chunk-invariant exactly like the fault-free driver."""
        a = _headline_run(chunk=5, n_iters=20)
        run_to_end(a)
        b = _headline_run(chunk=7, n_iters=20)
        run_to_end(b)
        assert (np.asarray(a.state.alpha) == np.asarray(b.state.alpha)).all()

    def test_recovery_emits_counters_and_spans(self):
        t = trace.enable()
        run = _headline_run(n_iters=16)        # one iter past the dropout
        run_to_end(run)
        names = [e[1] for e in t.events()]
        assert "fault.injected" in names
        assert "fault.recovery" in names

    def test_dropout_outside_run_rejected(self):
        nodes, _ = node_dataset(4, 8, m=4, seed=0)
        plan = FaultPlan(dropouts=(NodeDropout(t=30, node=1),))
        with pytest.raises(ValueError):
            FaultTolerantRun(nodes, ring(4, 1), SPEC, plan, n_iters=10)


class TestLinkFaultDegradation:
    def test_link_loss_window_still_converges(self):
        nodes, _ = node_dataset(12, 40, m=24, seed=4)
        plan = FaultPlan(seed=3,
                         links=(LinkFault(t0=5, t1=12, u=0, v=2),
                                link_delay(8, 4, u=3, v=5)),
                         stragglers=(StragglerStall(t0=10, t1=14, node=6),))
        run = FaultTolerantRun(nodes, ring(12, hops=2), SPEC, plan,
                               n_iters=40, chunk=8)
        run_to_end(run)
        assert run.n_reknits == 0              # degradation, not dropout
        sims = survivor_similarities(run, SPEC)
        assert np.mean(sims) >= 0.95, sims

    def test_censored_run_differs_from_clean_then_matches_itself(self):
        nodes, _ = node_dataset(6, 16, m=8, seed=1)
        plan = FaultPlan(links=(LinkFault(t0=2, t1=9, u=0, v=1),))
        kw = dict(n_iters=12, chunk=4)
        faulty = FaultTolerantRun(nodes, ring(6, 1), SPEC, plan, **kw)
        run_to_end(faulty)
        again = FaultTolerantRun(nodes, ring(6, 1), SPEC, plan, **kw)
        run_to_end(again)
        clean = FaultTolerantRun(nodes, ring(6, 1), SPEC, FaultPlan(), **kw)
        run_to_end(clean)
        a, b, c = (np.asarray(r.state.alpha) for r in (faulty, again, clean))
        assert (a == b).all()                  # deterministic injection
        assert not np.allclose(a, c)           # and it actually bit

    @pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 host devices")
    def test_spmd_link_mask_matches_dense(self):
        """RingComm under the same censoring mask replays the dense
        trajectory (fp32 tolerance) — FaultyComm composes with both
        transports."""
        from repro.core.dkpca import dkpca_distributed
        from repro.faults.plan import ring_slot_tables
        from repro.launch.mesh import make_mesh
        nodes, _ = node_dataset(4, 12, 8, seed=0)
        plan = FaultPlan(links=(LinkFault(t0=3, t1=6, u=0, v=1),
                                LinkFault(t0=5, t1=8, u=2, v=3,
                                          directed=True)))
        n_iters = 12
        setup = build_setup(jnp.asarray(nodes), ring(4, 1), SPEC)
        alpha0 = jax.random.normal(jax.random.PRNGKey(0), (4, 12),
                                   jnp.float32)
        lm_dense = plan.link_mask(np.asarray(setup.src),
                                  np.asarray(setup.mask), 0, n_iters)
        state = init_state(alpha0, setup.n_slots)
        for res in run_chunked(setup, n_iters=n_iters, chunk=4, state=state,
                               link_mask=lm_dense):
            state = res.state
        src_r, mask_r = ring_slot_tables(4, 1)
        lm_ring = plan.link_mask(src_r, mask_r, 0, n_iters)
        out = dkpca_distributed(
            nodes, make_mesh((4,), ("data",)), axis_names=("data",), hops=1,
            spec=SPEC, center="global", n_iters=n_iters, alpha0=alpha0,
            gamma=float(setup.gamma), link_mask=lm_ring)
        np.testing.assert_allclose(np.asarray(state.alpha),
                                   np.asarray(out.alpha), atol=2e-5)


class TestShrinkState:
    def test_surviving_edges_carry_duals_new_edges_start_cold(self):
        nodes, _ = node_dataset(6, 10, m=6, seed=2)
        graph = ring(6, hops=1)
        setup = build_setup(jnp.asarray(nodes), graph, SPEC)
        state = init_state(
            jax.random.normal(jax.random.PRNGKey(1), (6, 10), jnp.float32),
            setup.n_slots)
        for res in run_chunked(setup, n_iters=4, chunk=4, state=state):
            state = res.state
        new_graph, surv = reknit(graph, [2])
        shrunk = shrink_state(state, graph, new_graph, surv)
        assert shrunk.alpha.shape[0] == 5
        assert int(shrunk.t) == int(state.t)
        assert (np.asarray(shrunk.rho) == 0.0).all()
        b_old = np.asarray(state.b)
        b_new = np.asarray(shrunk.b)
        old_ids, _, old_mask = graph.neighbor_array()
        new_ids, _, new_mask = new_graph.neighbor_array()
        surv = [int(v) for v in surv]
        for nj, o in enumerate(surv):
            assert (b_new[nj, :, 0] == b_old[o, :, 0]).all()
            old_slot = {int(old_ids[o, d]): d + 1
                        for d in range(old_ids.shape[1]) if old_mask[o, d]}
            for d in range(new_ids.shape[1]):
                if not new_mask[nj, d]:
                    continue
                l_orig = surv[int(new_ids[nj, d])]
                col = b_new[nj, :, d + 1]
                if l_orig in old_slot:
                    assert (col == b_old[o, :, old_slot[l_orig]]).all()
                else:
                    assert (col == 0.0).all()   # re-knit edge: cold dual

    def test_checkpointed_state_shrinks_identically(self, tmp_path):
        """save_state -> load_state -> shrink == shrink of the live state:
        recovery works the same from a checkpoint as from memory."""
        nodes, _ = node_dataset(6, 10, m=6, seed=2)
        graph = ring(6, hops=1)
        setup = build_setup(jnp.asarray(nodes), graph, SPEC)
        state = None
        for res in run_chunked(setup, n_iters=4, chunk=4, seed=0,
                               ckpt_dir=str(tmp_path)):
            state = res.state
        restored = load_state(str(tmp_path))
        new_graph, surv = reknit(graph, [1, 4])
        live = shrink_state(state, graph, new_graph, surv)
        cold = shrink_state(restored, graph, new_graph, surv)
        for name in ("alpha", "b", "g", "znorm2", "rho"):
            assert (np.asarray(getattr(live, name))
                    == np.asarray(getattr(cold, name))).all(), name
        assert int(live.t) == int(cold.t)


# ---------------------------------------------------------------------------
# serving-side recovery


class TestDropShard:
    def test_dropped_shard_serves_survivor_scores(self):
        sharded, _ = make_sharded_handle()
        from repro.serve.sharded import project_sharded
        dropped = oos.drop_shard(sharded, 2)
        assert dropped.shard_sizes == (24, 24, 0, 24)
        assert dropped.n_support == 72
        assert dropped.n_shards == sharded.n_shards   # handle-compatible
        xq = jnp.asarray(
            np.random.default_rng(0).normal(size=(9, 12)), jnp.float32)
        got = np.asarray(project_sharded(dropped, xq))
        oracle = np.asarray(
            oos.project(oos.gather_fitted(dropped), xq))
        np.testing.assert_allclose(got, oracle, atol=1e-5)
        # centering was REBUILT for the survivor support set
        assert not np.allclose(np.asarray(dropped.bias),
                               np.asarray(sharded.bias))

    def test_idempotent_and_validated(self):
        sharded, _ = make_sharded_handle()
        once = oos.drop_shard(sharded, 1)
        assert oos.drop_shard(once, 1) is once
        with pytest.raises(ValueError):
            oos.drop_shard(sharded, 9)
        with pytest.raises(TypeError):
            oos.drop_shard(object(), 0)

    def test_cannot_drop_every_shard(self):
        sharded, _ = make_sharded_handle(n_shards=2)
        one = oos.drop_shard(sharded, 0)
        with pytest.raises(ValueError):
            oos.drop_shard(one, 1)

    def test_publish_through_pinned_handle(self):
        sharded, _ = make_sharded_handle()
        handle = ModelHandle(sharded)
        v0 = handle.version
        handle.publish(oos.drop_shard(sharded, 0))   # same n_shards: OK
        assert handle.version == v0 + 1


@pytest.mark.lockcheck
class TestServingShardLoss:
    """The serving acceptance scenario, under the lock-order checker."""

    def _scenario(self):
        sharded, _ = make_sharded_handle()
        # at_dispatch=0: the FIRST drain (and any later one that still
        # sees live rows in shard 1) hits the loss — deterministic no
        # matter how the flusher coalesces the 24 concurrent submits.
        plan = FaultPlan(seed=0,
                         shard_losses=(ShardLoss(at_dispatch=0, shard=1),))
        injector = ShardLossInjector(plan)
        rebalancer = ShardRebalancer()
        handle = ModelHandle(sharded)
        cfg = KpcaServeConfig(max_batch=16, min_bucket=8,
                              flush_max_wait_s=0.001,
                              max_retries=4, retry_backoff_s=0.005,
                              request_deadline_s=WAIT)
        eng = KpcaEngine(handle, cfg, inject_fault=injector,
                         on_fault=rebalancer)
        return eng, handle, injector, rebalancer

    def test_shard_loss_under_load_zero_hangs_one_publish(self, tmp_path):
        tracer = trace.enable()
        eng, handle, injector, rebalancer = self._scenario()
        v0 = handle.version

        def make_query(tid, i):
            rng = np.random.default_rng(100 * tid + i)
            return rng.normal(size=(int(rng.integers(1, 9)), 12)) \
                .astype(np.float32)

        with eng:
            futures = hammer_submit(eng, n_threads=3, requests_each=8,
                                    make_query=make_query)
            results, errors = settle(futures, timeout_s=WAIT)
        # EVERY future resolved; failures (if any) are typed FaultErrors
        assert len(results) + len(errors) == 24
        assert all(isinstance(e, FaultError) for e in errors), errors
        assert results, "recovery should let most requests succeed"
        # exactly one atomic re-balance publish
        assert rebalancer.n_rebalances == 1
        assert handle.version == v0 + 1
        assert injector.n_raised >= 1
        assert handle.current().shard_sizes[1] == 0
        # post-recovery scores match the survivor oracle
        survivor = oos.gather_fitted(handle.current())
        xq = np.random.default_rng(9).normal(size=(5, 12)).astype(np.float32)
        out = eng.project_many([xq])[0]
        np.testing.assert_allclose(
            out, np.asarray(oos.project(survivor, jnp.asarray(xq))),
            atol=1e-5)
        # recovery span + injection instant land in the Chrome trace export
        path = tmp_path / "chaos_trace.json"
        tracer.export(str(path))
        events = json.loads(path.read_text())["traceEvents"]
        recov = [e for e in events if e["name"] == "fault.recovery"]
        assert len(recov) == 1 and recov[0]["ph"] == "X"
        assert recov[0]["args"]["kind"] == "shard_loss"
        assert any(e["name"] == "fault.injected" for e in events)
        assert any(e["name"] == "serve.retry" for e in events)

    def test_rebalance_is_exactly_once_across_concurrent_retries(self):
        sharded, _ = make_sharded_handle()
        handle = ModelHandle(sharded)
        rebalancer = ShardRebalancer()
        exc = ShardLostError(2)
        import threading
        n_handled = []
        lk = threading.Lock()
        barrier = threading.Barrier(4)

        def racer():
            barrier.wait()
            handled = rebalancer(exc, handle)
            with lk:
                n_handled.append(handled)

        threads = [threading.Thread(target=racer) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert n_handled == [True] * 4
        assert rebalancer.n_rebalances == 1    # one publish, 3 observers
        assert handle.current().shard_sizes[2] == 0


class TestRetryAndDeadline:
    def test_transient_fault_heals_within_retry_budget(self):
        sharded, model = make_sharded_handle()
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8,
                                         max_retries=3,
                                         retry_backoff_s=0.001),
                         inject_fault=transient_faults(2))
        xq = np.random.default_rng(0).normal(size=(4, 12)).astype(np.float32)
        fut = eng.submit(xq)
        out = eng.flush()
        assert fut.result(timeout=WAIT).shape == (4, 2)
        assert out and eng.stats.n_retries == 2

    def test_retries_exhausted_raises_typed_error(self):
        sharded, _ = make_sharded_handle()
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8,
                                         max_retries=1,
                                         retry_backoff_s=0.001),
                         inject_fault=transient_faults(10))
        eng.submit(np.zeros((2, 12), np.float32))
        with pytest.raises(InjectedCrashError):
            eng.flush()

    def test_max_retries_zero_keeps_fail_fast_contract(self):
        sharded, _ = make_sharded_handle()
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8),
                         inject_fault=transient_faults(1))
        eng.submit(np.zeros((2, 12), np.float32))
        with pytest.raises(InjectedCrashError):
            eng.flush()
        assert eng.stats.n_retries == 0
        assert eng.flush()                     # restored entries now serve

    def test_expired_requests_fail_typed_not_served_late(self):
        sharded, _ = make_sharded_handle()
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8,
                                         request_deadline_s=0.0))
        fut = eng.submit(np.zeros((3, 12), np.float32))
        out = eng.flush()                      # deadline 0: instantly stale
        assert out == {}
        with pytest.raises(DeadlineExceededError):
            fut.result(timeout=0)
        assert eng.stats.n_deadline_expired == 1

    def test_async_faulted_batch_resolves_every_future(self):
        """Flusher-side faults with retries exhausted: every in-flight
        future resolves with the typed error — zero hangs."""
        sharded, _ = make_sharded_handle()
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8,
                                         flush_max_wait_s=0.001,
                                         max_retries=1,
                                         retry_backoff_s=0.001),
                         inject_fault=transient_faults(1000))
        with eng:
            futures = hammer_submit(
                eng, n_threads=2, requests_each=4,
                make_query=lambda tid, i: np.zeros((2, 12), np.float32))
            results, errors = settle(futures, timeout_s=WAIT)
        assert len(errors) == 8 and not results
        assert all(isinstance(e, InjectedCrashError) for e in errors)


class TestPublisherCrash:
    def test_background_publisher_survives_crashed_job(self):
        sharded, model = make_sharded_handle()
        plan = FaultPlan(publisher_crashes=(PublisherCrash(at_job=0),))
        crashing = CrashingHandle(ModelHandle(model), plan)
        with BackgroundPublisher(crashing) as pub:
            pub.refresh(model.coefs)           # job 0: crashes in the worker
            with pytest.raises(InjectedCrashError):
                pub.drain(timeout=WAIT)        # the error is remembered
            pub.refresh(model.coefs)           # worker is still alive
            pub.drain(timeout=WAIT)            # and the next job lands
        assert crashing.n_crashes == 1
        assert crashing.version == 1

    def test_engine_serves_stale_model_through_crash(self):
        sharded, _ = make_sharded_handle()
        plan = FaultPlan(publisher_crashes=(PublisherCrash(at_job=0),))
        crashing = CrashingHandle(ModelHandle(sharded), plan)
        eng = KpcaEngine(ModelHandle(sharded),
                         KpcaServeConfig(max_batch=16, min_bucket=8))
        xq = np.random.default_rng(1).normal(size=(4, 12)).astype(np.float32)
        before = eng.project_many([xq])[0]
        with pytest.raises(InjectedCrashError):
            crashing.publish(oos.drop_shard(sharded, 0))
        after = eng.project_many([xq])[0]      # crash never reached serving
        np.testing.assert_array_equal(before, after)
