"""Tests for the repro-lint static analyzer (tools/lint): every rule gets
a violation fixture AND a clean twin, plus pragma suppression, annotation
parsing, the CLI output formats, and a self-check that the repo's own
source tree is clean at HEAD.

The analyzer is stdlib-only, so these tests never touch jax — keep it
that way (a jitted-code *string* is just a string).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from tools.lint import FileContext, all_rules, lint_source

REPO = Path(__file__).resolve().parents[1]


def lint(src, path="src/mod.py", select=None):
    return lint_source(textwrap.dedent(src), path, select=select)


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# registry / engine basics


class TestEngine:
    def test_at_least_seven_distinct_rules_registered(self):
        assert len(all_rules()) >= 7

    def test_syntax_error_is_a_finding_not_a_crash(self):
        out = lint("def broken(:\n")
        assert rules_of(out) == ["syntax-error"]

    def test_unknown_select_raises(self):
        with pytest.raises(ValueError, match="unknown rule"):
            lint("x = 1\n", select=["no-such-rule"])

    def test_findings_sorted_and_deduped(self):
        out = lint("""
            import threading
            def leak_a():
                t = threading.Thread(target=print)
                t.start()
            def leak_b():
                t = threading.Thread(target=print)
                t.start()
        """)
        assert rules_of(out) == ["thread-join", "thread-join"]
        assert [f.line for f in out] == sorted(f.line for f in out)

    def test_as_dict_roundtrips(self):
        (f,) = lint("lock = object()\nlock.acquire()\n")
        d = f.as_dict()
        assert d["rule"] == "bare-acquire" and d["line"] == 2


class TestPragmas:
    VIOLATION = "lock = object()\nlock.acquire()\n"

    def test_trailing_pragma_suppresses(self):
        assert lint("lock = object()\n"
                    "lock.acquire()  # repro-lint: disable=bare-acquire\n") \
            == []

    def test_standalone_pragma_on_previous_line_suppresses(self):
        assert lint("lock = object()\n"
                    "# repro-lint: disable=bare-acquire\n"
                    "lock.acquire()\n") == []

    def test_disable_all(self):
        assert lint("lock = object()\n"
                    "lock.acquire()  # repro-lint: disable=all\n") == []

    def test_wrong_rule_name_does_not_suppress(self):
        out = lint("lock = object()\n"
                   "lock.acquire()  # repro-lint: disable=lock-order\n")
        assert rules_of(out) == ["bare-acquire"]

    def test_unsuppressed_twin_still_fires(self):
        assert rules_of(lint(self.VIOLATION)) == ["bare-acquire"]


class TestAnnotationParsing:
    SRC = textwrap.dedent("""
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0          # guarded-by: _lock
                self.other = []     # trailing prose   guarded-by: _cond

            def _bump_locked(self):  # holds-lock: _lock
                self.n += 1
    """)

    def test_guarded_by_map(self):
        ctx = FileContext("src/box.py", self.SRC)
        assert ctx.guarded_by[("Box", "n")] == "_lock"
        # marker parses even with prose before it on the comment
        assert ctx.guarded_by[("Box", "other")] == "_cond"

    def test_holds_lock_map(self):
        ctx = FileContext("src/box.py", self.SRC)
        assert "_lock" in ctx.holds_lock.values()

    def test_is_test_detection(self):
        assert FileContext("tests/test_x.py", "x = 1\n").is_test
        assert FileContext("tests/conftest.py", "x = 1\n").is_test
        assert not FileContext("src/repro/x.py", "x = 1\n").is_test


# ---------------------------------------------------------------------------
# concurrency rules


class TestGuardedBy:
    def test_unlocked_access_flagged(self):
        out = lint("""
            import threading
            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.n = 0  # guarded-by: _lock
                def bad(self):
                    self.n += 1
        """)
        assert rules_of(out) == ["guarded-by"]

    def test_locked_access_clean(self):
        assert lint("""
            import threading
            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.n = 0  # guarded-by: _lock
                def good(self):
                    with self._lock:
                        self.n += 1
        """) == []

    def test_nested_def_does_not_inherit_lock(self):
        # a nested def may run on another thread; the with-block around
        # its DEFINITION proves nothing about its execution
        out = lint("""
            import threading
            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.n = 0  # guarded-by: _lock
                def bad(self):
                    with self._lock:
                        def cb():
                            self.n += 1
                        return cb
        """)
        assert rules_of(out) == ["guarded-by"]

    def test_holds_lock_annotation_satisfies(self):
        assert lint("""
            import threading
            class Box:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.n = 0  # guarded-by: _lock
                def _bump_locked(self):  # holds-lock: _lock
                    self.n += 1
        """) == []


class TestBlockingInLock:
    def test_device_get_inside_lock_flagged(self):
        out = lint("""
            import numpy as np
            def flush(self, dev):
                with self._lock:
                    out = np.asarray(dev)
                return out
        """)
        assert rules_of(out) == ["blocking-in-lock"]

    def test_block_until_ready_and_item_flagged(self):
        out = lint("""
            def f(self, x):
                with self._lock:
                    x.block_until_ready()
                    return x.item()
        """)
        assert rules_of(out) == ["blocking-in-lock"] * 2

    def test_outside_lock_clean(self):
        assert lint("""
            import numpy as np
            def flush(self, dev):
                with self._lock:
                    launched = dev
                return np.asarray(launched)
        """) == []

    def test_non_lock_context_manager_clean(self):
        assert lint("""
            import numpy as np
            def f(dev, path):
                with open(path) as fh:
                    return np.asarray(dev), fh.read()
        """) == []


class TestThreadJoin:
    def test_unjoined_thread_flagged(self):
        out = lint("""
            import threading
            def leak():
                t = threading.Thread(target=print)
                t.start()
        """)
        assert rules_of(out) == ["thread-join"]

    def test_joined_thread_clean(self):
        assert lint("""
            import threading
            def ok():
                t = threading.Thread(target=print)
                t.start()
                t.join()
        """) == []

    def test_join_in_another_function_does_not_count(self):
        out = lint("""
            import threading
            def leak():
                t = threading.Thread(target=print)
                t.start()
            def unrelated():
                t = object()
                t.join()
        """)
        assert rules_of(out) == ["thread-join"]

    def test_returned_thread_escapes(self):
        assert lint("""
            import threading
            def spawn():
                t = threading.Thread(target=print)
                t.start()
                return t
        """) == []

    def test_self_attr_thread_joined_elsewhere(self):
        assert lint("""
            import threading
            class Eng:
                def start(self):
                    self._t = threading.Thread(target=print)
                    self._t.start()
                def close(self):
                    self._t.join()
        """) == []

    def test_loop_alias_join(self):
        assert lint("""
            import threading
            def fan_out():
                ts = []
                for i in range(3):
                    t = threading.Thread(target=print)
                    ts.append(t)
                    t.start()
                for t in ts:
                    t.join()
        """) == []


class TestLockOrder:
    def test_inverse_nesting_flagged(self):
        out = lint("""
            def a(self):
                with self._lock_a:
                    with self._lock_b:
                        pass
            def b(self):
                with self._lock_b:
                    with self._lock_a:
                        pass
        """)
        assert rules_of(out) == ["lock-order"]

    def test_consistent_nesting_clean(self):
        assert lint("""
            def a(self):
                with self._lock_a:
                    with self._lock_b:
                        pass
            def b(self):
                with self._lock_a:
                    with self._lock_b:
                        pass
        """) == []

    def test_holds_lock_counts_as_outer(self):
        out = lint("""
            def locked_helper(self):  # holds-lock: _lock_a
                with self._lock_b:
                    pass
            def other(self):
                with self._lock_b:
                    with self._lock_a:
                        pass
        """)
        assert rules_of(out) == ["lock-order"]


class TestBareAcquire:
    def test_acquire_flagged(self):
        out = lint("def f(self):\n    self._lock.acquire()\n")
        assert rules_of(out) == ["bare-acquire"]

    def test_with_statement_clean(self):
        assert lint("def f(self):\n    with self._lock:\n        pass\n") \
            == []

    def test_non_lockish_name_clean(self):
        assert lint("def f(sem_view):\n    sem_view.refresh()\n") == []


# ---------------------------------------------------------------------------
# jax rules


class TestImpureJit:
    def test_time_in_jitted_flagged(self):
        out = lint("""
            import time, jax
            @jax.jit
            def step(x):
                t0 = time.monotonic()
                return x + t0
        """)
        assert rules_of(out) == ["impure-jit"]

    def test_np_random_in_jitted_flagged(self):
        out = lint("""
            import numpy as np
            import jax
            @jax.jit
            def noisy(x):
                return x + np.random.normal()
        """)
        assert rules_of(out) == ["impure-jit"]

    def test_impurity_outside_jit_clean(self):
        assert lint("""
            import time
            def host_step(x):
                return x, time.monotonic()
        """) == []

    def test_jit_called_on_name_detected(self):
        out = lint("""
            import time, jax
            def step(x):
                return x + time.monotonic()
            fast_step = jax.jit(step)
        """)
        assert rules_of(out) == ["impure-jit"]


class TestClosureCapture:
    def test_scalar_capture_flagged(self):
        out = lint("""
            import jax
            def make(scale_db):
                scale = 10.0 ** (scale_db / 10.0)
                @jax.jit
                def apply(x):
                    return x * scale
                return apply
        """)
        assert rules_of(out) == ["closure-capture"]

    def test_argument_not_flagged(self):
        assert lint("""
            import jax
            def make():
                @jax.jit
                def apply(x, scale):
                    return x * scale
                return apply
        """) == []

    def test_top_level_jit_not_flagged(self):
        assert lint("""
            import jax
            SCALE = 2.0
            @jax.jit
            def apply(x):
                return x * SCALE
        """) == []


class TestInterpretLiteral:
    def test_hardcoded_interpret_flagged_in_src(self):
        out = lint("""
            import jax.experimental.pallas as pl
            def gram(x):
                return pl.pallas_call(kernel, interpret=True)(x)
        """, path="src/repro/kernels/gram.py")
        assert rules_of(out) == ["interpret-literal"]

    def test_allowed_in_tests(self):
        assert lint("""
            import jax.experimental.pallas as pl
            def gram(x):
                return pl.pallas_call(kernel, interpret=True)(x)
        """, path="tests/test_gram.py") == []

    def test_flag_from_variable_clean(self):
        assert lint("""
            import jax.experimental.pallas as pl
            def gram(x, interpret):
                return pl.pallas_call(kernel, interpret=interpret)(x)
        """, path="src/repro/kernels/gram.py") == []


class TestDonatedReuse:
    def test_reuse_after_donating_call_flagged(self):
        out = lint("""
            import jax
            step = jax.jit(_step, donate_argnums=(0,))
            def run(state):
                new = step(state)
                return new, state.norm
        """)
        assert rules_of(out) == ["donated-reuse"]

    def test_rebinding_idiom_clean(self):
        assert lint("""
            import jax
            step = jax.jit(_step, donate_argnums=(0,))
            def run(state):
                state = step(state)
                return state
        """) == []

    def test_partial_decorator_detected(self):
        out = lint("""
            import jax
            from functools import partial
            @partial(jax.jit, donate_argnums=(0,))
            def step(state):
                return state
            def run(state):
                out = step(state)
                return out, state.t
        """)
        assert rules_of(out) == ["donated-reuse"]


# ---------------------------------------------------------------------------
# observability rules


class TestSpanNotClosed:
    def test_bare_span_call_flagged(self):
        out = lint("""
            from repro.obs import trace
            def serve(entries):
                trace.span("serve.pack", n=len(entries))
                return entries
        """)
        assert rules_of(out) == ["span-not-closed"]

    def test_assigned_span_flagged(self):
        # spans record on __exit__; an assigned-but-never-entered span is
        # silent data loss, not deferred instrumentation
        out = lint("""
            from repro.obs import trace
            def serve(entries):
                s = trace.span("serve.pack")
                return entries
        """)
        assert rules_of(out) == ["span-not-closed"]

    def test_with_statement_clean(self):
        assert lint("""
            from repro.obs import trace
            def serve(entries):
                with trace.span("serve.pack", n=len(entries)):
                    return entries
        """) == []

    def test_with_as_and_tracer_instance_clean(self):
        assert lint("""
            def record(tracer, work):
                with tracer.span("phase") as s:
                    s.annotate(n=len(work))
                    return work
        """) == []

    def test_chained_annotate_inside_with_clean(self):
        assert lint("""
            from repro.obs import trace
            def serve(entries):
                with trace.span("serve.pack").annotate(n=1):
                    return entries
        """) == []

    def test_returned_span_is_a_factory_not_a_leak(self):
        assert lint("""
            def span(tracer, name):
                return tracer.span(name)
        """) == []

    def test_unrelated_span_function_clean(self):
        # only trace-ish attribute bases match: np column spans etc. are
        # out of scope by design
        assert lint("""
            def f(table):
                table.span("rows")
                return table
        """) == []

    def test_pragma_suppresses(self):
        assert lint("""
            from repro.obs import trace
            def defer(stack):
                s = trace.span("x")  # repro-lint: disable=span-not-closed
                stack.enter_context(s)
        """) == []


class TestSleepInTest:
    def test_time_sleep_in_test_file_flagged(self):
        out = lint("""
            import time
            def test_worker_finishes(worker):
                worker.start()
                time.sleep(0.1)
                assert worker.done
        """, path="tests/test_worker.py")
        assert rules_of(out) == ["sleep-in-test"]

    def test_from_import_and_alias_flagged(self):
        out = lint("""
            from time import sleep as snooze
            import time as clock
            def test_x():
                snooze(0.5)
                clock.sleep(1)
        """, path="tests/test_x.py")
        assert rules_of(out) == ["sleep-in-test", "sleep-in-test"]

    def test_helpers_and_conftest_are_in_scope(self):
        out = lint("import time\ntime.sleep(1)\n",
                   path="tests/helpers/util.py")
        assert rules_of(out) == ["sleep-in-test"]
        out = lint("import time\ntime.sleep(1)\n", path="tests/conftest.py")
        assert rules_of(out) == ["sleep-in-test"]

    def test_src_sleep_is_out_of_scope(self):
        # production backoffs are not this rule's business
        assert lint("import time\ndef backoff():\n    time.sleep(0.2)\n",
                    path="src/repro/serve/kpca_engine.py") == []

    def test_event_wait_join_and_unrelated_sleep_clean(self):
        assert lint("""
            import threading
            def test_worker(worker, actor):
                done = threading.Event()
                worker.start(on_done=done.set)
                assert done.wait(timeout=5.0)
                worker.thread.join(timeout=1.0)
                actor.sleep()              # not time.sleep: out of scope
        """, path="tests/test_worker.py") == []

    def test_pragma_suppresses_duration_sleep(self):
        assert lint("""
            import time
            def test_span_duration(tracer):
                with tracer.span("d"):
                    time.sleep(0.002)  # repro-lint: disable=sleep-in-test
        """, path="tests/test_obs.py") == []


class TestUntimedDeviceCall:
    VIOLATION = """
        import time
        import jax

        def bench(x):
            f = jax.jit(lambda v: v * 2)
            t0 = time.perf_counter()
            for _ in range(100):
                f(x)
            return (time.perf_counter() - t0) / 100
    """

    def test_unblocked_jit_call_in_timed_loop_fires(self):
        out = lint(self.VIOLATION, path="benchmarks/bench_thing.py")
        assert rules_of(out) == ["untimed-device-call"]

    def test_blocked_twin_is_clean(self):
        assert lint("""
            import time
            import jax

            def bench(x):
                f = jax.jit(lambda v: v * 2)
                t0 = time.perf_counter()
                for _ in range(100):
                    jax.block_until_ready(f(x))
                return (time.perf_counter() - t0) / 100
        """, path="benchmarks/bench_thing.py") == []

    def test_item_and_asarray_also_materialize(self):
        assert lint("""
            import time
            import numpy as np
            from repro.kernels import gram_op

            def bench(spec, x):
                t0 = time.perf_counter()
                out = np.asarray(gram_op(spec, x))
                dt = time.perf_counter() - t0
                return out, dt
        """, path="benchmarks/bench_kernels.py") == []

    def test_kernel_import_counts_as_device_call(self):
        out = lint("""
            import time
            from repro.kernels import gram_op

            def bench(spec, x):
                t0 = time.perf_counter()
                gram_op(spec, x)
                dt = time.perf_counter() - t0
                return dt
        """, path="benchmarks/bench_kernels.py")
        assert rules_of(out) == ["untimed-device-call"]

    def test_out_of_scope_outside_benchmarks(self):
        assert lint(self.VIOLATION, path="src/repro/serve/engine.py") == []

    def test_clock_start_without_read_is_not_a_region(self):
        assert lint("""
            import time
            import jax

            def warm(x):
                f = jax.jit(lambda v: v * 2)
                t0 = time.perf_counter()   # start stamp only, never read
                f(x)
        """, path="benchmarks/bench_thing.py") == []

    def test_pragma_suppresses(self):
        assert lint("""
            import time
            import jax

            def bench_dispatch_overhead(x):
                f = jax.jit(lambda v: v * 2)
                t0 = time.perf_counter()
                f(x)  # repro-lint: disable=untimed-device-call
                return time.perf_counter() - t0
        """, path="benchmarks/bench_thing.py") == []


# ---------------------------------------------------------------------------
# CLI + repo self-check


class TestCli:
    def _run(self, *argv, cwd=REPO):
        return subprocess.run([sys.executable, "-m", "tools.lint", *argv],
                              capture_output=True, text=True, cwd=cwd)

    def test_clean_tree_exits_zero(self):
        res = self._run("src", "tests", "benchmarks")
        assert res.returncode == 0, res.stdout + res.stderr

    def test_violation_exits_one_and_formats(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("lock = object()\nlock.acquire()\n")
        res = self._run(str(bad))
        assert res.returncode == 1
        assert "bare-acquire" in res.stdout

        res = self._run("--format", "github", str(bad))
        assert res.returncode == 1
        assert res.stdout.startswith("::error file=")

        res = self._run("--format", "json", str(bad))
        payload = json.loads(res.stdout)
        assert payload[0]["rule"] == "bare-acquire"

    def test_select_filters(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("lock = object()\nlock.acquire()\n")
        res = self._run("--select", "lock-order", str(bad))
        assert res.returncode == 0

    def test_list_rules(self):
        res = self._run("--list-rules")
        assert res.returncode == 0
        for rule in ("guarded-by", "blocking-in-lock", "thread-join",
                     "lock-order", "bare-acquire", "impure-jit",
                     "closure-capture", "interpret-literal",
                     "donated-reuse", "span-not-closed", "sleep-in-test",
                     "untimed-device-call"):
            assert rule in res.stdout

    def test_unknown_rule_is_usage_error(self):
        res = self._run("--select", "bogus", "src")
        assert res.returncode == 2
