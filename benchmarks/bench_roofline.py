"""Roofline summary rows from the dry-run artifact (results/dryrun.json).

Reads whatever cells have completed; the full table lives in
EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import json
import os

from repro.launch.roofline import analyze


def bench_roofline_summary():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")
    if not os.path.exists(path):
        return [("roofline/missing", 0.0, "run repro.launch.dryrun first")]
    rows = []
    data = json.load(open(path))
    for key, rec in sorted(data.items()):
        if not rec.get("ok") or rec["mesh"] != "16x16":
            continue
        a = analyze(rec)
        rows.append((f"roofline/{rec['arch']}/{rec['shape']}",
                     a["compute_s"] * 1e6,
                     f"dom={a['dominant']};frac={a['roofline_fraction']:.3f};"
                     f"useful={a['useful_flops_ratio']:.2f}"))
    return rows or [("roofline/empty", 0.0, "no completed cells yet")]
