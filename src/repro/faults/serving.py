"""Serving-side fault injection + recovery: shard loss, publisher crash.

Injection and recovery are deliberately separate objects wired into the
same :class:`~repro.serve.kpca_engine.KpcaEngine`:

- :class:`ShardLossInjector` is the engine's ``inject_fault`` hook — a
  deterministic stand-in for "the host serving shard s stopped
  answering". It raises :class:`~repro.faults.errors.ShardLostError`
  on every dispatch that would still read the lost shard's rows, and
  goes quiet once the served model no longer has live rows there.
- :class:`ShardRebalancer` is the engine's ``on_fault`` recovery hook:
  on a ``ShardLostError`` it republishes the model with the lost shard
  zeroed (``core/oos.drop_shard`` — survivor centering rebuilt from the
  cached per-shard kernel-mean sums) through ONE atomic
  ``ModelHandle.publish``. Exactly-once: concurrent retries for the
  same shard contend on a lock and the loser observes the already-
  healed model (``shard_sizes[s] == 0``) and publishes nothing.

The engine's bounded retry re-reads the handle on every attempt, so the
attempt after the re-balance publish serves from the survivor model and
the in-flight futures resolve with real scores — zero hangs.

:class:`CrashingHandle` wraps a ``ModelHandle`` so scheduled
publish/refresh jobs raise — it proves the ``BackgroundPublisher``
remembers the error, keeps its worker alive, and keeps serving the last
good version.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

from ..core import oos
from ..obs import metrics, trace
from .errors import InjectedCrashError, ShardLostError
from .plan import FaultPlan

_M_INJECTED_SHARD = metrics.counter(
    "faults_injected_total", "fault events activated", kind="shard_loss")
_M_INJECTED_CRASH = metrics.counter(
    "faults_injected_total", "fault events activated", kind="publisher_crash")
_M_REBALANCE = metrics.counter(
    "rebalance_publishes_total", "atomic shard-loss re-balance publishes")


class ShardLossInjector:
    """Deterministic shard-loss injection keyed off a :class:`FaultPlan`.

    ``__call__(model)`` is the engine's per-dispatch hook. Dispatches are
    counted under a lock (submitter/flusher threads race the counter);
    after dispatch ``at_dispatch`` of a ``ShardLoss`` event, any model
    still holding live rows for that shard raises ``ShardLostError``.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._dispatches = 0
        self.n_raised = 0

    @property
    def dispatches(self) -> int:
        with self._lock:
            return self._dispatches

    def __call__(self, model) -> None:
        with self._lock:
            n = self._dispatches
            self._dispatches += 1
            dead = [ev.shard for ev in self.plan.shard_losses
                    if n >= ev.at_dispatch]
        sizes = getattr(model, "shard_sizes", None)
        if sizes is None:
            return                       # non-sharded model: nothing to lose
        for s in dead:
            if sizes[s] > 0:
                with self._lock:
                    self.n_raised += 1
                _M_INJECTED_SHARD.inc()
                if trace.is_enabled():
                    trace.instant("fault.injected", kind="shard_loss",
                                  shard=s, dispatch=n)
                raise ShardLostError(s, f"injected at dispatch {n}")


class ShardRebalancer:
    """Exactly-once shard-loss recovery for ``KpcaEngine.on_fault``.

    Returns True when the fault was handled (model republished or already
    healed) so the engine retries immediately instead of backing off.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.n_rebalances = 0

    def __call__(self, exc: BaseException, handle) -> bool:
        if not isinstance(exc, ShardLostError):
            return False
        with self._lock:
            model = handle.current()
            if getattr(model, "shard_sizes", None) is None:
                return False
            if model.shard_sizes[exc.shard] == 0:
                return True              # a concurrent retry already healed it
            t0 = time.perf_counter()
            handle.publish(oos.drop_shard(model, exc.shard))
            self.n_rebalances += 1
            _M_REBALANCE.inc()
            if trace.is_enabled():
                trace.complete("fault.recovery",
                               time.perf_counter() - t0,
                               kind="shard_loss", shard=exc.shard,
                               version=handle.version)
        return True


class CrashingHandle:
    """``ModelHandle`` wrapper whose scheduled jobs crash.

    Counts publish/refresh calls; call index ``at_job`` of each
    ``PublisherCrash`` event raises ``InjectedCrashError`` instead of
    applying the job. Reads (``get``/``current``/``version``) always
    pass through — a crashed publisher must not take serving down.
    """

    def __init__(self, handle, plan: FaultPlan):
        self.handle = handle
        self._crash_at = frozenset(
            int(ev.at_job) for ev in plan.publisher_crashes)
        self._lock = threading.Lock()
        self._jobs = 0
        self.n_crashes = 0

    def _maybe_crash(self, kind: str) -> None:
        with self._lock:
            n = self._jobs
            self._jobs += 1
            crash = n in self._crash_at
            if crash:
                self.n_crashes += 1
        if crash:
            _M_INJECTED_CRASH.inc()
            if trace.is_enabled():
                trace.instant("fault.injected", kind="publisher_crash",
                              job=n)
            raise InjectedCrashError(f"publisher job {n} ({kind}) crashed")

    def publish(self, model) -> int:
        self._maybe_crash("publish")
        return self.handle.publish(model)

    def refresh(self, alpha) -> int:
        self._maybe_crash("refresh")
        return self.handle.refresh(alpha)

    def refresh_shard(self, shard: int, alpha) -> int:
        self._maybe_crash("refresh_shard")
        return self.handle.refresh_shard(shard, alpha)

    def __getattr__(self, name):
        return getattr(self.handle, name)


def transient_faults(errors_before_success: int,
                     exc_factory=None) -> "_TransientInjector":
    """An ``inject_fault`` hook raising on the first N dispatches.

    Used by the launcher demo and tests to exercise retry-with-backoff
    without a sharded model.
    """
    return _TransientInjector(errors_before_success, exc_factory)


class _TransientInjector:
    def __init__(self, n: int, exc_factory: Optional[callable]):
        self._remaining = int(n)
        self._lock = threading.Lock()
        self._exc_factory = exc_factory or (
            lambda: InjectedCrashError("transient injected fault"))

    def __call__(self, model) -> None:
        with self._lock:
            if self._remaining <= 0:
                return
            self._remaining -= 1
        _M_INJECTED_CRASH.inc()
        raise self._exc_factory()


__all__ = ["ShardLossInjector", "ShardRebalancer", "CrashingHandle",
           "transient_faults"]
