"""Chaos-test harness: deterministic fault scenarios with zero sleeps.

Helpers shared by tests/test_fault_injection.py. Synchronization is
event/future-based throughout — a chaos test that needs ``time.sleep``
to pass is itself timing-dependent, which is exactly the flakiness the
fault layer exists to rule out (the ``sleep-in-test`` repro-lint rule
enforces this repo-wide).
"""

import concurrent.futures
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, central_kpca, oos, similarity
from repro.data import kpca_dataset
from repro.faults import FaultTolerantRun


def run_to_end(run: FaultTolerantRun):
    """Drive a fault-tolerant run to completion; returns the chunk list."""
    return list(run.chunks())


def survivor_similarities(run: FaultTolerantRun, spec: KernelSpec,
                          n_components: int = 1):
    """Per-survivor similarity of the run's final alpha against the
    survivor-pooled CENTRAL solution under the run's pinned gamma —
    the paper's consistency metric, restricted to who is left."""
    nodes = np.asarray(run.x_nodes)
    pooled = nodes.reshape(-1, nodes.shape[-1])
    ag, _, _ = central_kpca(jnp.asarray(pooled), spec, n_components,
                            gamma=run.gamma)
    return [float(similarity(run.state.alpha[j], jnp.asarray(nodes[j]),
                             ag[:, 0], jnp.asarray(pooled), spec,
                             gamma=run.gamma))
            for j in range(nodes.shape[0])]


def make_sharded_handle(n_train=96, m=12, n_shards=4, n_components=2,
                        seed=0):
    """(handle-able sharded model, its source FittedKpca) on RBF data."""
    x = jnp.asarray(kpca_dataset(n_train, m=m, seed=seed))
    model = oos.fit_central(x, KernelSpec(kind="rbf"),
                            n_components=n_components, center=True)
    sharded, _ = oos.shard_fitted(model, n_shards)
    return sharded, model


def hammer_submit(engine, n_threads: int, requests_each: int, make_query,
                  collect_submit_errors=False):
    """Submit from ``n_threads`` concurrent threads (barrier-released so
    they really race), return every future.

    ``make_query(tid, i)`` builds each request payload. Futures are
    appended to per-thread slots (no lock needed: slot-per-thread, read
    after join). With ``collect_submit_errors`` admission failures are
    returned too instead of propagating.
    """
    futures = [[] for _ in range(n_threads)]
    submit_errors = [[] for _ in range(n_threads)]
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        barrier.wait()
        for i in range(requests_each):
            try:
                futures[tid].append(engine.submit(make_query(tid, i)))
            except Exception as e:
                if not collect_submit_errors:
                    raise
                submit_errors[tid].append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    flat = [f for fs in futures for f in fs]
    errs = [e for es in submit_errors for e in es]
    return (flat, errs) if collect_submit_errors else flat


def settle(futures, timeout_s: float = 30.0):
    """Wait for EVERY future to resolve; zero hangs allowed.

    Returns (results, exceptions) — each future lands in exactly one
    list. Asserts none are still pending at the timeout (the
    fault-tolerance contract: success or typed error, never a hang).

    One SHARED deadline across all futures (not timeout_s each), waited
    per-future: works for both ``concurrent.futures.Future`` and the
    engine's slot-table ``SlotFuture`` (which resolves whole flushes
    through one event and has no ``_condition`` for
    ``concurrent.futures.wait`` to grab).
    """
    deadline = time.monotonic() + timeout_s
    results, errors, pending = [], [], 0
    for f in futures:
        try:
            exc = f.exception(timeout=max(0.0, deadline - time.monotonic()))
        except concurrent.futures.TimeoutError:
            pending += 1
            continue
        except concurrent.futures.CancelledError as e:
            errors.append(e)
            continue
        if exc is None:
            results.append(f.result(timeout=0))
        else:
            errors.append(exc)
    assert not pending, f"{pending} futures hung past {timeout_s}s"
    return results, errors
