"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Where the tracer (``repro.obs.trace``) answers "what happened during this
window", the registry answers "how much, in total": monotonic counters
(``serve_requests_total``), point-in-time gauges (``serve_queue_depth_rows``)
and fixed-bucket histograms (``serve_request_latency_seconds``). Instrumented
code publishes through the module-level helpers —

    from repro.obs import metrics
    metrics.counter("serve_rejected_total").inc()

— and a run launched with ``--metrics-out metrics.json`` writes the final
``snapshot()``. ``prometheus_text()`` emits the standard text exposition
format, so a real deployment can mount it on a ``/metrics`` endpoint
unchanged. Metric and label names follow Prometheus conventions
(``snake_case``, ``_total`` for counters, base-unit ``_seconds``/``_bytes``
suffixes); docs/OBSERVABILITY.md catalogs every name this repo emits.

Metrics are always on (there is no disabled state): every instrument is one
short per-metric lock acquisition, and hot paths amortize — the serving
engine publishes per *drain*, not per request, and batches per-request
latency samples through ``Histogram.observe_many`` under one acquisition.
Instrument handles are plain objects; call-sites on hot paths should look
them up once (``self._m_x = metrics.counter(...)``) and hold the handle.
"""

from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, Iterator, List, Sequence, Tuple

# Latency-oriented default buckets (seconds): 100us .. 10s, log-ish.
DEFAULT_BUCKETS = (1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2,
                   5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_LabelItems = Tuple[Tuple[str, str], ...]


class _Metric:
    """Shared identity: name + sorted (label, value) pairs + help text."""

    kind = ""

    def __init__(self, name: str, help: str = "",
                 labels: _LabelItems = ()):
        self.name = name
        self.help = help
        self.labels = labels
        self._lock = threading.Lock()

    def label_dict(self) -> Dict[str, str]:
        return dict(self.labels)

    def snapshot(self) -> dict:
        raise NotImplementedError


class Counter(_Metric):
    """Monotonic accumulator; ``inc`` rejects negative deltas."""

    kind = "counter"

    def __init__(self, name: str, help: str = "", labels: _LabelItems = ()):
        super().__init__(name, help, labels)
        self._value = 0.0                   # guarded-by: _lock

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"value": self.value}


class Gauge(_Metric):
    """Point-in-time value; settable and incrementable either direction."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "", labels: _LabelItems = ()):
        super().__init__(name, help, labels)
        self._value = 0.0                   # guarded-by: _lock

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> dict:
        return {"value": self.value}


class Histogram(_Metric):
    """Fixed-bucket histogram (cumulative ``le`` exposition, like
    Prometheus). Bucket edges are upper bounds in ascending order; samples
    above the last edge land in the implicit ``+Inf`` bucket."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", labels: _LabelItems = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels)
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(
                f"histogram {name} needs strictly increasing buckets, "
                f"got {buckets}")
        self.buckets = edges
        self._counts = [0] * (len(edges) + 1)   # guarded-by: _lock
        self._sum = 0.0                         # guarded-by: _lock
        self._count = 0                         # guarded-by: _lock

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    def observe_many(self, values: Sequence[float]) -> None:
        """Batch a drain's worth of samples under ONE lock acquisition —
        the hot-path form (per-request latencies land here)."""
        if not values:
            return
        idx = [bisect.bisect_left(self.buckets, v) for v in values]
        with self._lock:
            for i in idx:
                self._counts[i] += 1
            self._sum += sum(values)
            self._count += len(values)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            total, s = self._count, self._sum
        cum, out = 0, []
        for edge, c in zip(self.buckets, counts):
            cum += c
            out.append([edge, cum])
        return {"buckets": out, "count": total, "sum": s}


class MetricsRegistry:
    """Get-or-create registry keyed by (name, labels); one instance per
    process is the normal mode (``default_registry()``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, _Metric] = {}    # guarded-by: _lock

    def _get(self, cls, name: str, help: str, labels: Dict[str, str],
             **extra) -> _Metric:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help, key[1], **extra)
                self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    def _sorted(self) -> List[_Metric]:
        with self._lock:
            items = list(self._metrics.items())
        return [m for _, m in sorted(items, key=lambda kv: kv[0])]

    def snapshot(self) -> dict:
        """JSON-ready view: every metric with kind, labels and values."""
        return {"metrics": [
            {"name": m.name, "kind": m.kind, "labels": m.label_dict(),
             **m.snapshot()} for m in self._sorted()]}

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)
            f.write("\n")

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        seen_header = set()
        for m in self._sorted():
            if m.name not in seen_header:
                seen_header.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
            if isinstance(m, Histogram):
                snap = m.snapshot()
                for edge, cum in snap["buckets"]:
                    lines.append(f"{m.name}_bucket"
                                 f"{_labels(m.labels, le=_fmt(edge))} {cum}")
                lines.append(f"{m.name}_bucket{_labels(m.labels, le='+Inf')}"
                             f" {snap['count']}")
                lines.append(
                    f"{m.name}_sum{_labels(m.labels)} {_fmt(snap['sum'])}")
                lines.append(
                    f"{m.name}_count{_labels(m.labels)} {snap['count']}")
            else:
                lines.append(f"{m.name}{_labels(m.labels)} {_fmt(m.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        """Drop every metric (tests; a live process never resets)."""
        with self._lock:
            self._metrics = {}

    def __iter__(self) -> Iterator[_Metric]:
        return iter(self._sorted())


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() else repr(float(v))


def _labels(items: _LabelItems, **extra) -> str:
    pairs = list(items) + sorted(extra.items())
    if not pairs:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in pairs)
    return "{" + body + "}"


# ---- process-wide registry -------------------------------------------------

_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _default


def counter(name: str, help: str = "", **labels) -> Counter:
    return _default.counter(name, help, **labels)


def gauge(name: str, help: str = "", **labels) -> Gauge:
    return _default.gauge(name, help, **labels)


def histogram(name: str, help: str = "",
              buckets: Sequence[float] = DEFAULT_BUCKETS,
              **labels) -> Histogram:
    return _default.histogram(name, help, buckets=buckets, **labels)


def snapshot() -> dict:
    return _default.snapshot()


def prometheus_text() -> str:
    return _default.prometheus_text()


def write_json(path: str) -> None:
    _default.write_json(path)


__all__ = ["Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram",
           "MetricsRegistry", "counter", "default_registry", "gauge",
           "histogram", "prometheus_text", "snapshot", "write_json"]
