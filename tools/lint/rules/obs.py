"""Observability rules.

The span tracer (``src/repro/obs/trace.py``) records a span only when its
context manager EXITS: a ``trace.span(...)`` / ``tracer.span(...)`` call
that is never entered with ``with`` silently records nothing — the
instrumented phase just disappears from the flight recording, which is the
worst kind of observability bug (absence looks like idleness). The
``span-not-closed`` rule flags span-factory calls used as bare expressions,
arguments, or assignments instead of as a ``with`` context.

Recognized factories are attribute calls ``<base>.span(...)`` where the
base name mentions ``trace`` (the module alias ``trace``, a ``tracer``
instance, ``self._tracer``, ...). A plain ``span(...)`` name call is NOT
matched — too many unrelated functions are called span (e.g. numpy column
spans), and the repo convention is to call through the module
(``trace.span``). Deliberate deferred-entry uses (rare; e.g. handing a
span to an ExitStack) can pragma the line with
``# repro-lint: disable=span-not-closed``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..engine import FileContext, Finding, Rule, register


def _base_mentions_trace(expr: ast.AST) -> bool:
    """Does the attribute base refer to a tracer? Matches ``trace``,
    ``tracer``, ``self._tracer``, ``obs.trace`` ... by name substring."""
    if isinstance(expr, ast.Name):
        return "trace" in expr.id.lower()
    if isinstance(expr, ast.Attribute):
        return "trace" in expr.attr.lower() or _base_mentions_trace(expr.value)
    return False


def _is_span_factory(call: ast.Call) -> bool:
    return (isinstance(call.func, ast.Attribute)
            and call.func.attr == "span"
            and _base_mentions_trace(call.func.value))


@register
class SpanNotClosedRule(Rule):
    name = "span-not-closed"
    summary = ("a trace/tracer .span(...) call must be entered via 'with' "
               "— a span that never exits is never recorded")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and _is_span_factory(node)):
                continue
            # Entered via with — directly, or through a chained call
            # (``with trace.span(...).annotate(...):``): walk up any
            # attribute/call chain looking for the enclosing withitem.
            if self._in_with_chain(node):
                continue
            # ``return <factory>.span(...)`` — a wrapper handing the span
            # to ITS caller to enter (the trace module's own pattern).
            if isinstance(getattr(node, "parent", None), ast.Return):
                continue
            yield self.finding(
                ctx, node,
                "span is created but never entered — spans record on "
                "__exit__ only; write \"with ...span(...):\" around the "
                "timed work (or pragma a deliberate deferred entry)")

    @staticmethod
    def _in_with_chain(call: ast.Call) -> bool:
        node = call
        while hasattr(node, "parent"):
            parent = node.parent
            if isinstance(parent, ast.withitem):
                return True
            if not isinstance(parent, (ast.Attribute, ast.Call)):
                return False
            node = parent
        return False
