"""Versioned model publishing: the trainer-to-server hand-off.

A still-running ADMM driver (``repro.core.solver.run_chunked``) produces a
stream of coefficient snapshots; the serving side must pick them up without
dropping or mixing in-flight work. ``ModelHandle`` is the seam: a
thread-safe, versioned, atomically-swappable reference to a servable model.
``KpcaEngine`` reads THROUGH the handle — each flush snapshots (model,
version) once up front, so every slab of that flush scores against one
consistent model version even if a publish lands mid-flush; the next flush
sees the new version. Publishing never blocks serving (the swap is a
reference assignment under a lock, not a copy).

End-to-end streaming glue: ``stream_chunks`` consumes a ``run_chunked``
iterator and republishes a refreshed ``FittedKpca``
(``repro.core.oos.refresh_coefficients`` — cached kernel-mean statistics,
no Gram re-formation) every k chunks.
"""

from __future__ import annotations

import threading
from typing import Iterable, Optional, Tuple

from ..core import oos


class ModelHandle:
    """Thread-safe versioned reference to a servable kPCA model.

    The handle pins the model TYPE at construction (``FittedKpca`` or
    ``ShardedFittedKpca``) — and, for sharded models, the shard count: the
    engine compiles its projection path against that type (and its mesh
    against that shard count), so a publish may change coefficients/shapes
    (jit re-traces on shape changes) but not the artifact kind or the
    shard layout.
    """

    def __init__(self, model, version: int = 0):
        self._lock = threading.Lock()
        self._model = model
        self._version = version
        self._kind = type(model)
        # the engine's compiled sharded path also pins its mesh to the
        # initial shard count, so that is part of the contract too
        self._n_shards = getattr(model, "n_shards", None)

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def current(self):
        """The live model (convenience; use ``get`` when the matching
        version number matters)."""
        with self._lock:
            return self._model

    def get(self) -> Tuple[object, int]:
        """Consistent (model, version) snapshot — THE read path: take it
        once per batch so all work in the batch serves one version."""
        with self._lock:
            return self._model, self._version

    def publish(self, model) -> int:
        """Atomically swap in a new model; returns its version number.

        In-flight readers keep the snapshot they took; only subsequent
        ``get``/``current`` calls see the new model.
        """
        if not isinstance(model, self._kind):
            raise TypeError(
                f"handle serves {self._kind.__name__}, got "
                f"{type(model).__name__}")
        if self._n_shards is not None and model.n_shards != self._n_shards:
            raise ValueError(
                f"handle serves a {self._n_shards}-shard model (the "
                f"engine's mesh is pinned to it), got {model.n_shards} "
                f"shards — re-shard behind a new engine instead")
        with self._lock:
            self._model = model
            self._version += 1
            return self._version

    def refresh(self, alpha) -> int:
        """Publish the current model rebuilt around live dual coefficients
        (``repro.core.oos.refresh_coefficients`` — reuses the cached
        kernel-mean statistics). Returns the new version.

        Plain ``FittedKpca`` handles only; per-shard refresh of a
        ``ShardedFittedKpca`` is a ROADMAP follow-up (build the refreshed
        model yourself and ``publish`` it meanwhile)."""
        with self._lock:
            base = self._model
        return self.publish(oos.refresh_coefficients(base, alpha))


def stream_chunks(chunks: Iterable, handle: ModelHandle,
                  every: int = 1) -> Optional[object]:
    """Drive a ``repro.core.solver.run_chunked`` iterator to completion,
    refreshing ``handle`` from the live state every ``every`` chunks (and
    always at the last chunk). Returns the final ``ChunkResult`` (None if
    the iterator was empty)."""
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")
    last = None
    pending = False
    for i, chunk in enumerate(chunks):
        last = chunk
        pending = True
        if (i + 1) % every == 0:
            handle.refresh(chunk.state.alpha)
            pending = False
    if last is not None and pending:
        handle.refresh(last.state.alpha)
    return last


__all__ = ["ModelHandle", "stream_chunks"]
