"""Jitted public wrapper around the Gram Pallas kernel.

Handles padding to block multiples (zero-padding the feature axis is exact:
dot products and squared norms are unchanged; padded rows/cols are sliced
off), self-kernel/sq-norm precomputation, gamma resolution and backend
dispatch (interpret=True everywhere except real TPU).

Tile sizes default to the autotuner's table (``repro.kernels.autotune``)
keyed by (op, shape-bucket, dtype, backend); explicit ``block_*`` kwargs
override, and an untuned key falls back to the historical 128x128x512."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.kernels_math import KernelSpec, resolve_gamma, _self_k
from ..autotune import get_tiles
from .._util import _on_tpu, _pad_to, _round_up
from .gram import gram_tiles


def gram_op(spec: KernelSpec, x: jax.Array, y: Optional[jax.Array] = None,
            gamma: Optional[jax.Array] = None,
            block_n: Optional[int] = None, block_k: Optional[int] = None,
            block_m: Optional[int] = None,
            interpret: Optional[bool] = None) -> jax.Array:
    """Gram matrix K[i, j] = K(x_i, y_j) via the Pallas kernel.

    Matches ``repro.kernels.gram.ref.gram_reference`` (tested across shapes
    and dtypes in tests/test_kernels_gram.py).
    """
    if y is None:
        y = x
    if interpret is None:
        interpret = not _on_tpu()
    if block_n is None or block_k is None or block_m is None:
        tiles = get_tiles("gram", (x.shape[0], y.shape[0], x.shape[1]),
                          x.dtype)
        block_n = block_n or tiles["block_n"]
        block_k = block_k or tiles["block_k"]
        block_m = block_m or tiles["block_m"]
    if spec.kind == "rbf":
        g = resolve_gamma(spec, x) if gamma is None else jnp.asarray(gamma)
        sx = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
        sy = jnp.sum(y.astype(jnp.float32) ** 2, axis=-1)
    else:
        g = jnp.zeros((), jnp.float32)
        sx = _self_k(spec, x.astype(jnp.float32))
        sy = _self_k(spec, y.astype(jnp.float32))
    n, k = x.shape[0], y.shape[0]
    # adapt block sizes for small problems (interpret/test shapes)
    bn = min(block_n, _round_up(n, 8))
    bk = min(block_k, _round_up(k, 8))
    bm = min(block_m, _round_up(x.shape[1], 128))
    xp = _pad_to(_pad_to(x, bm, 1), bn, 0)
    yp = _pad_to(_pad_to(y, bm, 1), bk, 0)
    sxp = _pad_to(sx, bn, 0)
    syp = _pad_to(sy, bk, 0)
    out = gram_tiles(xp, yp, sxp, syp, jnp.reshape(g, (1,)).astype(jnp.float32),
                     kind=spec.kind, degree=spec.degree, coef=spec.coef,
                     scale=spec.scale, normalize=spec.normalize,
                     block_n=bn, block_k=bk, block_m=bm, interpret=interpret)
    return out[:n, :k]
