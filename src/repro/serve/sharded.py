"""Multi-device sharded kPCA projection serving with adaptive routing.

The out-of-sample score is a sum over support points (paper §1), so it
shards embarrassingly along EITHER operand of the kernel matrix — and the
two choices have opposite communication shapes:

  * **model-parallel** (``"mp"``): each device holds one slice of a
    ``ShardedFittedKpca`` — a contiguous block of support rows and the
    matching dual-coefficient rows — and computes the raw partial

        P_j = K(X_query, X_j) @ coefs_ext_j          # (B, C+1)

    with the fused projection kernel
    (``repro.kernels.project.project_partial_op``; the extra column is the
    raw kernel row-sum via the indicator column). Partials are
    ``psum``-reduced over the shard mesh axis and the GLOBAL centering
    terms (row-mean weight, bias), which depend on the full support set,
    are applied exactly once after the reduction
    (``repro.core.oos.finalize_partial_scores``). Per-query traffic is one
    (B, C+1) all-reduce regardless of support-set size — the communication
    shape COKE/Balcan-style distributed kPCA exploits. Wins when the
    support set is large relative to the batch.

  * **data-parallel** (``"dp"``): the model is replicated on every device
    and the QUERY rows are partitioned instead. No cross-device reduction
    at all — each device finishes its own rows, including the centering
    epilogue. Wins at large batches: the per-device kernel-matrix
    intermediate is 1/S the size, so it stays cache-resident where the
    single-device one spills.

  * **single-device** (``"single"``): the same-math loop-over-shards
    reduction on one device. Wins at small/compressed support sets, where
    any multi-device choreography costs more than it saves — and is the
    only choice when the host exposes fewer devices than shards.

``CrossoverTable`` picks between them per slab, keyed on (slab rows,
support rows); its defaults are measured on the CI container and
``measure_crossover`` re-measures them for a concrete model/mesh/host.
``ShardedRouter`` owns the dispatch hot path for ``KpcaEngine``: per-policy
donated jit entry points and a per-model-version placement cache, so
steady-state serving never re-transfers the model (the per-drain
replication that made BENCH_9's shards4 rows LOSE to shards1 — see
docs/PERFORMANCE.md, "sharded drain anatomy").

Live updates: a sharded model refreshes per shard
(``repro.core.oos.refresh_shard_coefficients`` — per-shard cached
kernel-mean stats, global centering rebuilt post-hoc) and is republished as
ONE atomic ``ModelHandle`` swap, so this module never sees a model whose
shards disagree about the version; the placement cache is keyed on that
version, so a publish invalidates it atomically too.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.kernels_math import gram
from ..core.oos import ShardedFittedKpca, finalize_partial_scores
from ..distributed.compat import shard_map
from ..launch.mesh import make_serving_mesh, mesh_shardings, replicate_on_mesh
from ..obs import metrics, trace

POLICIES = ("mp", "dp", "single")

# One dispatch's device result plus the routing decision that produced it
# (the engine's drain surfaces the policy in stats/trace without another
# router round trip).
ShardedScores = collections.namedtuple("ShardedScores", "scores policy")


def _shard_partial(spec, xq, xs, coefs_ext, gamma, use_pallas, interpret):
    """One shard's raw (B, C+1) partial: K(xq, xs) @ coefs_ext."""
    if use_pallas:
        from ..kernels.project import project_partial_op
        return project_partial_op(spec, xq, xs, coefs_ext, gamma=gamma,
                                  interpret=interpret)
    return gram(spec, xq, xs, gamma=gamma) @ coefs_ext


def _pow2(n: int, floor: int = 8) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class CrossoverTable:
    """Routing decision table: (slab rows, support rows) -> policy.

    ``table`` holds MEASURED winners keyed by pow2-bucketed
    (rows, support) pairs (``measure_crossover`` fills it for a concrete
    model/mesh/host). Unmeasured keys fall back to two thresholds whose
    defaults come from the 1-core CI container sweep behind BENCH_10:

      * support <= ``single_max_support``: the single-device reduction wins
        — at small/compressed support sets (e.g. the lm64 rows) every
        multi-device choreography costs more than it saves;
      * above that, slabs with >= ``dp_min_rows`` rows go data-parallel
        (per-device kernel intermediates stay cache-resident), smaller
        slabs go model-parallel (support slicing is the only useful cut).

    Data-parallel additionally requires the row count to divide evenly
    over the shards (``shard_map`` partitions the leading axis exactly);
    pow2 slab buckets make that automatic on pow2 shard counts, and
    ``choose`` degrades to "mp"/"single" otherwise.
    """

    single_max_support: int = 2048
    dp_min_rows: int = 2048
    table: Mapping[Tuple[int, int], str] = \
        dataclasses.field(default_factory=dict)

    def choose(self, n_rows: int, n_support: int, n_shards: int, *,
               has_mesh: bool) -> str:
        if not has_mesh or n_shards <= 1:
            return "single"
        policy = self.table.get((_pow2(n_rows), _pow2(n_support)))
        if policy is None:
            if n_support <= self.single_max_support:
                policy = "single"
            elif n_rows >= self.dp_min_rows:
                policy = "dp"
            else:
                policy = "mp"
        if policy == "dp" and n_rows % n_shards:
            policy = "mp" if n_support > self.single_max_support \
                else "single"
        return policy


class ShardedRouter:
    """Policy-routed, placement-cached dispatch for sharded serving.

    Owns the three pieces the engine's sharded hot path needs:

      * ``choose``: the per-slab routing decision (``CrossoverTable``, or
        a forced policy for benchmarking/parity tests);
      * a per-policy jitted entry point, compiled once per slab bucket with
        the query slab donated (``donate_argnums``) exactly like the
        single-device path;
      * a placement cache keyed on the model VERSION: "mp" wants the
        per-shard arrays one slice per device, "dp" wants the whole model
        replicated, and both placements are paid once per publish instead
        of once per drain — re-transferring the model every call is what
        made sharded serving lose to one shard before this layer existed.

    Thread-safety: ``dispatch`` runs on the engine's single device-runner
    thread (or under its dispatch lock), so the internal lock only guards
    the placement dict against the measure/warmup paths; a racy duplicate
    placement is wasted work, never wrong results.
    """

    _GROUPS = {"mp": "sliced", "dp": "replicated", "single": None}

    def __init__(self, mesh, *, use_pallas: bool = False,
                 interpret: Optional[bool] = None, policy: str = "auto",
                 crossover: Optional[CrossoverTable] = None,
                 donate: bool = True):
        if policy != "auto" and policy not in POLICIES:
            raise ValueError(f"policy must be 'auto' or one of {POLICIES}, "
                             f"got {policy!r}")
        self.mesh = mesh
        self.policy = policy
        self.crossover = crossover if crossover is not None \
            else CrossoverTable()
        self.donate = donate
        self._use_pallas = use_pallas
        self._interpret = interpret
        self._lock = threading.Lock()
        self._placed: Dict[str, Tuple[int, ShardedFittedKpca]] = {}
        self.n_placements = 0              # placement-cache fill count
        self._entries: Dict[str, object] = {}
        self._m_routed = {p: metrics.counter(
            "serve_routing_total",
            "Sharded slabs dispatched, by routing policy", policy=p)
            for p in POLICIES}

    # -- routing ------------------------------------------------------------

    def choose(self, n_rows: int, model: ShardedFittedKpca) -> str:
        """The policy this slab will dispatch under (deterministic in
        (rows, model) — warmup relies on that to pre-compile exactly the
        programs traffic will hit)."""
        has_mesh = self.mesh is not None
        if self.policy == "auto":
            return self.crossover.choose(n_rows, int(model.n_support),
                                         model.n_shards, has_mesh=has_mesh)
        if not has_mesh or model.n_shards <= 1:
            return "single"
        if self.policy == "dp" and n_rows % model.n_shards:
            return "mp"
        return self.policy

    # -- placement cache ----------------------------------------------------

    def _place(self, model: ShardedFittedKpca, version: int, policy: str):
        group = self._GROUPS[policy]
        if group is None:        # single-device: the model's home placement
            return model
        with self._lock:
            hit = self._placed.get(group)
            if hit is not None and hit[0] == version:
                return hit[1]
        # Build OUTSIDE the lock: device_put moves real bytes, and a racy
        # duplicate placement is idempotent (same values, last write wins).
        placed = place_sharded_model(model, self.mesh) \
            if group == "sliced" else replicate_on_mesh(model, self.mesh)
        with self._lock:
            self._placed[group] = (version, placed)
            self.n_placements += 1
        return placed

    # -- jitted entry points ------------------------------------------------

    def _build(self, policy: str):
        mesh, up, ip = self.mesh, self._use_pallas, self._interpret

        if policy == "mp":
            def f(m, xq):
                parts = _partials_shard_map(m, xq, mesh, up, ip)
                return finalize_partial_scores(parts, m.row_mean_coef,
                                               m.bias, m.n_support)
        elif policy == "dp":
            def f(m, xq):
                return _scores_data_parallel(m, xq, mesh, up, ip)
        else:
            def f(m, xq):
                parts = _partials_local(m, xq, up, ip)
                return finalize_partial_scores(parts, m.row_mean_coef,
                                               m.bias, m.n_support)
        if self.donate:
            return jax.jit(f, donate_argnums=(1,))
        return jax.jit(f)

    def dispatch(self, model: ShardedFittedKpca, version: int, xq,
                 policy: Optional[str] = None) -> ShardedScores:
        """Route one staged slab: pick/honor the policy, fetch the cached
        placement for this model version, call the policy's jitted entry
        point (slab donated). Returns the DEVICE scores plus the policy —
        the blocking device->host read stays with the caller so pipelined
        drains overlap it with the next dispatch."""
        if policy is None:
            policy = self.choose(int(xq.shape[0]), model)
        placed = self._place(model, version, policy)
        entry = self._entries.get(policy)
        if entry is None:
            entry = self._entries.setdefault(policy, self._build(policy))
        with trace.span("serve.shard_dispatch", policy=policy,
                        rows=int(xq.shape[0])):
            out = entry(placed, xq)
        self._m_routed[policy].inc()
        return ShardedScores(out, policy)


def place_sharded_model(model: ShardedFittedKpca,
                        mesh) -> ShardedFittedKpca:
    """Pin one ``ShardedFittedKpca`` onto a 1-D mesh, field-precise: the
    per-shard arrays (leading axis S — support slices, coefficient rows,
    cached kernel means) get one slice per device; the global centering
    terms and scalars are replicated. Field names, not a leading-dim
    heuristic: ``bias`` is (C,) and C can coincide with S."""
    sliced, replicated = mesh_shardings(mesh)

    def put(leaf, sharding):
        return None if leaf is None else jax.device_put(leaf, sharding)

    return dataclasses.replace(
        model,
        x_support=put(model.x_support, sliced),
        coefs_ext=put(model.coefs_ext, sliced),
        k_row_mean=put(model.k_row_mean, sliced),
        row_mean_coef=put(model.row_mean_coef, replicated),
        bias=put(model.bias, replicated),
        gamma=put(model.gamma, replicated),
        k_grand_mean=put(model.k_grand_mean, replicated))


def measure_crossover(model: ShardedFittedKpca, *, mesh=None,
                      row_buckets=(256, 1024, 4096), reps: int = 3,
                      use_pallas: bool = False,
                      interpret: Optional[bool] = None) -> CrossoverTable:
    """Time every feasible policy at each row bucket for THIS model on
    THIS host and return a ``CrossoverTable`` whose measured entries pin
    the winners (unmeasured keys keep the threshold defaults).

    Slabs are zeros: the kernel math is data-independent in cost, and the
    measurement wants placement + compute + gather, exactly what a drain
    pays. Compile time is excluded by an untimed first call per policy.
    """
    if mesh is None:
        mesh = make_serving_mesh(model.n_shards)
    router = ShardedRouter(mesh, use_pallas=use_pallas, interpret=interpret,
                           donate=False)
    support_key = _pow2(int(model.n_support))
    table = {}
    for rows in row_buckets:
        xq = np.zeros((int(rows), model.n_features), np.float32)
        best, best_t = "single", float("inf")
        for policy in POLICIES:
            if policy != "single" and mesh is None:
                continue
            if policy == "dp" and rows % model.n_shards:
                continue
            np.asarray(router.dispatch(model, 0, xq, policy).scores)
            t = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                np.asarray(router.dispatch(model, 0, xq, policy).scores)
                t = min(t, time.perf_counter() - t0)
            if t < best_t:
                best, best_t = policy, t
        table[(_pow2(int(rows)), support_key)] = best
    return CrossoverTable(table=table)


def project_sharded(model: ShardedFittedKpca, x_query: jax.Array, *,
                    mesh=None, axis_name: str = "shard",
                    use_pallas: bool = False,
                    interpret: Optional[bool] = None,
                    policy: str = "mp",
                    crossover: Optional[CrossoverTable] = None) -> jax.Array:
    """Sharded centered out-of-sample scores: (B, M) -> (B, C).

    Args:
      model: sharded artifact (see ``repro.core.oos.shard_fitted``).
      x_query: (B, M) query batch.
      mesh: 1-D ``jax.sharding.Mesh`` whose single axis has size
        ``model.n_shards``. None = build one over the first n_shards local
        devices, falling back to the single-device reduction when the
        machine has fewer devices than shards.
      axis_name: mesh axis to reduce over (when building the default mesh).
      use_pallas: per-shard partials via the fused Pallas kernel instead of
        the dense jnp path.
      interpret: forwarded to the Pallas wrapper.
      policy: "mp" (default — queries replicated, support sharded, psum),
        "dp" (query rows sharded, model replicated, no reduction),
        "single" (loop-over-shards on one device), or "auto" (route via
        ``crossover``). Infeasible choices (no mesh; "dp" with a row count
        that doesn't divide over the shards) degrade to the same-math
        fallback instead of raising.
      crossover: routing table for ``policy="auto"`` (None: defaults).

    Returns:
      (B, C) float32 scores, equal to ``oos.project(gather_fitted(model))``
      to fp32 tolerance for every policy (tests/test_sharded_serving.py).
    """
    x_query = jnp.asarray(x_query)
    if policy != "auto" and policy not in POLICIES:
        raise ValueError(f"policy must be 'auto' or one of {POLICIES}, "
                         f"got {policy!r}")
    if mesh is None:
        mesh = make_serving_mesh(model.n_shards, axis_name)
    if policy == "auto":
        policy = (crossover if crossover is not None else CrossoverTable()) \
            .choose(int(x_query.shape[0]), int(model.n_support),
                    model.n_shards, has_mesh=mesh is not None)
    if policy == "dp" and mesh is not None \
            and x_query.shape[0] % model.n_shards == 0:
        return _scores_data_parallel(model, x_query, mesh, use_pallas,
                                     interpret)
    if mesh is None or policy == "single":
        partials = _partials_local(model, x_query, use_pallas, interpret)
    else:                                 # "mp" (and infeasible-"dp")
        partials = _partials_shard_map(model, x_query, mesh, use_pallas,
                                       interpret)
    return finalize_partial_scores(partials, model.row_mean_coef,
                                   model.bias, model.n_support)


def _partials_shard_map(model: ShardedFittedKpca, x_query: jax.Array, mesh,
                        use_pallas: bool,
                        interpret: Optional[bool]) -> jax.Array:
    """psum-reduced (B, C+1) partials over the mesh's shard axis."""
    (axis_name,) = mesh.axis_names
    spec = model.spec

    def fn(xs, ae, xq, g):
        # xs (1, Lp, M), ae (1, Lp, C+1): this device's shard slice.
        part = _shard_partial(spec, xq, xs[0], ae[0], g, use_pallas,
                              interpret)
        return jax.lax.psum(part, axis_name)

    f = shard_map(fn, mesh=mesh,
                  in_specs=(P(axis_name), P(axis_name), P(None, None), P()),
                  out_specs=P(None, None), check_vma=False)
    return f(model.x_support, model.coefs_ext, x_query, model.gamma)


def _scores_data_parallel(model: ShardedFittedKpca, x_query: jax.Array,
                          mesh, use_pallas: bool,
                          interpret: Optional[bool]) -> jax.Array:
    """Data-parallel FULL scores: query rows partitioned over the mesh,
    model replicated, each device running the complete loop-over-shards
    reduction AND the centering epilogue on its own rows. No psum — row
    independence of the score math is what makes the cut free."""
    (axis_name,) = mesh.axis_names
    spec, n_shards = model.spec, model.n_shards
    n_support = model.n_support

    def fn(xs, ae, xq, g, rmc, bias):
        total = jnp.zeros((xq.shape[0], ae.shape[2]), jnp.float32)
        for j in range(n_shards):
            total = total + _shard_partial(spec, xq, xs[j], ae[j], g,
                                           use_pallas, interpret)
        return finalize_partial_scores(total, rmc, bias, n_support)

    f = shard_map(fn, mesh=mesh,
                  in_specs=(P(), P(), P(axis_name, None), P(), P(), P()),
                  out_specs=P(axis_name, None), check_vma=False)
    return f(model.x_support, model.coefs_ext, x_query, model.gamma,
             model.row_mean_coef, model.bias)


def _partials_local(model: ShardedFittedKpca, x_query: jax.Array,
                    use_pallas: bool,
                    interpret: Optional[bool]) -> jax.Array:
    """Single-device reduction: loop shards, sum partials (== psum)."""
    spec = model.spec
    total = jnp.zeros((x_query.shape[0], model.n_components + 1),
                      jnp.float32)
    for j in range(model.n_shards):
        total = total + _shard_partial(
            spec, x_query, model.x_support[j], model.coefs_ext[j],
            model.gamma, use_pallas, interpret)
    return total


__all__ = ["CrossoverTable", "POLICIES", "ShardedRouter", "ShardedScores",
           "measure_crossover", "place_sharded_model", "project_sharded"]
