from .engine import DecodeEngine, ServeConfig
from .kpca_engine import (EngineStats, KpcaEngine, KpcaServeConfig,
                          RequestStats)

__all__ = ["DecodeEngine", "EngineStats", "KpcaEngine", "KpcaServeConfig",
           "RequestStats", "ServeConfig"]
