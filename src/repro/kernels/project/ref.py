"""Pure-jnp oracle for the projection Pallas kernel — same score contract
as ``repro.core.oos.project`` (single source of numerical truth)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...core.kernels_math import KernelSpec, gram


def project_reference(spec: KernelSpec, x_query: jax.Array,
                      x_support: jax.Array, coefs: jax.Array,
                      row_mean_coef: Optional[jax.Array] = None,
                      bias: Optional[jax.Array] = None,
                      gamma: Optional[jax.Array] = None) -> jax.Array:
    """Dense oracle for ``project_op``: (B, M) x (L, M) x (L, C) -> (B, C)
    scores = K @ coefs + rowmean(K) * row_mean_coef + bias."""
    k = gram(spec, x_query, x_support, gamma=gamma)
    out = k @ coefs
    if row_mean_coef is not None:
        out = out + jnp.mean(k, axis=1, keepdims=True) * row_mean_coef[None]
    if bias is not None:
        out = out + bias[None, :]
    return out


def project_partial_reference(spec: KernelSpec, x_query: jax.Array,
                              x_support: jax.Array, coefs_ext: jax.Array,
                              gamma: Optional[jax.Array] = None) -> jax.Array:
    """Dense oracle for ``project_partial_op``: raw (B, C+1) partials
    K(x_query, x_support) @ coefs_ext with no centering epilogue. The last
    column of ``coefs_ext`` is the valid-row indicator, so the last output
    column is the raw kernel row-sum over valid support rows."""
    k = gram(spec, x_query, x_support, gamma=gamma)
    return k @ coefs_ext
