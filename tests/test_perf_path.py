"""Tests for the dispatch-gap performance path: donated jit entry
points, start()-time warmup, the ``SlabArena`` staging ring, the
``RequestQueue.coalesce`` arrival damper, and the Pallas tile-table
autotuner plumbing (``repro.kernels.autotune``).

Correctness bar: every fast path must be bitwise-identical to the plain
path it replaces — donation, arena staging, and warmup are dispatch
optimizations, not numerics changes.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, oos
from repro.kernels import autotune
from repro.serve import KpcaEngine, KpcaServeConfig
from repro.serve.batching import RequestQueue, SlabArena

SPEC = KernelSpec(kind="rbf", gamma=0.25)
WAIT = 30.0

# Instrument every serve-layer lock and fail on a recorded AB/BA
# acquisition cycle (tests/helpers/lockcheck.py).
pytestmark = pytest.mark.lockcheck


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def model():
    x = jnp.asarray(_rand((48, 12), seed=0))
    return oos.fit_central(x, SPEC, n_components=2, center=True)


class TestDonationParity:
    def test_donated_scores_bitwise_equal_plain(self, model):
        reqs = [_rand((int(q), 12), seed=10 + i)
                for i, q in enumerate([3, 8, 1, 17, 32, 5])]
        plain = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, donate=False, warmup=False))
        donated = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, donate=True, warmup=False))
        out_p = plain.project_many([r.copy() for r in reqs])
        out_d = donated.project_many([r.copy() for r in reqs])
        for a, b in zip(out_p, out_d):
            np.testing.assert_array_equal(a, b)

    def test_donation_does_not_clobber_caller_arrays(self, model):
        xq = _rand((8, 12), seed=3)
        keep = xq.copy()
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, donate=True, warmup=False))
        eng.project_many([xq])
        np.testing.assert_array_equal(xq, keep)

    def test_donated_flushes_counted(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, donate=True, warmup=False))
        eng.project_many([_rand((4, 12))])
        assert eng.stats.n_donated >= 1
        eng2 = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, donate=False, warmup=False))
        eng2.project_many([_rand((4, 12))])
        assert eng2.stats.n_donated == 0


class TestWarmup:
    def test_start_compiles_every_bucket_once(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=32, min_bucket=8))
        built = eng.warmup()
        assert built == len(eng.cfg.buckets())
        assert eng.stats.n_warmup_compiles == built
        assert eng.warmup() == 0                 # idempotent per shape

    def test_steady_state_traffic_never_compiles(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=32, min_bucket=8))
        with eng:                                # start() warms by default
            futs = [eng.submit(_rand((int(q), 12), seed=q))
                    for q in (1, 7, 8, 9, 20, 32, 2, 15)]
            for f in futs:
                f.result(timeout=WAIT)
        assert eng.stats.n_warmup_compiles == len(eng.cfg.buckets())
        assert eng.stats.n_compiles == 0

    def test_warmup_off_compiles_lazily(self, model):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, warmup=False))
        eng.project_many([_rand((4, 12))])
        assert eng.stats.n_warmup_compiles == 0
        assert eng.stats.n_compiles == 1


class TestSlabArena:
    def test_fifo_release_reuses_rows(self):
        a = SlabArena(n_features=4, capacity_rows=16)
        s0 = a.stage(_rand((6, 4), seed=0))
        s1 = a.stage(_rand((6, 4), seed=1))
        assert (s0, s1) == (0, 6)
        a.release(s0)
        a.release(s1)
        s2 = a.stage(_rand((5, 4), seed=2))
        assert s2 == 0                           # empty ring resets
        assert a.stats()["n_reused_rows"] >= 5

    def test_wraps_into_released_prefix(self):
        a = SlabArena(n_features=4, capacity_rows=16)
        s0 = a.stage(_rand((10, 4), seed=0))
        s1 = a.stage(_rand((4, 4), seed=1))
        a.release(s0)                            # head pops, tail run lives
        s2 = a.stage(_rand((8, 4), seed=2))      # tail space (2) too small
        assert s2 == 0 and s1 == 10              # wrapped before live run
        assert a.stats()["live_runs"] == 2

    def test_full_ring_falls_back(self):
        a = SlabArena(n_features=4, capacity_rows=8)
        assert a.stage(_rand((8, 4))) == 0
        assert a.stage(_rand((1, 4))) is None    # full: caller keeps copy
        assert a.stage(_rand((9, 4))) is None    # oversize: never fits
        assert a.stats()["n_fallback"] == 2

    def test_staged_rows_hold_exact_payload(self):
        a = SlabArena(n_features=3, capacity_rows=12)
        x = _rand((5, 3), seed=7)
        start = a.stage(x)
        np.testing.assert_array_equal(a.buf[start:start + 5], x)

    def test_frame_pool_reuses_buffers(self):
        a = SlabArena(n_features=4, capacity_rows=8)
        f = a.acquire_frame(16)
        a.release_frame(f)
        assert a.acquire_frame(16) is f
        assert a.stats()["n_frame_allocs"] == 1

    def test_concurrent_submitters_no_stale_rows(self, model):
        """Hammer one engine from several threads; every request's scores
        must match its own direct projection — a stale or cross-wired
        arena row would corrupt exactly this."""
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=32, min_bucket=8, flush_max_wait_s=0.001))
        reqs = [_rand((1 + i % 9, 12), seed=100 + i) for i in range(48)]
        oracle = [np.asarray(oos.project(model, jnp.asarray(r)))
                  for r in reqs]
        got = [None] * len(reqs)

        def submitter(tid):
            for i in range(tid, len(reqs), 4):
                got[i] = eng.submit(reqs[i]).result(timeout=WAIT)

        with eng:
            threads = [threading.Thread(target=submitter, args=(t,))
                       for t in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        for g, o in zip(got, oracle):
            np.testing.assert_allclose(g, o, rtol=1e-5, atol=1e-5)
        assert eng.stats.n_zero_copy_slabs > 0   # arena path actually ran


class TestCoalesce:
    def test_noop_on_empty_queue(self):
        q = RequestQueue()
        t0 = time.perf_counter()
        q.coalesce(64, 0.05, threading.Event())
        assert time.perf_counter() - t0 < 0.04   # returned without stalling

    def test_noop_when_batch_already_full(self):
        q = RequestQueue()
        q.put(_rand((4, 2)), n=4)
        t0 = time.perf_counter()
        q.coalesce(4, 0.05, threading.Event())
        assert time.perf_counter() - t0 < 0.04

    def test_collects_arrivals_until_stall(self):
        q = RequestQueue()
        q.put(_rand((1, 2)), n=1)

        def late_submits():
            for i in range(3):
                # deliberate pacing: arrivals must trickle INTO the stall
                # window, which is the behavior under test
                time.sleep(0.002)  # repro-lint: disable=sleep-in-test
                q.put(_rand((1, 2)), n=1)

        t = threading.Thread(target=late_submits)
        t.start()
        q.coalesce(64, 0.01, threading.Event())
        t.join()
        assert q.depth == 4                      # the whole wave landed

    def test_stop_event_breaks_out(self):
        q = RequestQueue()
        q.put(_rand((1, 2)), n=1)
        stop = threading.Event()
        stop.set()
        t0 = time.perf_counter()
        q.coalesce(64, 1.0, stop)
        assert time.perf_counter() - t0 < 0.5


class TestTileTable:
    def test_round_trip(self, tmp_path):
        t = autotune.TileTable()
        key = t.put("gram", (100, 100, 60), np.float32, "cpu",
                    {"block_n": 64, "block_k": 64, "block_m": 256}, 1.5e-4)
        path = tmp_path / "tiles.json"
        t.save(str(path))
        loaded = autotune.TileTable.load(str(path))
        assert len(loaded) == 1 and key in loaded.entries
        hit = loaded.lookup("gram", (100, 100, 60), np.float32, "cpu")
        assert hit == {"block_n": 64, "block_k": 64, "block_m": 256}

    def test_lookup_buckets_shapes_pow2(self):
        t = autotune.TileTable()
        t.put("gram", (128, 128, 64), np.float32, "cpu",
              {"block_n": 32, "block_k": 32, "block_m": 128}, 1e-4)
        # 100 and 65 bucket to 128; 60 buckets to 64 -> same key
        assert t.lookup("gram", (100, 65, 60), np.float32, "cpu") \
            is not None
        assert t.lookup("gram", (256, 128, 64), np.float32, "cpu") is None

    def test_get_tiles_falls_back_to_defaults(self):
        tiles = autotune.get_tiles("gram", (64, 64, 32), np.float32,
                                   table=autotune.TileTable())
        assert tiles == autotune.DEFAULT_TILES["gram"]

    def test_get_tiles_prefers_table_hit(self):
        t = autotune.TileTable()
        t.put("project", (64, 48, 12), np.float32, "cpu",
              {"block_q": 32}, 1e-4)
        tiles = autotune.get_tiles("project", (64, 48, 12), np.float32,
                                   table=t)
        assert tiles["block_q"] == 32            # tuned dim wins
        assert tiles["block_l"] == \
            autotune.DEFAULT_TILES["project"]["block_l"]  # rest default

    def test_version_mismatch_rejected(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text('{"version": 999, "entries": {}}')
        with pytest.raises(ValueError, match="version"):
            autotune.TileTable.load(str(path))

    def test_env_var_loads_process_table(self, tmp_path, monkeypatch):
        t = autotune.TileTable()
        t.put("centering", (64, 64), np.float32, "cpu", {"block": 64}, 1e-4)
        path = tmp_path / "env_tiles.json"
        t.save(str(path))
        monkeypatch.setenv(autotune.TABLE_ENV_VAR, str(path))
        autotune.set_default_table(None)         # force a re-read
        try:
            hit = autotune.default_table().lookup(
                "centering", (64, 64), np.float32, "cpu")
            assert hit == {"block": 64}
        finally:
            autotune.set_default_table(None)     # don't leak into others
