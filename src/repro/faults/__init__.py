"""Deterministic fault injection + the recovery machinery it exercises.

The paper's premise is a fusion-center-free deployment where any node
can vanish; this package makes that a first-class, TESTED property
instead of a simulation done before the run starts. Three layers:

- ``plan``: seeded, immutable :class:`FaultPlan` — what fails and when
  (node dropout, link loss/delay, straggler stalls, shard loss,
  publisher crashes). Same seed ⇒ same faults ⇒ same trajectory.
- ``comm`` + ``driver``: solver-side injection (``FaultyComm``
  transport censoring, per-iteration slot masks) and recovery
  (:class:`FaultTolerantRun` — re-knit, state shrink, warm
  continuation).
- ``serving``: engine-side injection/recovery (shard loss +
  exactly-once re-balance publish, publisher crashes, transient
  faults for the retry path).

``errors``/``plan``/``comm`` are import-cycle leaves (``core.solver``
lazily imports ``faults.comm``); ``driver`` and ``serving`` pull in the
solver/serving stacks and load lazily via module ``__getattr__``.

See docs/FAULT_TOLERANCE.md for schema, semantics and guarantees.
"""

from .comm import FaultyComm
from .errors import (DeadlineExceededError, FaultError, InjectedCrashError,
                     NodeDownError, ShardLostError)
from .plan import (FaultPlan, LinkFault, NodeDropout, PublisherCrash,
                   ShardLoss, StragglerStall, link_delay)

_LAZY = {
    "FaultTolerantRun": "driver",
    "FaultEventRecord": "driver",
    "run_chunked_with_faults": "driver",
    "shrink_state": "driver",
    "ShardLossInjector": "serving",
    "ShardRebalancer": "serving",
    "CrashingHandle": "serving",
    "transient_faults": "serving",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)


__all__ = [
    "FaultError", "ShardLostError", "DeadlineExceededError",
    "InjectedCrashError", "NodeDownError",
    "FaultPlan", "NodeDropout", "LinkFault", "StragglerStall", "ShardLoss",
    "PublisherCrash", "link_delay", "FaultyComm",
    *sorted(_LAZY),
]
