"""Pallas TPU kernel: fused local ADMM update (paper eq. 12-13).

Per node and iteration, after the Z-exchange, the *local* math is a chain of
small matmuls over the same N x N operands:

    rhs   = sum_s (rho_s G[:, s] - B[:, s])
    alpha = V diag(inv_den) V^T rhs          (eigh-factorized eq. 12 solve)
    ka    = K alpha
    B'    = B + rho_s (ka 1^T - G)           (eq. 13)

Unfused, each step round-trips N^2/N*S data through HBM. This kernel keeps
V, K, B, G resident in VMEM and performs the whole chain in one invocation —
one read of each operand, one write of (alpha, B'). N_j <= 1024 keeps
V + K + scratch within the ~16 MB VMEM budget (2 * 4 MB fp32 + tiles).
The grid iterates over nodes so the same kernel serves the vmapped
simulator and the per-device shard_map path (J_local = 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _admm_kernel(v_ref, invd_ref, k_ref, b_ref, g_ref, rho_ref,
                 alpha_ref, bout_ref):
    v = v_ref[0]                                   # (N, N)
    k = k_ref[0]                                   # (N, N)
    b = b_ref[0]                                   # (N, S)
    g = g_ref[0]                                   # (N, S)
    invd = invd_ref[0]                             # (N, 1)
    rho = rho_ref[0]                               # (1, S)

    rhs = jnp.sum(rho * g - b, axis=1, keepdims=True)          # (N, 1)
    t = jax.lax.dot_general(v, rhs, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # V^T rhs
    t = t * invd
    alpha = jnp.dot(v, t, preferred_element_type=jnp.float32)   # (N, 1)
    ka = jnp.dot(k, alpha, preferred_element_type=jnp.float32)  # (N, 1)
    alpha_ref[0] = alpha
    bout_ref[0] = b + rho * (ka - g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def admm_local_update(v: jax.Array, inv_den: jax.Array, k: jax.Array,
                      b: jax.Array, g: jax.Array, rho_slots: jax.Array,
                      *, interpret: bool = False):
    """Fused eq. 12-13. Shapes: v,k (J,N,N); inv_den (J,N,1); b,g (J,N,S);
    rho_slots (J,1,S). Returns (alpha (J,N,1), b_new (J,N,S))."""
    j, n, _ = v.shape
    s = b.shape[-1]
    whole = lambda shape: pl.BlockSpec((1,) + shape, lambda i: (i,) + (0,) * len(shape))
    return pl.pallas_call(
        _admm_kernel,
        grid=(j,),
        in_specs=[whole((n, n)), whole((n, 1)), whole((n, n)),
                  whole((n, s)), whole((n, s)), whole((1, s))],
        out_specs=[whole((n, 1)), whole((n, s))],
        out_shape=[jax.ShapeDtypeStruct((j, n, 1), jnp.float32),
                   jax.ShapeDtypeStruct((j, n, s), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(v, inv_den, k, b, g, rho_slots)
