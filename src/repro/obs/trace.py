"""Low-overhead span tracer with Chrome-trace/Perfetto export.

The flight recorder for the serving + solver stack: instrumented code
brackets work in named spans —

    from repro.obs import trace

    with trace.span("serve.pack", requests=len(entries)):
        slabs = list(iter_slabs(...))

and a run launched with ``--trace-out trace.json`` (``launch/serve_kpca``,
``launch/train``, ``benchmarks/run``) writes every recorded span as a
Chrome-trace JSON that ``chrome://tracing`` or https://ui.perfetto.dev
renders as a per-thread timeline (docs/OBSERVABILITY.md lists the span
taxonomy).

Design constraints, in order:

  1. **Zero-cost when disabled.** Tracing is off by default; ``span()``
     then returns one process-wide no-op context-manager singleton —
     no span object, no buffer append, no lock. The hot serving path
     pays a function call and an identity ``with``.
  2. **Bounded memory.** Events land in a fixed-capacity ring buffer
     (latest wins); a long-running server can trace forever and export
     the most recent window. ``n_dropped`` counts overwritten events.
  3. **Thread-safe, monotonic.** Spans may open/close on any thread;
     timestamps come from ``time.perf_counter_ns`` (monotonic, ns), and
     the buffer append is one short lock acquisition per *completed*
     span — never held while user code runs.

Spans must be entered via ``with`` — a span created and never exited is
never recorded and corrupts the nesting the viewer reconstructs from
timestamps. The repro-lint rule ``span-not-closed`` enforces this
statically (docs/STATIC_ANALYSIS.md).

For durations that do not nest on one thread (e.g. a request's
queue-wait measured between the submitter thread and the flusher
thread), ``complete(name, duration_s)`` records an already-finished
span ending now; ``instant(name)`` records a point event.
"""

from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

_DEFAULT_CAPACITY = 65536


class _NoopSpan:
    """Identity context manager returned by ``span()`` while tracing is
    disabled: one process-wide instance, allocation-free per call."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def annotate(self, **attrs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One live span: records a complete ("X") event on ``__exit__`` —
    including on the exception path, so a raising body still closes its
    span and the trace tree stays well-formed."""

    __slots__ = ("_tracer", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._t0 = 0

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        end = time.perf_counter_ns()
        self._tracer._record("X", self.name, self._t0, end - self._t0,
                             self.attrs)
        return False

    def annotate(self, **attrs) -> "Span":
        """Attach attributes discovered mid-span (exported as ``args``)."""
        self.attrs.update(attrs)
        return self


def _json_safe(v):
    return v if isinstance(v, (str, int, float, bool, type(None))) else str(v)


class Tracer:
    """Thread-safe fixed-capacity ring buffer of trace events.

    Use the module-level API (``enable``/``span``/``export``) for the
    process-wide tracer; standalone instances are for tests and scoped
    measurements (e.g. the bench harness timing one suite).
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._buf: List[Optional[tuple]] = [None] * capacity
        self._pos = 0                       # events ever recorded
        self._thread_names: Dict[int, str] = {}

    # -- recording ----------------------------------------------------------

    def span(self, name: str, **attrs) -> Span:
        """A span context manager recording into THIS tracer (the module
        function routes to the process-wide tracer instead)."""
        return Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A point event (Chrome phase "i") at the current time."""
        self._record("i", name, time.perf_counter_ns(), 0, attrs)

    def complete(self, name: str, duration_s: float, **attrs) -> None:
        """An already-finished span of ``duration_s`` seconds ending NOW —
        for durations measured across threads (queue waits) or from
        foreign clocks; only the duration must be meaningful."""
        dur = max(0, int(duration_s * 1e9))
        end = time.perf_counter_ns()
        self._record("X", name, end - dur, dur, attrs)

    def _record(self, ph: str, name: str, t0_ns: int, dur_ns: int,
                attrs: Dict[str, Any]) -> None:
        th = threading.current_thread()
        with self._lock:
            if th.ident not in self._thread_names:
                self._thread_names[th.ident] = th.name
            self._buf[self._pos % self.capacity] = (
                ph, name, t0_ns, dur_ns, th.ident, attrs)
            self._pos += 1

    # -- inspection ---------------------------------------------------------

    @property
    def n_recorded(self) -> int:
        """Events ever recorded (including ones the ring overwrote)."""
        with self._lock:
            return self._pos

    @property
    def n_dropped(self) -> int:
        """Events overwritten by ring wrap-around (ring keeps the latest)."""
        with self._lock:
            return max(0, self._pos - self.capacity)

    def events(self) -> List[tuple]:
        """Surviving events, oldest first: ``(ph, name, t0_ns, dur_ns,
        tid, attrs)`` tuples."""
        with self._lock:
            if self._pos <= self.capacity:
                return list(self._buf[:self._pos])
            i = self._pos % self.capacity
            return self._buf[i:] + self._buf[:i]

    def durations(self, name: str) -> List[float]:
        """Seconds per surviving complete span named ``name`` (oldest
        first) — the snapshot the bench harness aggregates phase means
        from."""
        return [e[3] / 1e9 for e in self.events()
                if e[0] == "X" and e[1] == name]

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self.capacity
            self._pos = 0
            self._thread_names = {}

    # -- export -------------------------------------------------------------

    def to_chrome(self) -> dict:
        """The Chrome-trace JSON object: ``traceEvents`` of complete
        ("X") / instant ("i") events in microseconds plus ``thread_name``
        metadata, loadable by chrome://tracing and Perfetto."""
        with self._lock:
            names = dict(self._thread_names)
        out: List[dict] = []
        for tid, name in sorted(names.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": 0,
                        "tid": tid, "args": {"name": name}})
        for ph, name, t0, dur, tid, attrs in self.events():
            ev = {"name": name, "ph": ph, "ts": t0 / 1e3, "pid": 0,
                  "tid": tid}
            if ph == "X":
                ev["dur"] = dur / 1e3
            else:
                ev["s"] = "t"
            if attrs:
                ev["args"] = {k: _json_safe(v) for k, v in attrs.items()}
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write ``to_chrome()`` to ``path``; returns the event count."""
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f)
            f.write("\n")
        return len(doc["traceEvents"])


# ---- process-wide tracer ---------------------------------------------------

_tracer: Optional[Tracer] = None


def enable(capacity: int = _DEFAULT_CAPACITY) -> Tracer:
    """Install (and return) a fresh process-wide tracer."""
    global _tracer
    _tracer = Tracer(capacity)
    return _tracer


def disable() -> None:
    """Remove the process-wide tracer; ``span()`` reverts to the no-op."""
    global _tracer
    _tracer = None


def install(tracer: Optional[Tracer]) -> None:
    """Swap in a specific tracer instance (None = disable) — lets a scoped
    measurement (the obs bench) run on its own tracer and hand the
    original back with its events intact."""
    global _tracer
    _tracer = tracer


def is_enabled() -> bool:
    return _tracer is not None


def active() -> Optional[Tracer]:
    """The process-wide tracer, or None while disabled."""
    return _tracer


def span(name: str, **attrs):
    """A ``with``-able span on the process-wide tracer — THE instrumentation
    entry point. Returns the no-op singleton while tracing is disabled."""
    t = _tracer
    if t is None:
        return NOOP_SPAN
    return Span(t, name, attrs)


def instant(name: str, **attrs) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, **attrs)


def complete(name: str, duration_s: float, **attrs) -> None:
    t = _tracer
    if t is not None:
        t.complete(name, duration_s, **attrs)


def export(path: str) -> int:
    """Export the process-wide tracer's events (raises when disabled)."""
    t = _tracer
    if t is None:
        raise RuntimeError("tracing is not enabled (call trace.enable())")
    return t.export(path)


__all__ = ["NOOP_SPAN", "Span", "Tracer", "active", "complete", "disable",
           "enable", "export", "install", "instant", "is_enabled", "span"]
