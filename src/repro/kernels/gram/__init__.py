from .ops import gram_op
from .ref import gram_reference

__all__ = ["gram_op", "gram_reference"]
