from .checkpoint import (latest_step, restore_checkpoint, save_checkpoint,
                         save_checkpoint_async)

__all__ = ["latest_step", "restore_checkpoint", "save_checkpoint",
           "save_checkpoint_async"]
