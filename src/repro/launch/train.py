"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On real hardware the same entry point runs the full configs on the
production mesh; on CPU use --smoke (reduced config, single device)."""

from __future__ import annotations

from . import env as _env
_env.apply_from_environ()          # before any jax-importing import

import argparse
import logging


from ..configs import get_config
from ..data.tokens import TokenStream
from ..distributed.sharding import default_rules
from ..models import build_model
from ..obs import trace
from ..obs.cli import add_obs_args, obs_session
from ..optim import AdamWConfig, cosine_with_warmup
from ..train import TrainConfig, activation_probe, train
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    add_obs_args(ap)
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--probe-every", type=int, default=0)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4x2' => (data=4, model=2)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = rules = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[: len(dims)]
        mesh = make_mesh(dims, names)
        rules = default_rules(multi_pod=False)
    model = build_model(cfg, mesh=mesh)
    data = TokenStream(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                       seed=args.seed)
    opt = AdamWConfig(lr=args.lr,
                      schedule=cosine_with_warmup(args.steps // 20,
                                                  args.steps))
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, seed=args.seed,
                       probe_every=args.probe_every)
    probe = (lambda state, batch: activation_probe(
        state["params"], batch, mesh=mesh)) if args.probe_every else None
    with obs_session(args):
        with trace.span("train.run", arch=args.arch, steps=args.steps):
            state, history = train(model, opt, data, tcfg, mesh=mesh,
                                   rules=rules, probe_fn=probe)
    print(f"final loss: {history['loss'][-1]:.4f} "
          f"(first: {history['loss'][0]:.4f}); "
          f"straggler flags: {history['straggler_flags']}")


if __name__ == "__main__":
    main()
