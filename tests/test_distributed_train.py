"""Distributed-correctness subprocess tests (8 forced host devices):
- sharded (data x model) train step == single-device step
- shard_map expert-parallel MoE == dense reference
- projection-consensus compressed gradient psum ~= dense psum
- DKPCA activation probe runs over the data axis
"""

import os
import subprocess
import sys


HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "check_dp_train.py")
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(mode, marker):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, HELPER, mode], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert marker in out.stdout


def test_dp_train_step_equivalence():
    _run("dp", "DP-EQUIV-OK")


def test_moe_sharded_matches_reference():
    _run("moe", "MOE-OK")


def test_compressed_gradient_psum():
    _run("compress", "COMPRESS-OK")
