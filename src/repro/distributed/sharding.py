"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Every parameter is created with a tuple of *logical* axis names (see
``repro.models.common.ParamCollector``); a per-config rule table maps logical
axes to physical mesh axes. ``spec_for`` resolves the PartitionSpec for a
concrete shape, skipping any mapping whose mesh-axis size does not divide the
dimension (jax requires input shardings to divide evenly) and never using one
mesh axis twice within a tensor.

Conventions:
  batch   -> ("pod", "data") on the multi-pod mesh, ("data",) per pod
  heads / kv_heads / mlp / expert / vocab -> "model"   (tensor parallelism)
  embed / embed_out -> "data" [+"pod"]                 (FSDP weight shard)
  cache_seq -> "model"    (context-parallel decode: KV cache sharded along
                           sequence; softmax/contractions over the sharded
                           axis become psum-style partial reductions under
                           GSPMD, which is exactly flash-decode's math)
  layers / stack / conv / state -> None
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, MeshAxes]


def default_rules(multi_pod: bool) -> Rules:
    batch = ("pod", "data") if multi_pod else ("data",)
    fsdp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch,
        "seq": None,
        "embed": fsdp,          # FSDP: weights gathered per layer on use
        "embed_nofsdp": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "expert_mlp": None,
        "vocab": "model",
        "layers": None,
        "stack": None,
        "kv_lora": None,
        "q_lora": None,
        "rope": None,
        "conv": None,
        "state": None,
        "inner": "model",       # mamba d_inner
        "cache_batch": batch,
        "cache_seq": "model",
        "cache_heads": None,
        "act_embed": None,      # activations replicated over model by default
    }


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             rules: Rules, mesh: Mesh) -> P:
    """Resolve a PartitionSpec; drop mappings that don't divide or reuse."""
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        axes = rules.get(name) if name is not None else None
        if axes is None:
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        if any(a in used for a in ax_tuple):
            out.append(None)
            continue
        if dim % _axis_size(mesh, ax_tuple) != 0:
            out.append(None)  # divisibility fallback: replicate
            continue
        used.update(ax_tuple)
        out.append(axes)
    return P(*out)


def sharding_for(shape, logical, rules, mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, rules, mesh))


def tree_shardings(specs: Dict[str, Tuple[Optional[str], ...]],
                   shapes: Dict[str, Tuple[int, ...]],
                   rules: Rules, mesh: Mesh) -> Dict[str, NamedSharding]:
    return {k: sharding_for(shapes[k], specs[k], rules, mesh) for k in specs}
