"""Deterministic synthetic token stream for LM training.

Checkpointable: the full iterator state is (seed, step). Batches are a
function of (seed, step) only — restart-resume reproduces the exact stream
(tested), and generation is independent of the device layout.

The stream has learnable structure (a random order-1 Markov chain over the
vocab) so small-model training loss decreases visibly below log(V)."""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0
    markov_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # sparse-ish transition structure mapped into the vocab
        self._trans = rng.integers(0, self.vocab,
                                   size=(self.markov_states, 4),
                                   dtype=np.int64)

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict):
        self.seed = state["seed"]
        self.step = state["step"]

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ self.step)
        b, s = self.batch, self.seq
        starts = rng.integers(0, self.markov_states, size=(b,))
        choices = rng.integers(0, 4, size=(b, s))
        toks = np.zeros((b, s), np.int64)
        state = starts
        for t in range(s):
            toks[:, t] = self._trans[state, choices[:, t]]
            state = toks[:, t] % self.markov_states
        self.step += 1
        toks = toks.astype(np.int32)
        return {"tokens": jnp.asarray(toks),
                "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
