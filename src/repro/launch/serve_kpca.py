"""Async kPCA serving launcher: concurrent submitters against the
futures-based engine, with optional admission control.

    PYTHONPATH=src python -m repro.launch.serve_kpca --smoke
    PYTHONPATH=src python -m repro.launch.serve_kpca \
        --n-train 512 --submitters 4 --requests 64 --queue-factor 2

Fits a synthetic model, starts the background flusher, then hammers
``submit`` from several threads and reports throughput, batching
efficiency, queue waits, and (with --queue-factor) how many requests
admission control refused.
"""

from __future__ import annotations

from . import env as _env
_env.apply_from_environ()          # before any jax-importing import

import argparse
import threading
import time

import jax.numpy as jnp
import numpy as np

from ..core import oos
from ..core.kernels_math import KernelSpec
from ..data import kpca_dataset
from ..obs.cli import add_obs_args, obs_session
from ..faults import FaultError, transient_faults
from ..serve import KpcaEngine, KpcaServeConfig, ModelHandle, QueueFullError
from ..serve.batching import format_latency


def main():
    ap = argparse.ArgumentParser()
    add_obs_args(ap)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny dims for a fast sanity run")
    ap.add_argument("--n-train", type=int, default=512)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--components", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=128)
    ap.add_argument("--submitters", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per submitter thread")
    ap.add_argument("--max-q", type=int, default=32,
                    help="max rows per request (sizes are uniform 1..max-q)")
    ap.add_argument("--queue-factor", type=int, default=None,
                    help="admission bound = max_batch * k rows (None: off)")
    ap.add_argument("--admission", default="reject",
                    choices=["reject", "shed"])
    ap.add_argument("--flush-wait-ms", type=float, default=2.0)
    ap.add_argument("--inject-faults", type=int, default=0, metavar="N",
                    help="fault-injection demo: fail the first N engine "
                         "dispatches with InjectedCrashError and let the "
                         "retry path heal them (docs/FAULT_TOLERANCE.md)")
    ap.add_argument("--max-retries", type=int, default=None,
                    help="serve retries per drain (default: 0, or 3 when "
                         "--inject-faults is on)")
    ap.add_argument("--request-deadline-ms", type=float, default=None,
                    help="per-request submit->serve budget; expired "
                         "requests fail with DeadlineExceededError")
    args = ap.parse_args()
    if args.smoke:
        args.n_train, args.m, args.requests = 128, 16, 16
    with obs_session(args):
        _run(args)


def _run(args):
    x = jnp.asarray(kpca_dataset(args.n_train, m=args.m, seed=0))
    model = oos.fit_central(x, KernelSpec(kind="rbf"),
                            n_components=args.components, center=True)
    retries = args.max_retries if args.max_retries is not None \
        else (3 if args.inject_faults else 0)
    cfg = KpcaServeConfig(max_batch=args.max_batch,
                          queue_factor=args.queue_factor,
                          admission=args.admission,
                          flush_max_wait_s=args.flush_wait_ms / 1e3,
                          max_retries=retries,
                          retry_backoff_s=0.005,
                          request_deadline_s=(
                              args.request_deadline_ms / 1e3
                              if args.request_deadline_ms is not None
                              else None))
    handle = ModelHandle(model)
    inject = (transient_faults(args.inject_faults)
              if args.inject_faults else None)
    eng = KpcaEngine(handle, cfg, inject_fault=inject)
    # warm every bucket through a fault-free twin so injected faults hit
    # the measured run, not the compile warm-up
    warm = KpcaEngine(handle, cfg)
    for b in cfg.buckets():
        warm.project_many([np.zeros((b, args.m), np.float32)])
    eng.stats = type(eng.stats)()

    # No lock: each submitter thread writes ONLY its own slot (index tid),
    # and the main thread reads after join() — per-slot thread affinity.
    rejected = [0] * args.submitters
    futures = [[] for _ in range(args.submitters)]

    def submitter(tid: int):
        rng = np.random.default_rng(tid)
        for _ in range(args.requests):
            q = int(rng.integers(1, args.max_q + 1))
            xq = rng.normal(size=(q, args.m)).astype(np.float32)
            try:
                futures[tid].append(eng.submit(xq))
            except QueueFullError:
                rejected[tid] += 1

    t0 = time.perf_counter()
    with eng:                                      # flusher thread runs here
        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(args.submitters)]
        for t in threads:
            t.start()
        # One live publish while submitters hammer: same coefficients, so
        # scores are unchanged, but the refresh -> atomic-swap path runs
        # under real load (in-flight flushes finish on the old version,
        # the next drain picks up the new one).
        version = handle.refresh(model.coefs)
        for t in threads:
            t.join()
        done, faulted = [], 0
        for fs in futures:
            for f in fs:
                try:
                    done.append(f.result(timeout=60.0))
                except FaultError:             # typed, never a hang
                    faulted += 1
    dt = time.perf_counter() - t0

    st = eng.stats
    p50, p99 = st.latency_percentiles()
    waits = [r.queue_wait_s for r in st.per_request] or [0.0]
    print(f"served {st.n_queries} queries / {st.n_requests} requests "
          f"({len(done)} futures) in {dt:.2f}s "
          f"-> {st.n_queries / max(dt, 1e-9):.0f} q/s wall")
    print(f"flushes={st.n_flushes} compiles={st.n_compiles} "
          f"pad_rows={st.n_padded} "
          f"pad_frac={st.n_padded / max(st.n_queries + st.n_padded, 1):.2f} "
          f"model_version={version}")
    print(f"compute p50={format_latency(p50)} p99={format_latency(p99)}  "
          f"queue-wait p50={format_latency(np.percentile(waits, 50))} "
          f"p99={format_latency(np.percentile(waits, 99))}")
    if args.queue_factor is not None:
        print(f"admission: bound={cfg.queue_capacity()} rows "
              f"policy={args.admission} rejected={sum(rejected)} "
              f"shed={st.n_shed}")
    if args.inject_faults or args.request_deadline_ms is not None:
        print(f"faults: injected={args.inject_faults} "
              f"retries={st.n_retries} "
              f"deadline_expired={st.n_deadline_expired} "
              f"faulted_futures={faulted}")


if __name__ == "__main__":
    main()
