"""Shared request batching/queueing layer for the serving engines.

``DecodeEngine`` (token slots) and ``KpcaEngine`` (projection slabs) shape
traffic the same way: variable-size requests go into a FIFO queue, a
drainer packs them into fixed-shape device batches, and per-request
accounting rides along. This module owns that machinery once:

  * ``RequestQueue`` — thread-safe FIFO of ``Request`` entries with an
    optional admission bound: when the queued work exceeds ``max_queries``
    the queue either REJECTS the new request (``QueueFullError``) or SHEDS
    the oldest queued ones (their futures fail) to admit it. A condition
    variable lets a background drainer sleep until a size-or-deadline
    trigger fires (``wait_for_work``).
  * ``RequestFuture`` — a ``concurrent.futures.Future`` carrying the
    request id/size, the handle ``submit()`` returns in the async API.
  * pow2 shape buckets (``pow2_buckets``/``bucket_for``) and slab packing
    (``iter_slabs`` head-to-tail rows for kPCA, ``left_pad_pack`` padded
    token waves for decode) — the fixed set of compiled shapes that keeps
    any request mix recompile-free in steady state.
  * per-request accounting (``RequestStats``/``EngineStats``).

Everything here is engine-agnostic: payloads are opaque, only their row
count ``n`` matters to the queue and the packers.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Deque, List, Optional, Sequence, Tuple

import numpy as np


# ---- accounting -----------------------------------------------------------

# Window of recent per-request records kept by ``EngineStats``: enough for
# stable p50/p99 estimates, bounded so a long-running async engine cannot
# grow without limit (requests beyond the window age out oldest-first).
PER_REQUEST_WINDOW = 4096

@dataclasses.dataclass
class RequestStats:
    request_id: int
    n_queries: int
    latency_s: float              # wall time inside the engine for this req
    model_version: int = 0        # handle version this request was served at
    queue_wait_s: float = 0.0     # submit -> start-of-serve wait (async path)


@dataclasses.dataclass
class EngineStats:
    n_requests: int = 0
    n_queries: int = 0
    n_padded: int = 0             # wasted pad rows actually computed
    n_compiles: int = 0           # distinct (bucket) programs built
    n_rejected: int = 0           # admissions refused (QueueFullError)
    n_shed: int = 0               # queued requests dropped to admit newer
    n_flushes: int = 0            # drain cycles that served >= 1 request
    n_retries: int = 0            # drain attempts retried after a fault
    n_deadline_expired: int = 0   # requests failed on the request deadline
    total_time_s: float = 0.0
    # Ring of the most recent PER_REQUEST_WINDOW requests (bounded: a
    # long-running async engine must not accumulate one record per request
    # forever). Aggregate counters above cover the full history; the ring
    # feeds the percentile estimates.
    per_request: Deque[RequestStats] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=PER_REQUEST_WINDOW))

    @property
    def queries_per_s(self) -> float:
        return self.n_queries / self.total_time_s if self.total_time_s else 0.0

    def latency_percentiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Per-request latency percentiles in seconds over the retained
        window (last ``PER_REQUEST_WINDOW`` requests), one per entry of
        ``qs`` (default p50/p99); (0.0, ...) before any request is served."""
        lat = [r.latency_s for r in self.per_request] or [0.0]
        return tuple(float(np.percentile(lat, q)) for q in qs)


# ---- queue ----------------------------------------------------------------

class QueueFullError(RuntimeError):
    """Admission control refused a request (queue at capacity)."""


class ShedError(RuntimeError):
    """This queued request was shed to admit a newer one."""


class RequestFuture(concurrent.futures.Future):
    """Future for one request's result, tagged with its queue identity."""

    def __init__(self, request_id: int, n: int):
        super().__init__()
        self.request_id = request_id
        self.n = n


@dataclasses.dataclass
class Request:
    """One queued request: opaque payload + its row count and future."""

    rid: int
    payload: Any
    n: int
    future: RequestFuture
    t_submit: float


class RequestQueue:
    """Thread-safe bounded FIFO with admission control and a drain trigger.

    ``max_queries`` bounds the total queued row count (None = unbounded).
    ``policy`` picks what happens when an admission would exceed it:
    "reject" raises ``QueueFullError`` at ``put``; "shed" drops the OLDEST
    queued requests (failing their futures with ``ShedError``) until the
    new one fits — latency-loving head drop, matching LM-serving practice
    where a stale queued request is worth less than a fresh one. A request
    larger than the whole capacity is always rejected.
    """

    def __init__(self, max_queries: Optional[int] = None,
                 policy: str = "reject"):
        if policy not in ("reject", "shed"):
            raise ValueError(f"unknown admission policy {policy!r}")
        if max_queries is not None and max_queries < 1:
            raise ValueError(f"max_queries must be >= 1, got {max_queries}")
        self.max_queries = max_queries
        self.policy = policy
        self._cond = threading.Condition()
        self._entries: List[Request] = []   # guarded-by: _cond
        self._depth = 0               # queued rows     guarded-by: _cond
        self._next_id = 0                   # guarded-by: _cond
        self.n_rejected = 0                 # guarded-by: _cond
        self.n_shed = 0                     # guarded-by: _cond
        self.depth_peak = 0                 # guarded-by: _cond

    # -- producer side ------------------------------------------------------

    def put(self, payload: Any, n: int) -> Tuple[RequestFuture,
                                                 List[RequestFuture]]:
        """Enqueue one request of ``n`` rows.

        Returns (future, shed) where ``shed`` lists the futures of any
        requests dropped to admit this one (empty unless policy="shed").
        Raises ``QueueFullError`` when the request cannot be admitted.
        """
        with self._cond:
            shed: List[RequestFuture] = []
            if self.max_queries is not None and \
                    self._depth + n > self.max_queries:
                if n > self.max_queries or self.policy == "reject":
                    self.n_rejected += 1
                    raise QueueFullError(
                        f"queue at capacity ({self._depth}/"
                        f"{self.max_queries} rows queued, request adds {n})")
                while self._entries and self._depth + n > self.max_queries:
                    old = self._entries.pop(0)
                    self._depth -= old.n
                    self.n_shed += 1
                    shed.append(old.future)
            rid = self._next_id
            self._next_id += 1
            fut = RequestFuture(rid, n)
            self._entries.append(
                Request(rid, payload, n, fut, time.monotonic()))
            self._depth += n
            self.depth_peak = max(self.depth_peak, self._depth)
            self._cond.notify_all()
        for f in shed:
            f.set_exception(ShedError("shed by admission control"))
        return fut, shed

    # -- consumer side ------------------------------------------------------

    @property
    def depth(self) -> int:
        """Queued rows (not requests)."""
        with self._cond:
            return self._depth

    def __len__(self) -> int:
        with self._cond:
            return len(self._entries)

    def drain(self) -> List[Request]:
        """Atomically take everything queued (FIFO order)."""
        with self._cond:
            out, self._entries = self._entries, []
            self._depth = 0
            return out

    def take(self, n_requests: int) -> List[Request]:
        """Atomically take up to ``n_requests`` entries from the head."""
        with self._cond:
            out = self._entries[:n_requests]
            self._entries = self._entries[n_requests:]
            for e in out:
                self._depth -= e.n
            return out

    def restore(self, entries: Sequence[Request]) -> None:
        """Put drained entries back at the FRONT (failed-flush retry)."""
        with self._cond:
            self._entries = list(entries) + self._entries
            self._depth += sum(e.n for e in entries)
            self._cond.notify_all()

    def kick(self) -> None:
        """Wake any ``wait_for_work`` sleeper (e.g. on engine shutdown)."""
        with self._cond:
            self._cond.notify_all()

    def wait_for_work(self, min_queries: int, max_wait_s: float,
                      stop: threading.Event) -> bool:
        """Sleep until a flush trigger fires: queued rows reach
        ``min_queries``, OR the oldest entry has waited ``max_wait_s``
        since submit, OR ``stop`` is set. Returns True when there is
        anything queued (the caller should drain), False otherwise.
        """
        with self._cond:
            while not stop.is_set():
                if self._entries:
                    if self._depth >= min_queries:
                        return True
                    age = time.monotonic() - self._entries[0].t_submit
                    if age >= max_wait_s:
                        return True
                    self._cond.wait(timeout=max_wait_s - age)
                else:
                    self._cond.wait(timeout=0.1)
            return bool(self._entries)


# ---- shape buckets --------------------------------------------------------

def pow2_buckets(min_bucket: int, max_batch: int) -> List[int]:
    """Power-of-two widths: min_bucket, 2*min_bucket, ..., max_batch."""
    if not 0 < min_bucket <= max_batch:
        raise ValueError(f"need 0 < min_bucket <= max_batch, got "
                         f"min_bucket={min_bucket} max_batch={max_batch}")
    out, b = [], min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


def bucket_for(buckets: Sequence[int], size: int) -> int:
    """Smallest bucket holding ``size`` rows (widest bucket for overflow —
    callers split anything larger across multiple slabs)."""
    for b in buckets:
        if size <= b:
            return b
    return buckets[-1]


# ---- slab packing ---------------------------------------------------------

def iter_slabs(entries: Sequence[Request], max_batch: int,
               buckets: Sequence[int]):
    """Head-to-tail pack 2-D float payloads into pow2-bucketed slabs.

    Concatenates every entry's ``payload`` rows into one flat stream and
    yields ``(slab, take, owners)`` per device batch: ``slab`` is a
    (bucket, M) float32 array whose first ``take`` rows are real,
    ``owners`` maps each real row back to its request id. Row-wise kernel
    math makes valid rows independent of the zero padding, so per-request
    results are exactly the unbatched ones.
    """
    if not entries:
        return
    stream = np.concatenate([e.payload for e in entries], axis=0)
    owners = np.concatenate(
        [np.full(e.n, e.rid, np.int64) for e in entries])
    pos = 0
    while pos < stream.shape[0]:
        take = min(max_batch, stream.shape[0] - pos)
        bucket = bucket_for(buckets, take)
        slab = np.zeros((bucket, stream.shape[1]), np.float32)
        slab[:take] = stream[pos:pos + take]
        yield slab, take, owners[pos:pos + take]
        pos += take


def left_pad_pack(prompts: Sequence[Sequence[int]], slots: int,
                  pad_id: int = 0) -> Tuple[np.ndarray, int]:
    """Pack up to ``slots`` token prompts into one LEFT-padded int32 wave.

    Returns (toks, plen): toks is (slots, plen) with prompt i right-aligned
    in row i (rows beyond len(prompts) stay all-pad), plen the longest
    prompt. Left padding keeps the last prompt token in the last column, so
    one uniform-length prefill position works for the whole wave.
    """
    if not prompts:
        raise ValueError("left_pad_pack needs at least one prompt")
    if len(prompts) > slots:
        raise ValueError(f"{len(prompts)} prompts > {slots} slots")
    plen = max(len(p) for p in prompts)
    toks = np.full((slots, plen), pad_id, np.int32)
    for i, prompt in enumerate(prompts):
        if len(prompt):
            toks[i, plen - len(prompt):] = prompt
    return toks, plen


__all__ = [
    "EngineStats", "PER_REQUEST_WINDOW", "QueueFullError", "Request",
    "RequestFuture", "RequestQueue", "RequestStats", "ShedError",
    "bucket_for", "iter_slabs", "left_pad_pack", "pow2_buckets",
]
