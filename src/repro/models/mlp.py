"""Gated MLP (SwiGLU family)."""

from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ParamCollector, activation


def init_mlp(col: ParamCollector, cfg: ArchConfig, prefix: str = "mlp",
             d_ff: int = 0):
    e = cfg.d_model
    f = d_ff or cfg.d_ff
    col.param(f"{prefix}/w_gate", (e, f), ("embed", "mlp"))
    col.param(f"{prefix}/w_up", (e, f), ("embed", "mlp"))
    col.param(f"{prefix}/w_down", (f, e), ("mlp", "embed"))


def mlp_forward(p, cfg: ArchConfig, x):
    act = activation(cfg.act)
    g = act(jnp.einsum("bse,ef->bsf", x, p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("bse,ef->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fe->bse", g * u, p["w_down"].astype(x.dtype))
