"""Pure-jnp oracle for the Gram Pallas kernel — delegates to the core math
module (single source of numerical truth)."""

from __future__ import annotations

from typing import Optional

import jax

from ...core.kernels_math import KernelSpec, gram


def gram_reference(spec: KernelSpec, x: jax.Array,
                   y: Optional[jax.Array] = None,
                   gamma: Optional[jax.Array] = None) -> jax.Array:
    return gram(spec, x, y, gamma=gamma)
