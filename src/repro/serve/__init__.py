from .batching import (EngineStats, QueueFullError, RequestFuture,
                       RequestQueue, RequestStats, ShedError)
from .engine import DecodeEngine, ServeConfig
from .kpca_engine import KpcaEngine, KpcaServeConfig
from .publisher import BackgroundPublisher, ModelHandle, stream_chunks
from .sharded import project_sharded

__all__ = ["BackgroundPublisher", "DecodeEngine", "EngineStats",
           "KpcaEngine", "KpcaServeConfig", "ModelHandle", "QueueFullError",
           "RequestFuture", "RequestQueue", "RequestStats", "ServeConfig",
           "ShedError", "project_sharded", "stream_chunks"]
