"""Pallas TPU kernel: fused out-of-sample kPCA projection (serving hot path).

Computes scores = K(X_query, X_support) @ A with the centering epilogue
fused in (see ``repro.core.oos``):

    scores[q, c] = sum_l K(x_q, x_l) A[l, c]
                   + (1/L) sum_l K(x_q, x_l) * row_mean_coef[c] + bias[c]

The (B, L) kernel block is never materialized in HBM: the grid walks
(B/bq, L/bl, M/bm); a VMEM scratch accumulates the query x support dot
products over the feature axis, the kernel epilogue (exp for RBF) runs once
per (q, l) tile, and each tile's contribution K_tile @ A_tile is accumulated
straight into the (bq, C) output block. The row-sum needed for the centering
term rides along as one extra column of A (an all-ones column over the VALID
support rows — this also makes zero-padding of the support axis exact), so
no second pass or extra scratch is needed.

Grid: (B/bq, L/bl, M/bm), dimension_semantics = (parallel, arbitrary,
arbitrary) — the output block for a fixed q is revisited across the l/m
axes. Defaults 128x128x512 match the gram kernel's MXU-aligned tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _project_kernel(sq_ref, ss_ref, gamma_ref, invl_ref, c_ref, b_ref,
                    xq_ref, xs_ref, a_ref, o_ref, acc_ref, *,
                    kind: str, degree: int, coef: float, scale: float,
                    normalize: bool, n_l_blocks: int, n_m_blocks: int,
                    sum_col: int):
    lb = pl.program_id(1)
    mb = pl.program_id(2)

    @pl.when((lb == 0) & (mb == 0))
    def _init_out():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(mb == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = xq_ref[...].astype(jnp.float32)            # (bq, bm)
    xs = xs_ref[...].astype(jnp.float32)            # (bl, bm)
    acc_ref[...] += jax.lax.dot_general(
        xq, xs, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)         # (bq, bl)

    @pl.when(mb == n_m_blocks - 1)
    def _kernel_epilogue_and_matvec():
        acc = acc_ref[...]
        sq = sq_ref[...].astype(jnp.float32)        # (bq,)
        ss = ss_ref[...].astype(jnp.float32)        # (bl,)
        if kind == "rbf":
            d2 = jnp.maximum(sq[:, None] + ss[None, :] - 2.0 * acc, 0.0)
            k = jnp.exp(-gamma_ref[0] * d2)
        else:
            k = acc * scale
            if kind == "poly":
                k = (k + coef) ** degree
            if normalize:
                # sq/ss hold the *self-kernel* values for linear/poly.
                denom = jnp.maximum(sq[:, None] * ss[None, :], 1e-12)
                k = k * jax.lax.rsqrt(denom)
        o_ref[...] += jnp.dot(k, a_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when((lb == n_l_blocks - 1) & (mb == n_m_blocks - 1))
    def _centering_epilogue():
        scores = o_ref[...]                         # (bq, cp)
        # column ``sum_col`` of A was all-ones over valid support rows, so
        # it accumulated the row-sums of K; turn them into the centering
        # term. c/b are zero there, so the column itself stays harmless
        # (the wrapper slices it off).
        kmean = scores[:, sum_col] * invl_ref[0]    # (bq,)
        o_ref[...] = (scores + kmean[:, None] * c_ref[...][None, :]
                      + b_ref[...][None, :])


@functools.partial(
    jax.jit,
    static_argnames=("kind", "degree", "coef", "scale", "normalize",
                     "block_q", "block_l", "block_m", "sum_col", "interpret"))
def project_tiles(xq: jax.Array, xs: jax.Array, a_ext: jax.Array,
                  sq: jax.Array, ss: jax.Array, gamma: jax.Array,
                  inv_l: jax.Array, c_ext: jax.Array, b_ext: jax.Array, *,
                  kind: str = "rbf", degree: int = 3, coef: float = 1.0,
                  scale: float = 1.0, normalize: bool = True,
                  block_q: int = 128, block_l: int = 128, block_m: int = 512,
                  sum_col: int = 0, interpret: bool = False) -> jax.Array:
    """Fused projection over pre-padded operands.

    xq (B, M) queries; xs (L, M) support; a_ext (L, CP) coefficients with
    the ones-column at ``sum_col``; sq (B,), ss (L,) sq-norms (RBF) or
    self-kernels; gamma (1,); inv_l (1,) = 1/L_true; c_ext, b_ext (CP,).
    Returns (B, CP) float32 scores.
    """
    bq_n, m = xq.shape
    l, cp = a_ext.shape
    assert bq_n % block_q == 0 and l % block_l == 0 and m % block_m == 0, \
        (xq.shape, xs.shape, (block_q, block_l, block_m))
    assert cp % 128 == 0, cp
    n_l_blocks = l // block_l
    n_m_blocks = m // block_m
    grid = (bq_n // block_q, n_l_blocks, n_m_blocks)

    kernel = functools.partial(
        _project_kernel, kind=kind, degree=degree, coef=coef, scale=scale,
        normalize=normalize, n_l_blocks=n_l_blocks, n_m_blocks=n_m_blocks,
        sum_col=sum_col)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q,), lambda i, j, b: (i,)),          # sq
            pl.BlockSpec((block_l,), lambda i, j, b: (j,)),          # ss
            pl.BlockSpec((1,), lambda i, j, b: (0,)),                # gamma
            pl.BlockSpec((1,), lambda i, j, b: (0,)),                # inv_l
            pl.BlockSpec((cp,), lambda i, j, b: (0,)),               # c_ext
            pl.BlockSpec((cp,), lambda i, j, b: (0,)),               # b_ext
            pl.BlockSpec((block_q, block_m), lambda i, j, b: (i, b)),
            pl.BlockSpec((block_l, block_m), lambda i, j, b: (j, b)),
            pl.BlockSpec((block_l, cp), lambda i, j, b: (j, 0)),     # a_ext
        ],
        out_specs=pl.BlockSpec((block_q, cp), lambda i, j, b: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bq_n, cp), jnp.float32),
        scratch_shapes=[
            # persists across the sequential l/m axes for a fixed q block
            pltpu.VMEM((block_q, block_l), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(sq, ss, gamma, inv_l, c_ext, b_ext, xq, xs, a_ext)
