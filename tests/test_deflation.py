"""Beyond-paper top-k deflation vs central top-k components."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, build_setup, central_kpca, similarity
from repro.core.deflation import run_admm_topk
from repro.core.topology import ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf")


@pytest.fixture(scope="module")
def topk_problem():
    nodes, pooled = node_dataset(8, 80, m=32, seed=2)
    graph = ring(8, hops=2)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    alpha_gt, lam, _ = central_kpca(jnp.asarray(pooled), SPEC, 4,
                                    gamma=setup.gamma)
    return nodes, pooled, setup, alpha_gt


def test_topk_matches_central(topk_problem):
    nodes, pooled, setup, alpha_gt = topk_problem
    alphas = run_admm_topk(setup, k=2, n_iters=40)

    def msim(a, comp):
        return float(np.mean([
            float(similarity(a[j], jnp.asarray(nodes[j]), alpha_gt[:, comp],
                             jnp.asarray(pooled), SPEC, gamma=setup.gamma))
            for j in range(nodes.shape[0])]))

    s1 = msim(alphas[0], 0)
    assert s1 > 0.9, s1
    # The 2nd/3rd central eigenvalues are near-degenerate on this data, so
    # per-component matching is ill-posed for any solver; the well-posed
    # check is CONTAINMENT: our 2-D component subspace must lie inside the
    # central top-3 subspace (mean principal-angle cosine per node).
    from repro.core import subspace_alignment
    align = float(np.mean([
        float(subspace_alignment(
            jnp.stack([alphas[0][j], alphas[1][j]], axis=1),
            jnp.asarray(nodes[j]), alpha_gt[:, :3], jnp.asarray(pooled),
            SPEC, gamma=setup.gamma))
        for j in range(nodes.shape[0])]))
    assert align > 0.85, align
    # deflated component must NOT align with the first
    cross = msim(alphas[1], 0)
    assert cross < 0.5, cross


def test_components_mutually_orthogonal(topk_problem):
    nodes, pooled, setup, _ = topk_problem
    alphas = run_admm_topk(setup, k=2, n_iters=40)
    # w1^T w2 in feature space per node: alpha1 K_j alpha2 (normalized)
    k = setup.k
    num = jnp.einsum("jn,jnm,jm->j", alphas[0], k, alphas[1])
    d1 = jnp.einsum("jn,jnm,jm->j", alphas[0], k, alphas[0])
    d2 = jnp.einsum("jn,jnm,jm->j", alphas[1], k, alphas[1])
    cos = np.abs(np.asarray(num / jnp.sqrt(d1 * d2 + 1e-12)))
    assert cos.max() < 0.25, cos
