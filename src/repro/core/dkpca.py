"""Distributed (SPMD) decentralized kernel PCA — paper Alg. 1 on a device
mesh.

Mapping (DESIGN.md §3): network node j == device j on the flattened mesh
axes; the paper's k-nearest-neighbor ring becomes ``jax.lax.ppermute``
shifts, i.e. nearest-neighbor hops on the TPU ICI torus. One program runs on
every node (bulk-synchronous SPMD, exactly the ADMM's communication
structure):

  setup:  r ppermute hops each direction exchange raw X_j (paper's setup
          phase); Gram blocks are computed locally (Pallas kernel on TPU);
          global-centering row-mean statistics use one ring sweep
          (J ppermute steps) + one pmean — the "consensus averaging round".
  iterate (lax.scan):
          2 message rounds per iteration, each 2r ppermutes of N-vectors:
          (alpha_l, K_l^-1 B_l column)  ->  Z-update (eq. 10-11)
          (phi(X_l)^T z_j projections)  ->  alpha/eta updates (eq. 12-13)

Per-node per-iteration communication is O(|Omega_j| N) numbers — matching
the paper's §4.2 cost analysis — and is independent of the network size J.

Fault tolerance: the ring is re-knit around failed nodes by re-launching
with the survivor mesh (see ``repro.core.topology.reknit`` and
tests/test_fault_tolerance.py); ADMM state (alpha, B) checkpoints via
``repro.checkpoint``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .admm import initial_alpha  # noqa: F401  (same init semantics)
from .kernels_math import KernelSpec, gram, psd_jitter_eigh, resolve_gamma
from .rho import RhoSchedule
from ..distributed.compat import pvary, shard_map
from .topology import ring_shifts


@dataclasses.dataclass
class DistDkpcaResult:
    alpha: jax.Array           # (J, N)
    alpha_hist: jax.Array      # (T, J, N)
    primal_residual: jax.Array  # (T,)
    znorm2_hist: jax.Array     # (T, J)


def _ring_recv(v, axes, offset: int, j: int):
    """result[m] = v[(m + offset) % J] over the flattened mesh axes."""
    perm = [((m + offset) % j, m) for m in range(j)]
    return jax.lax.ppermute(v, axes, perm)


def dkpca_distributed(
    x_nodes,
    mesh: Mesh,
    axis_names: Sequence[str] = ("data", "model"),
    hops: int = 2,
    spec: KernelSpec = KernelSpec(),
    center: str = "global",
    include_self: bool = True,
    rho1: float = 100.0,
    rho2: Optional[RhoSchedule] = None,
    n_iters: int = 30,
    seed: int = 0,
    alpha0: Optional[jax.Array] = None,
    project: str = "ball",
    gamma: Optional[float] = None,
    use_pallas: bool = False,
    message_dtype=None,
    unroll_iters: bool = False,
) -> DistDkpcaResult:
    """Run decentralized kPCA with one network node per device.

    x_nodes: (J, N, M) with J == prod(mesh axis sizes for axis_names).
    """
    axis_names = tuple(axis_names)
    j_nodes = int(np.prod([mesh.shape[a] for a in axis_names]))
    x_nodes = jnp.asarray(x_nodes, jnp.float32)
    jj, n, m = x_nodes.shape
    assert jj == j_nodes, (jj, j_nodes)
    assert center in ("global", "none")
    if rho2 is None:
        rho2 = RhoSchedule()
    if gamma is None:
        g = resolve_gamma(spec, x_nodes.reshape(jj * n, m))
    else:
        g = jnp.asarray(gamma, jnp.float32)
    if alpha0 is None:
        alpha0 = jax.random.normal(jax.random.PRNGKey(seed), (jj, n),
                                   jnp.float32)
    rho2_arr = jnp.asarray([rho2.at(t) for t in range(n_iters)], jnp.float32)
    rho_self = float(rho1) if include_self else 0.0

    offsets = ring_shifts(hops)                 # [-r..-1, 1..r]
    s_slots = len(offsets) + 1                  # slot 0 = self
    # rev_static[d]: for in-slot d (offset o), the sender's out-slot index
    # pointing back at us = slot of offset -o (in the same 0=self layout).
    slot_of = {0: 0}
    slot_of.update({o: i + 1 for i, o in enumerate(offsets)})
    rev_static = [slot_of[-o] for o in offsets]

    fn = partial(_node_fn, axes=axis_names, j_nodes=j_nodes,
                 offsets=tuple(offsets), rev_static=tuple(rev_static),
                 s_slots=s_slots, spec=spec, center=center,
                 rho_self=rho_self, project=project, n_iters=n_iters,
                 use_pallas=use_pallas, message_dtype=message_dtype,
                 unroll_iters=unroll_iters)
    shmap = shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis_names, None, None), P(axis_names, None), P(), P()),
        out_specs=(P(axis_names, None), P(None, axis_names, None),
                   P(None), P(None, axis_names)),
        # Pallas calls inside the body produce ShapeDtypeStructs without vma
        # annotations; disable the varying-mesh-axes checker for this map.
        check_vma=False,
    )
    with mesh:
        alpha, hist, res, zn = jax.jit(shmap)(x_nodes, alpha0, g, rho2_arr)
    return DistDkpcaResult(alpha=alpha, alpha_hist=hist, primal_residual=res,
                           znorm2_hist=zn)


def _node_fn(x_blk, a_blk, g, rho2_arr, *, axes, j_nodes, offsets, rev_static,
             s_slots, spec, center, rho_self, project, n_iters, use_pallas,
             message_dtype=None, unroll_iters=False):
    """Per-node SPMD program. x_blk: (1, N, M); a_blk: (1, N).

    message_dtype (e.g. jnp.bfloat16): §Perf knob — cast per-iteration
    ppermute payloads (alpha, K^-1 B columns, z-projections) to a narrower
    dtype before the wire, halving ICI bytes; accumulation stays fp32."""
    x = x_blk[0]
    alpha = a_blk[0]
    n = x.shape[0]

    def gram_fn(xa, xb):
        if use_pallas:
            from ..kernels.gram import gram_op
            return gram_op(spec, xa, xb, gamma=g)
        return gram(spec, xa, xb, gamma=g)

    # ---- setup: exchange raw data with r-hop neighbors (paper Alg. 1) ----
    xs = [x] + [_ring_recv(x, axes, o, j_nodes) for o in offsets]
    xs = jnp.stack(xs)                                     # (S, N, M)

    # ---- global centering statistics: one ring sweep + pmean -------------
    if center == "global":
        def sweep(carry, _):
            rot, macc, mubar = carry
            kb = gram_fn(x, rot)                           # (N, N)
            macc = macc + jnp.sum(kb, axis=1)
            mubar = mubar + jnp.sum(kb)
            rot = _ring_recv(rot, axes, 1, j_nodes)
            return (rot, macc, mubar), None

        zero_n = pvary(jnp.zeros((n,), jnp.float32), axes)
        zero_s = pvary(jnp.zeros((), jnp.float32), axes)
        (_, macc, mubar), _ = jax.lax.scan(
            sweep, (x, zero_n, zero_s), None, length=j_nodes)
        m_own = macc / (j_nodes * n)                       # m(x) for own rows
        mu_bar = jax.lax.pmean(mubar / (j_nodes * n * n), axes)
        m_slots = [m_own] + [_ring_recv(m_own, axes, o, j_nodes)
                             for o in offsets]
        m_slots = jnp.stack(m_slots)                       # (S, N)
    else:
        m_slots = jnp.zeros((s_slots, n), jnp.float32)
        mu_bar = jnp.zeros((), jnp.float32)

    # ---- Gram blocks over slot data (Pallas hotspot on TPU) --------------
    xflat = xs.reshape(s_slots * n, -1)
    kfull = gram_fn(xflat, xflat)
    if center == "global":
        mf = m_slots.reshape(s_slots * n)
        kfull = kfull - mf[:, None] - mf[None, :] + mu_bar
    kcross = kfull.reshape(s_slots, n, s_slots, n).transpose(0, 2, 1, 3)

    k_loc = kcross[0, 0]
    lam, vec = psd_jitter_eigh(k_loc)
    inv_lam = jnp.where(lam > 1e-5 * lam[-1], 1.0 / lam, 0.0)

    n_nbr = len(offsets)
    rho_bar_base = rho_self  # + n_nbr * rho2 (per-iteration)

    def iteration(carry, t):
        alpha, b = carry                                   # (N,), (N, S)
        rho2 = rho2_arr[t]
        rho_bar = rho_bar_base + n_nbr * rho2

        # K^-1 B (all slots at once)
        m1 = vec @ ((vec.T @ b) * inv_lam[:, None])        # (N, S)

        # ---- message round 1: alpha + K^-1 B columns ---------------------
        def send(v, off):
            if message_dtype is not None:
                v = v.astype(message_dtype)
            r = _ring_recv(v, axes, off, j_nodes)
            return r.astype(jnp.float32) if message_dtype is not None else r

        recv_m1 = [send(m1[:, rev_static[d]], offsets[d])
                   for d in range(n_nbr)]
        recv_a = [send(alpha, offsets[d]) for d in range(n_nbr)]
        c0 = (m1[:, 0] + rho_self * alpha) / rho_bar
        c = jnp.stack([c0] + [(recv_m1[d] + rho2 * recv_a[d]) / rho_bar
                              for d in range(n_nbr)])      # (S, N)

        znorm2 = jnp.einsum("an,abnm,bm->", c, kcross, c)
        rs = jax.lax.rsqrt(jnp.maximum(znorm2, 1e-30))
        scale = jnp.where(znorm2 > 1.0, rs, 1.0)
        p = scale * jnp.einsum("abnm,bm->an", kcross, c)   # (S, N)

        # ---- message round 2: z-projections ------------------------------
        g_cols = [p[0]] + [send(p[rev_static[d]], offsets[d])
                           for d in range(n_nbr)]
        g_mat = jnp.stack(g_cols, axis=1)                  # (N, S)

        # ---- alpha update (eq. 12) ---------------------------------------
        rho_slots = jnp.concatenate(
            [jnp.full((1,), rho_self), jnp.full((n_nbr,), rho2)])
        rhs = jnp.sum(rho_slots[None, :] * g_mat - b, axis=1)
        den = rho_bar * lam - 2.0 * lam * lam
        # see admm.py: drop non-PD directions during rho warm-up
        inv_den = jnp.where((lam > 1e-5 * lam[-1]) & (den > 0),
                            1.0 / den, 0.0)
        alpha_n = vec @ ((vec.T @ rhs) * inv_den)

        # ---- eta update (eq. 13) -----------------------------------------
        ka = k_loc @ alpha_n
        b_n = b + rho_slots[None, :] * (ka[:, None] - g_mat)
        if rho_self == 0.0:
            b_n = b_n.at[:, 0].set(0.0)

        res2 = jax.lax.psum(jnp.sum((ka[:, None] - g_mat) ** 2
                                    * (rho_slots[None, :] > 0)), axes)

        if project == "rescale":
            zmax = jnp.sqrt(jnp.maximum(
                jax.lax.pmax(znorm2, axes), 1e-30))
            gain = jnp.where(zmax < 1.0, 1.0 / zmax, 1.0)
            alpha_n = alpha_n * gain
            b_n = b_n * gain
        return (alpha_n, b_n), (alpha_n, jnp.sqrt(res2), znorm2)

    b0 = pvary(jnp.zeros((n, s_slots), jnp.float32), axes)
    (alpha_f, _), (ahist, rhist, znhist) = jax.lax.scan(
        iteration, (alpha, b0), jnp.arange(n_iters), unroll=unroll_iters)
    return (alpha_f[None], ahist[:, None, :], rhist, znhist[:, None])
