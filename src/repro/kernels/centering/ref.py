"""Pure-jnp oracle for the centering kernel."""

from ...core.kernels_math import center_gram as center_reference  # noqa: F401
