"""Tests for sharded multi-device kPCA serving: ShardedFittedKpca
(repro.core.oos), the shard_map + psum execution path (repro.serve.sharded),
per-shard landmark compression, the adaptive mp/dp/single routing layer
(CrossoverTable + ShardedRouter: placement cache, donated per-policy entry
points, warmup coverage), and the engine integration.

tests/conftest.py exposes 4 host CPU devices, so shard counts 1/2/4 all run
on a REAL mesh (shard_map + psum / data-parallel row partitioning), not just
the single-device fallback.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, oos
from repro.core.kernels_math import gram
from repro.launch.mesh import make_serving_mesh
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle
from repro.serve.sharded import (POLICIES, CrossoverTable, ShardedRouter,
                                 measure_crossover, project_sharded)

SPEC = KernelSpec(kind="rbf", gamma=0.25)
N, M, C = 90, 12, 3                       # N chosen indivisible by 4


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def fitted():
    x = jnp.asarray(_rand((N, M), seed=0))
    return oos.fit_central(x, SPEC, n_components=C, center=True)


@pytest.fixture(scope="module")
def queries():
    return jnp.asarray(_rand((17, M), seed=1))


class TestShardingParity:
    @pytest.mark.parametrize("n_shards", [1, 2, 4])
    def test_matches_unsharded_on_mesh(self, fitted, queries, n_shards):
        """Sharded psum scores == FittedKpca.transform to fp32 tolerance,
        on a real CPU device mesh."""
        assert jax.device_count() >= 4, "conftest should expose 4 devices"
        sharded, err = oos.shard_fitted(fitted, n_shards)
        assert np.all(np.asarray(err) == 0.0)     # sharding alone is exact
        mesh = make_serving_mesh(n_shards)
        assert mesh is not None and mesh.devices.size == n_shards
        got = np.asarray(project_sharded(sharded, queries, mesh=mesh))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_pallas_partials_match(self, fitted, queries, n_shards):
        sharded, _ = oos.shard_fitted(fitted, n_shards)
        got = np.asarray(project_sharded(sharded, queries, use_pallas=True,
                                         interpret=True))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_uneven_partition(self, fitted, queries):
        """N=90 over 4 shards: sizes (23, 23, 22, 22), padding rows must
        contribute nothing."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        assert sum(sharded.shard_sizes) == N
        assert sharded.shard_capacity == max(sharded.shard_sizes)
        assert len(set(sharded.shard_sizes)) > 1   # actually uneven
        # indicator column is 0 exactly on padding rows
        ind = np.asarray(sharded.coefs_ext[..., -1])
        for j, n in enumerate(sharded.shard_sizes):
            assert ind[j, :n].all() and not ind[j, n:].any()
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_single_device_fallback_same_math(self, fitted, queries):
        """mesh=None with more shards than devices falls back to the local
        reduction; scores identical to the mesh path."""
        sharded, _ = oos.shard_fitted(fitted, 8)   # > 4 devices
        assert make_serving_mesh(8) is None
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


class TestGatherAndCheckpoint:
    def test_shard_gather_roundtrip(self, fitted, queries):
        sharded, _ = oos.shard_fitted(fitted, 3)
        back = oos.gather_fitted(sharded)
        np.testing.assert_array_equal(np.asarray(back.x_support),
                                      np.asarray(fitted.x_support))
        np.testing.assert_array_equal(np.asarray(back.coefs),
                                      np.asarray(fitted.coefs))
        np.testing.assert_array_equal(np.asarray(oos.project(back, queries)),
                                      np.asarray(oos.project(fitted, queries)))

    def test_checkpoint_roundtrip(self, fitted, queries, tmp_path):
        """save -> load -> gather recovers the exact serving behavior."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        oos.save_sharded(str(tmp_path / "ck"), sharded)
        back = oos.load_sharded(str(tmp_path / "ck"))
        assert back.spec == sharded.spec
        assert back.shard_sizes == sharded.shard_sizes
        assert back.n_support == sharded.n_support
        np.testing.assert_array_equal(np.asarray(back.coefs_ext),
                                      np.asarray(sharded.coefs_ext))
        np.testing.assert_array_equal(
            np.asarray(project_sharded(back, queries)),
            np.asarray(project_sharded(sharded, queries)))
        gathered = oos.gather_fitted(back)
        np.testing.assert_allclose(
            np.asarray(oos.project(gathered, queries)),
            np.asarray(oos.project(fitted, queries)), rtol=1e-6, atol=1e-6)

    def test_load_rejects_wrong_kind(self, fitted, tmp_path):
        oos.save_fitted(str(tmp_path / "ck"), fitted)
        with pytest.raises(ValueError):
            oos.load_sharded(str(tmp_path / "ck"))


class TestPerShardCompression:
    def test_bound_dominates_actual_error(self, fitted):
        """The aggregate triangle-inequality bound must upper-bound the true
        relative RKHS error of the summed compressed component."""
        sharded, bound = oos.shard_fitted(fitted, 2, landmarks_per_shard=16)
        a_eff = np.asarray(oos.effective_coefs(fitted))
        x, g = fitted.x_support, fitted.gamma
        cm = oos.gather_fitted(sharded)               # row_mean_coef == 0
        z, beta = cm.x_support, np.asarray(cm.coefs)
        kxx = np.asarray(gram(SPEC, x, gamma=g))
        kzz = np.asarray(gram(SPEC, z, gamma=g))
        kxz = np.asarray(gram(SPEC, x, z, gamma=g))
        w2 = np.sum(a_eff * (kxx @ a_eff), axis=0)
        wh2 = np.sum(beta * (kzz @ beta), axis=0)
        cross = np.sum(a_eff * (kxz @ beta), axis=0)
        actual = np.sqrt(np.clip(w2 + wh2 - 2 * cross, 0.0, None) / w2)
        assert (np.asarray(bound) >= actual - 1e-5).all(), (bound, actual)

    def test_bound_monotone_in_landmarks(self, fitted):
        """Per-shard nested landmark schedules => the aggregate bound is
        monotone non-increasing in the per-shard budget."""
        bounds = []
        for n_l in (8, 16, 32, 45):
            _, b = oos.shard_fitted(fitted, 2, landmarks_per_shard=n_l,
                                    seed=0)
            bounds.append(np.asarray(b))
        for lo, hi in zip(bounds[1:], bounds[:-1]):
            assert (lo <= hi + 1e-5).all(), (lo, hi)

    def test_full_budget_recovers_exact_scores(self, fitted, queries):
        """landmarks_per_shard >= every shard size => projection is onto the
        full span, so scores match the uncompressed model."""
        sharded, bound = oos.shard_fitted(fitted, 3, landmarks_per_shard=N)
        assert float(np.max(np.asarray(bound))) < 1e-2
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_compressed_serving_cost_shrinks(self, fitted):
        sharded, _ = oos.shard_fitted(fitted, 4, landmarks_per_shard=8)
        assert sharded.shard_capacity == 8
        assert sharded.n_support == 32
        assert np.all(np.asarray(sharded.row_mean_coef) == 0.0)


class TestEngineRouting:
    def test_engine_serves_sharded_model(self, fitted):
        """KpcaEngine results over a sharded model match the unsharded
        engine request-for-request."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        reqs = [_rand((q, M), seed=10 + q) for q in (3, 11, 26)]
        ref_eng = KpcaEngine(fitted, KpcaServeConfig(max_batch=16,
                                                     min_bucket=8))
        sh_eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=16,
                                                     min_bucket=8))
        want = ref_eng.project_many(reqs)
        got = sh_eng.project_many(reqs)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=3e-4, atol=3e-4)
        assert sh_eng.stats.n_requests == 3
        assert sh_eng.stats.n_queries == 3 + 11 + 26

    def test_engine_rejects_mesh_for_plain_model(self, fitted):
        mesh = make_serving_mesh(1)
        with pytest.raises(ValueError):
            KpcaEngine(fitted, mesh=mesh)


class TestCrossoverTable:
    def test_no_mesh_or_single_shard_routes_single(self):
        t = CrossoverTable()
        assert t.choose(4096, 4096, 4, has_mesh=False) == "single"
        assert t.choose(4096, 4096, 1, has_mesh=True) == "single"

    def test_threshold_defaults(self):
        t = CrossoverTable()
        assert t.choose(64, 512, 4, has_mesh=True) == "single"
        assert t.choose(256, 4096, 4, has_mesh=True) == "mp"
        assert t.choose(4096, 4096, 4, has_mesh=True) == "dp"

    def test_measured_entry_overrides_thresholds(self):
        t = CrossoverTable(table={(256, 4096): "dp"})
        assert t.choose(256, 4096, 4, has_mesh=True) == "dp"
        assert t.choose(256, 8192, 4, has_mesh=True) == "mp"  # unmeasured

    def test_dp_requires_divisible_rows(self):
        t = CrossoverTable()
        # default choice would be dp, but 4097 rows don't divide over 4
        assert t.choose(4097, 4096, 4, has_mesh=True) == "mp"
        # measured dp at a SMALL support degrades to single, not mp
        t2 = CrossoverTable(table={(16, 512): "dp"})
        assert t2.choose(9, 512, 4, has_mesh=True) == "single"


class TestRoutingParity:
    """fp32 parity of every policy against the unsharded reference, on the
    real 4-device CPU mesh (acceptance: routing is a perf decision, never a
    numerics one)."""

    @pytest.mark.parametrize("policy", ["mp", "dp", "single", "auto"])
    def test_project_sharded_policies_match(self, fitted, queries, policy):
        sharded, _ = oos.shard_fitted(fitted, 4)
        mesh = make_serving_mesh(4)
        assert mesh is not None and mesh.devices.size == 4
        q16 = queries[:16]                    # divisible by 4 (dp-feasible)
        got = np.asarray(project_sharded(sharded, q16, mesh=mesh,
                                         policy=policy))
        want = np.asarray(oos.project(fitted, q16))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_dp_indivisible_rows_degrade_same_math(self, fitted, queries):
        sharded, _ = oos.shard_fitted(fitted, 4)   # 17 rows % 4 != 0
        got = np.asarray(project_sharded(sharded, queries, policy="dp"))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_invalid_policy_rejected(self, fitted, queries):
        sharded, _ = oos.shard_fitted(fitted, 2)
        with pytest.raises(ValueError):
            project_sharded(sharded, queries, policy="fastest")
        with pytest.raises(ValueError):
            ShardedRouter(make_serving_mesh(2), policy="fastest")

    @pytest.mark.parametrize("routing", ["auto", "mp", "dp", "single"])
    def test_engine_routing_parity(self, fitted, routing):
        sharded, _ = oos.shard_fitted(fitted, 4)
        reqs = [_rand((q, M), seed=20 + q) for q in (8, 16, 32)]
        ref = KpcaEngine(fitted, KpcaServeConfig(max_batch=32, min_bucket=8))
        eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=32, min_bucket=8,
                                                  routing=routing))
        want = ref.project_many([r.copy() for r in reqs])
        got = eng.project_many([r.copy() for r in reqs])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=3e-4, atol=3e-4)
        if routing != "auto":     # forced policy taken for EVERY slab
            for p in POLICIES:
                n = getattr(eng.stats, f"n_routed_{p}")
                assert (n > 0) == (p == routing), eng.stats.routing_summary()

    def test_engine_rejects_routing_for_plain_model(self, fitted):
        with pytest.raises(ValueError):
            KpcaEngine(fitted, KpcaServeConfig(routing="mp"))


class TestPlacementCache:
    def test_placement_paid_once_per_version_and_group(self, fitted,
                                                       queries):
        sharded, _ = oos.shard_fitted(fitted, 4)
        router = ShardedRouter(make_serving_mesh(4), donate=False)
        q = jnp.asarray(queries[:16])
        router.dispatch(sharded, 0, q, "mp")
        router.dispatch(sharded, 0, q, "mp")       # cache hit
        assert router.n_placements == 1
        router.dispatch(sharded, 0, q, "dp")       # second group
        assert router.n_placements == 2
        router.dispatch(sharded, 1, q, "mp")       # new version invalidates
        assert router.n_placements == 3
        router.dispatch(sharded, 1, q, "single")   # home placement: free
        assert router.n_placements == 3

    def test_engine_drains_reuse_placement(self, fitted):
        sharded, _ = oos.shard_fitted(fitted, 4)
        eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=16, min_bucket=8,
                                                  routing="mp"))
        for i in range(3):
            eng.project_many([_rand((16, M), seed=50 + i)])
        assert eng._router.n_placements == 1


class TestShardedWarmup:
    def test_warmup_reaches_sharded_dispatch(self, fitted):
        """Regression: warmup must go through the ROUTER (policy choice +
        placement + donated entry), so the first sharded drain after
        warmup compiles nothing."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=32, min_bucket=8,
                                                  warmup=False))
        built = eng.warmup()
        assert built == len(eng._buckets) > 0
        assert eng.warmup() == 0               # idempotent
        eng.project_many([_rand((q, M), seed=30) for q in (8, 16, 32)])
        assert eng.stats.n_compiles == 0

    @pytest.mark.parametrize("routing", ["mp", "dp"])
    def test_warmup_covers_forced_policies(self, fitted, routing):
        sharded, _ = oos.shard_fitted(fitted, 4)
        eng = KpcaEngine(sharded, KpcaServeConfig(
            max_batch=16, min_bucket=16, routing=routing, warmup=False))
        eng.warmup()
        eng.project_many([_rand((16, M), seed=31)])
        assert eng.stats.n_compiles == 0
        assert getattr(eng.stats, f"n_routed_{routing}") == 1


class TestMeasureCrossover:
    def test_measures_feasible_policies_per_bucket(self, fitted):
        sharded, _ = oos.shard_fitted(fitted, 4)
        t = measure_crossover(sharded, row_buckets=(8, 16), reps=1)
        assert set(t.table) == {(8, 128), (16, 128)}   # pow2(N=90) == 128
        assert all(p in POLICIES for p in t.table.values())
        # the measured entry drives choose() for its bucket
        for (rows, _), policy in t.table.items():
            assert t.choose(rows, N, 4, has_mesh=True) == policy


@pytest.mark.lockcheck
class TestOverlappedShardedDrainHammer:
    WAIT = 30.0

    def test_hammer_no_stale_version_no_clobber(self, fitted):
        """4 submitter threads over a STARTED sharded engine, racing a
        stream of per-shard coefficient publishes through the overlapped
        (pipelined) drain. Every result must match the oracle for the
        version recorded in its request stats (no stale shard, no mixed
        versions), no submitted array may be clobbered by donation, and
        version churn must never recompile (placement is re-paid, programs
        are not)."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        handle = ModelHandle(sharded)
        eng = KpcaEngine(handle, KpcaServeConfig(
            max_batch=16, min_bucket=16, flush_max_wait_s=0.002,
            routing="mp", warmup=False))
        eng.warmup()
        eng.stats = type(eng.stats)()
        versions = [sharded]                   # version v -> model
        n_threads, n_per = 4, 5
        outs = [[] for _ in range(n_threads)]
        errors = []

        def submitter(tid):
            try:
                for i in range(n_per):
                    x = _rand((16, M), seed=100 + tid * n_per + i)
                    keep = x.copy()
                    fut = eng.submit(x)
                    r = fut.result(timeout=self.WAIT)
                    outs[tid].append((fut.request_id, x, keep, r))
            except Exception as e:             # surfaces after join
                errors.append(e)

        def publisher():
            rng = np.random.default_rng(41)
            try:
                for i in range(8):
                    shard = i % sharded.n_shards
                    a = rng.normal(size=(sharded.shard_sizes[shard], C)) \
                        .astype(np.float32)
                    handle.refresh_shard(shard, jnp.asarray(a))
                    versions.append(handle.current())
            except Exception as e:
                errors.append(e)

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        threads.append(threading.Thread(target=publisher))
        with eng:
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert not errors, errors

        by_rid = {s.request_id: s for s in eng.stats.per_request}
        ref = jax.jit(lambda m, q: project_sharded(m, q, policy="mp"))
        seen = set()
        for tid in range(n_threads):
            assert len(outs[tid]) == n_per
            for rid, x, keep, r in outs[tid]:
                np.testing.assert_array_equal(x, keep)     # no clobber
                v = by_rid[rid].model_version
                seen.add(v)
                want = np.asarray(ref(versions[v], jnp.asarray(keep)))
                # a stale shard would be off by O(1); 1e-6 is program skew
                np.testing.assert_allclose(r, want, rtol=1e-6, atol=1e-6)
        assert seen                            # every request attributed
        assert eng.stats.n_compiles == 0       # churn re-places, not re-jits
        assert eng.stats.n_routed_mp > 0       # the forced policy was taken
        assert eng._router.n_placements >= len(seen)   # re-placed per version


class TestValidation:
    def test_rejects_bad_shard_count(self, fitted):
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, 0)
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, N + 1)
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, 2, landmarks_per_shard=0)
