"""Kernel functions and Gram-matrix math for (decentralized) kernel PCA.

Everything here is pure jnp and serves as the numerical ground truth; the
Pallas kernels in ``repro.kernels.gram`` implement the same contract with
explicit VMEM tiling and are validated against these functions.

The paper (§3.1) requires the kernel to be *normalized*: K(x, x) = 1 for all
x. RBF satisfies this by construction; linear/polynomial kernels are
normalized via K(x,y)/sqrt(K(x,x) K(y,y)).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Positive-definite kernel specification.

    kind: "rbf" | "linear" | "poly"
    gamma: RBF bandwidth K(x,y)=exp(-gamma ||x-y||^2); None => median heuristic
           resolved at Gram time (see ``resolve_gamma``).
    degree/coef: polynomial kernel (x.y * scale + coef) ** degree.
    normalize: enforce K(x,x)=1 (paper §3.1). RBF is already normalized.
    """

    kind: str = "rbf"
    gamma: Optional[float] = None
    degree: int = 3
    coef: float = 1.0
    scale: float = 1.0
    normalize: bool = True

    def __post_init__(self):
        if self.kind not in ("rbf", "linear", "poly"):
            raise ValueError(f"unknown kernel kind: {self.kind}")


def pairwise_sqdist(x: jax.Array, y: jax.Array) -> jax.Array:
    """Squared euclidean distances. x: (n, m), y: (k, m) -> (n, k)."""
    sx = jnp.sum(x * x, axis=-1)
    sy = jnp.sum(y * y, axis=-1)
    d2 = sx[:, None] + sy[None, :] - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def resolve_gamma(spec: KernelSpec, x: jax.Array) -> jax.Array:
    """Median heuristic: gamma = 1 / median(||x_i - x_j||^2) over a subsample."""
    if spec.gamma is not None:
        return jnp.asarray(spec.gamma, x.dtype)
    n = min(x.shape[0], 256)
    d2 = pairwise_sqdist(x[:n], x[:n])
    med = jnp.median(d2 + jnp.eye(n, dtype=x.dtype) * jnp.max(d2))
    return 1.0 / jnp.maximum(med, 1e-12)


def gram(spec: KernelSpec, x: jax.Array, y: Optional[jax.Array] = None,
         gamma: Optional[jax.Array] = None) -> jax.Array:
    """Dense Gram matrix K[i, j] = K(x_i, y_j). Pure-jnp oracle."""
    if y is None:
        y = x
    if spec.kind == "rbf":
        g = resolve_gamma(spec, x) if gamma is None else gamma
        return jnp.exp(-g * pairwise_sqdist(x, y))
    k = (x @ y.T) * spec.scale
    if spec.kind == "poly":
        k = (k + spec.coef) ** spec.degree
    if spec.normalize:
        dx = _self_k(spec, x)
        dy = _self_k(spec, y)
        k = k / jnp.sqrt(jnp.maximum(dx[:, None] * dy[None, :], 1e-12))
    return k


def _self_k(spec: KernelSpec, x: jax.Array) -> jax.Array:
    s = jnp.sum(x * x, axis=-1) * spec.scale
    if spec.kind == "poly":
        s = (s + spec.coef) ** spec.degree
    return s


def center_gram(k: jax.Array) -> jax.Array:
    """Center a Gram block per the paper's §6.1 formula.

    K_c = K - 1_m K / m - K 1_n / n + 1_m K 1_n / (mn), for K in R^{m x n}.
    (1_m K / m subtracts column means; K 1_n / n subtracts row means.)
    """
    col_mean = jnp.mean(k, axis=0, keepdims=True)
    row_mean = jnp.mean(k, axis=1, keepdims=True)
    tot_mean = jnp.mean(k)
    return k - col_mean - row_mean + tot_mean


def center_gram_global(k_xy: jax.Array, k_x_train: jax.Array,
                       k_train_y: jax.Array, k_train: jax.Array) -> jax.Array:
    """Center a cross block consistently with a reference ("train") set.

    K_c(x,y) = K(x,y) - mean_t K(x,t) - mean_t K(t,y) + mean_tt' K(t,t').
    Used when projecting new data onto components learned on train data.
    """
    return (k_xy
            - jnp.mean(k_x_train, axis=1, keepdims=True)
            - jnp.mean(k_train_y, axis=0, keepdims=True)
            + jnp.mean(k_train))


def psd_jitter_eigh(k: jax.Array, rel_eps: float = 1e-6):
    """Eigendecomposition of a symmetric PSD Gram matrix with eigenvalue
    flooring: lam_i <- max(lam_i, rel_eps * lam_max).

    Centering makes K_j singular (the all-ones vector is in the null space),
    while the paper's algebra uses K_j^{-1}; flooring keeps every solve
    well-posed without changing the top of the spectrum. Returns (lam, v)
    with k ~= v @ diag(lam) @ v.T, lam ascending.
    """
    lam, v = jnp.linalg.eigh(k)
    lam_max = jnp.maximum(lam[-1], 1e-30)
    lam = jnp.maximum(lam, rel_eps * lam_max)
    return lam, v


@partial(jax.jit, static_argnames=("k",))
def topk_eigh(kmat: jax.Array, k: int = 1):
    """Top-k eigenpairs of a symmetric matrix, descending."""
    lam, v = jnp.linalg.eigh(kmat)
    return lam[::-1][:k], v[:, ::-1][:, :k]
