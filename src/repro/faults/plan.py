"""Seeded fault plans: WHAT fails, WHEN, deterministically.

A :class:`FaultPlan` is an immutable schedule of fault events, fixed before
the run starts (chaos engineering needs reproducibility more than it needs
surprise: same seed ⇒ same faults ⇒ same trajectory, so a chaos test can
assert bitwise determinism). The plan is pure data — injection happens in
``faults.comm`` (link-level), ``faults.driver`` (node dropout) and
``faults.serving`` (shard loss / publisher crash), all reading the same
plan.

Event vocabulary (see docs/FAULT_TOLERANCE.md for the schema):

- :class:`NodeDropout` — node ``node`` permanently leaves at iteration
  ``t``. The ADMM driver detects it at the next chunk boundary, re-knits
  the topology and shrinks the solver state to survivors.
- :class:`LinkFault` — messages on edge ``(u, v)`` are LOST for iterations
  ``t0 <= t < t1``. ``directed=True`` drops only ``u <- v`` (u stops
  hearing v); undirected drops both directions. A *delay* of ``d``
  iterations is modeled as loss over ``[t0, t0 + d)`` — the stale payload
  is censored rather than applied late, matching COKE-style censored
  communication (the receiver renormalizes over slots actually heard).
- :class:`StragglerStall` — node ``node`` is unresponsive for
  ``t0 <= t < t1``: loss on every incident edge, both directions, for the
  window. The stalled node itself keeps iterating on its own data.
- :class:`ShardLoss` — serving-side: shard ``shard`` becomes unreachable
  at the ``at_dispatch``-th engine dispatch (0-based: ``at_dispatch=0``
  fails the first batch).
- :class:`PublisherCrash` — the ``at_job``-th publish/refresh job raises
  :class:`~repro.faults.errors.InjectedCrashError`.

Iteration-level events compile to a dense per-iteration *link mask* via
:meth:`FaultPlan.link_mask` — shape ``(n_iters, J, S)`` float32 in
{0, 1}, aligned with the solver's slot tables (slot 0 = self, slots
1.. = neighbors). Slot 0 is never masked: a node that cannot talk to
itself is a dropout, not a link fault.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class NodeDropout:
    t: int
    node: int


@dataclasses.dataclass(frozen=True)
class LinkFault:
    t0: int
    t1: int
    u: int
    v: int
    directed: bool = False


@dataclasses.dataclass(frozen=True)
class StragglerStall:
    t0: int
    t1: int
    node: int


@dataclasses.dataclass(frozen=True)
class ShardLoss:
    at_dispatch: int
    shard: int


@dataclasses.dataclass(frozen=True)
class PublisherCrash:
    at_job: int


_EVENT_TYPES = {
    "dropouts": NodeDropout,
    "links": LinkFault,
    "stragglers": StragglerStall,
    "shard_losses": ShardLoss,
    "publisher_crashes": PublisherCrash,
}


def link_delay(t0: int, delay: int, u: int, v: int,
               directed: bool = False) -> LinkFault:
    """A link delay of ``delay`` iterations == censoring for that window."""
    return LinkFault(t0=t0, t1=t0 + delay, u=u, v=v, directed=directed)


def ring_slot_tables(j_nodes: int, hops: int):
    """(src, mask) routing tables in the SPMD ring slot layout.

    ``core.dkpca`` orders neighbor slots by ``ring_shifts(hops)``
    (offsets [-r..-1, 1..r]), which differs from the dense setup's
    ``graph.nbr`` ordering — compile a mask with THESE tables when
    feeding ``dkpca_distributed(link_mask=...)``.
    """
    from ..core.topology import ring_shifts
    offsets = ring_shifts(hops)
    src = np.empty((j_nodes, len(offsets) + 1), np.int32)
    src[:, 0] = np.arange(j_nodes)
    for i, o in enumerate(offsets):
        src[:, i + 1] = (np.arange(j_nodes) + o) % j_nodes
    return src, np.ones_like(src, np.float32)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Immutable, seed-stamped schedule of faults for one run."""

    seed: int = 0
    dropouts: Tuple[NodeDropout, ...] = ()
    links: Tuple[LinkFault, ...] = ()
    stragglers: Tuple[StragglerStall, ...] = ()
    shard_losses: Tuple[ShardLoss, ...] = ()
    publisher_crashes: Tuple[PublisherCrash, ...] = ()

    # -- schedule views ---------------------------------------------------

    def dropout_schedule(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """Sorted ``[(t, (nodes dropping at t, ...)), ...]``."""
        by_t: Dict[int, List[int]] = {}
        for d in self.dropouts:
            by_t.setdefault(int(d.t), []).append(int(d.node))
        return [(t, tuple(sorted(ns))) for t, ns in sorted(by_t.items())]

    def dead_after(self, t: int) -> Tuple[int, ...]:
        """Original node ids dead strictly before iteration ``t`` runs."""
        return tuple(sorted(int(d.node) for d in self.dropouts
                            if int(d.t) <= t))

    # -- link-mask compilation --------------------------------------------

    def link_mask(self, src: np.ndarray, mask: np.ndarray,
                  t0: int, t1: int,
                  node_ids: Optional[Sequence[int]] = None) -> np.ndarray:
        """Compile link events into a per-iteration slot mask.

        ``src``/``mask`` are the solver's ``(J, S)`` routing tables
        (``src[j, s]`` = node index whose columns land in node j's slot s;
        ``mask[j, s]`` = structural slot validity). ``node_ids`` maps the
        current row index to the ORIGINAL node id (after a re-knit the
        survivor table is re-indexed but the plan still speaks original
        ids); ``None`` means identity.

        Returns ``(t1 - t0, J, S)`` float32 with 0 where a message is
        censored at iteration ``t0 + i``. Slot 0 (self) is never censored,
        and structurally-invalid slots stay 0-masked upstream so their
        value here is irrelevant.
        """
        src = np.asarray(src)
        j, s = src.shape
        ids = (np.arange(j) if node_ids is None
               else np.asarray(node_ids, dtype=np.int64))
        if len(ids) != j:
            raise ValueError(f"node_ids has {len(ids)} entries for {j} rows")
        id_of_row = ids                       # row -> original id
        row_of_id = {int(v): r for r, v in enumerate(ids)}
        out = np.ones((t1 - t0, j, s), np.float32)

        def censor(t_a: int, t_b: int, u: int, v: int) -> None:
            """Drop u <- v (receiver u stops hearing sender v)."""
            ru = row_of_id.get(int(u))
            rv = row_of_id.get(int(v))
            if ru is None or rv is None:
                return                        # endpoint already dropped out
            lo, hi = max(t_a, t0), min(t_b, t1)
            if lo >= hi:
                return
            slots = np.nonzero(src[ru, 1:] == rv)[0] + 1
            out[lo - t0:hi - t0, ru, slots] = 0.0

        for lf in self.links:
            censor(lf.t0, lf.t1, lf.u, lf.v)
            if not lf.directed:
                censor(lf.t0, lf.t1, lf.v, lf.u)
        for st in self.stragglers:
            for other in id_of_row:
                if int(other) == int(st.node):
                    continue
                censor(st.t0, st.t1, int(other), int(st.node))
                censor(st.t0, st.t1, int(st.node), int(other))
        out *= np.asarray(mask, np.float32)[None, :, :]
        out[:, :, 0] = 1.0                    # self slot is never censored
        return out

    def has_link_faults(self, t0: int, t1: int) -> bool:
        win = [(e.t0, e.t1) for e in self.links]
        win += [(e.t0, e.t1) for e in self.stragglers]
        return any(a < t1 and b > t0 for a, b in win)

    # -- (de)serialization -------------------------------------------------

    def to_dict(self) -> dict:
        d: dict = {"seed": int(self.seed)}
        for key in _EVENT_TYPES:
            events = getattr(self, key)
            if events:
                d[key] = [dataclasses.asdict(e) for e in events]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        kw: dict = {"seed": int(d.get("seed", 0))}
        for key, typ in _EVENT_TYPES.items():
            kw[key] = tuple(typ(**e) for e in d.get(key, ()))
        return cls(**kw)

    # -- seeded generation -------------------------------------------------

    @classmethod
    def random(cls, seed: int, n_nodes: int, n_iters: int, *,
               n_dropouts: int = 0, n_link_faults: int = 0,
               n_stragglers: int = 0, max_window: int = 5,
               protect: Iterable[int] = (),
               t_min: int = 1) -> "FaultPlan":
        """Deterministic plan from a seed (same args ⇒ identical plan).

        Dropout times land in ``[t_min, n_iters)`` and dropped nodes are
        distinct, never in ``protect``, and never a majority — at least
        ``n_nodes - n_dropouts >= 2`` nodes must survive.
        """
        if n_nodes - n_dropouts < 2:
            raise ValueError("a fault plan must leave >= 2 survivors")
        rng = np.random.default_rng(seed)
        protected = set(int(p) for p in protect)
        pool = [n for n in range(n_nodes) if n not in protected]
        victims = rng.choice(pool, size=n_dropouts, replace=False) \
            if n_dropouts else np.empty(0, np.int64)
        dropouts = tuple(
            NodeDropout(t=int(rng.integers(t_min, max(n_iters, t_min + 1))),
                        node=int(v))
            for v in sorted(int(v) for v in victims))
        live = [n for n in range(n_nodes)
                if n not in {d.node for d in dropouts}]
        links = []
        for _ in range(n_link_faults):
            u, v = rng.choice(live, size=2, replace=False)
            t_a = int(rng.integers(t_min, max(n_iters, t_min + 1)))
            links.append(LinkFault(
                t0=t_a, t1=t_a + int(rng.integers(1, max_window + 1)),
                u=int(u), v=int(v),
                directed=bool(rng.integers(0, 2))))
        stragglers = []
        for _ in range(n_stragglers):
            t_a = int(rng.integers(t_min, max(n_iters, t_min + 1)))
            stragglers.append(StragglerStall(
                t0=t_a, t1=t_a + int(rng.integers(1, max_window + 1)),
                node=int(rng.choice(live))))
        return cls(seed=int(seed), dropouts=dropouts,
                   links=tuple(links), stragglers=tuple(stragglers))


__all__ = [
    "FaultPlan", "NodeDropout", "LinkFault", "StragglerStall",
    "ShardLoss", "PublisherCrash", "link_delay",
]
