from .synthetic import distribute, kpca_dataset, node_dataset

__all__ = ["distribute", "kpca_dataset", "node_dataset"]
