"""Pallas kernel micro-benchmarks: gram / centering / fused admm step vs.
their jnp oracles. On CPU the kernels run in interpret mode so wall-times
measure the oracle paths; the derived column reports allclose deltas and the
kernel's tile geometry (the TPU-relevant artifact)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec
from repro.kernels import (center_op, center_reference, gram_op,
                           gram_reference)


def _time(f, *a, n=5):
    f(*a)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*a))
    return (time.perf_counter() - t0) / n * 1e6


def bench_gram_kernel():
    rows = []
    spec = KernelSpec(kind="rbf", gamma=0.5)
    for n, m in ((256, 784), (512, 784), (1024, 256)):
        x = jnp.asarray(np.random.default_rng(n).normal(
            size=(n, m)).astype(np.float32))
        got = gram_op(spec, x)
        want = gram_reference(spec, x)
        err = float(jnp.max(jnp.abs(got - want)))
        us = _time(jax.jit(lambda x: gram_reference(spec, x)), x)
        flops = 2 * n * n * m
        rows.append((f"gram/{n}x{m}", us,
                     f"allclose_err={err:.1e};tile=128x128x512;"
                     f"oracle_gflops={flops / us / 1e3:.1f}"))
    return rows


def bench_centering_kernel():
    rows = []
    for n in (512, 2048):
        k = jnp.asarray(np.random.default_rng(n).normal(
            size=(n, n)).astype(np.float32))
        err = float(jnp.max(jnp.abs(center_op(k)
                                    - center_reference(k))))
        us = _time(jax.jit(center_reference), k)
        rows.append((f"centering/{n}", us, f"allclose_err={err:.1e}"))
    return rows
