"""Command line front end: ``python -m tools.lint PATHS...``.

Formats:
  * ``text`` (default) — ``path:line:col: rule message`` per finding;
  * ``github`` — workflow annotation commands (``::error file=...``) so
    findings surface inline on the PR diff;
  * ``json`` — a list of finding objects for tooling.

Exit status: 0 clean, 1 findings, 2 bad invocation.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .engine import Finding, all_rules, iter_findings


def _format_text(findings: List[Finding]) -> str:
    return "\n".join(f"{f.path}:{f.line}:{f.col}: {f.rule} {f.message}"
                     for f in findings)


def _format_github(findings: List[Finding]) -> str:
    return "\n".join(
        f"::error file={f.path},line={f.line},col={f.col},"
        f"title=repro-lint {f.rule}::{f.message}" for f in findings)


def _format_json(findings: List[Finding]) -> str:
    return json.dumps([f.as_dict() for f in findings], indent=2)


_FORMATS = {"text": _format_text, "github": _format_github,
            "json": _format_json}


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: concurrency- and JAX-aware static "
                    "analysis (see docs/STATIC_ANALYSIS.md)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint (e.g. src tests)")
    ap.add_argument("--format", choices=sorted(_FORMATS), default="text",
                    help="output format (default: text)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule names to run "
                         "(default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name, cls in sorted(all_rules().items()):
            print(f"{name:18s} {cls.summary}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (try: python -m tools.lint src tests)",
              file=sys.stderr)
        return 2

    select = None
    if args.select:
        select = [s.strip() for s in args.select.split(",") if s.strip()]
    try:
        findings = sorted(
            iter_findings(args.paths, select=select),
            key=lambda f: (f.path, f.line, f.col, f.rule))
    except (ValueError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    out = _FORMATS[args.format](findings)
    if out:
        print(out)
    if args.format != "json" and findings:
        print(f"\n{len(findings)} finding(s).", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
