"""Property-based tests (hypothesis, or the deterministic tests/_hyp.py
fallback engine) for the serving packers and the coefficient-refresh
equivalence.

These pin ALGEBRAIC invariants across randomized shapes rather than a
few hand-picked cases:

- ``iter_slabs``: packing is a pure reshuffle — concatenating the real
  rows of every slab (in owner order) reproduces the input stream
  exactly, padding never leaks, and every slab width is a legal bucket.
- ``left_pad_pack``: right-aligned rows round-trip token-exactly.
- ``pow2_buckets``: strictly increasing, pow2-spaced, ends at max_batch.
- ``oos.refresh_coefficients`` == ``oos.from_dual`` for ANY new dual on
  the same support set — the O(L*C) cached-statistics update is exactly
  the O(L^2) rebuild (fp32 tolerance), per kernel kind.
"""

import jax.numpy as jnp
import numpy as np

from _hyp import given, settings, st
from repro.core import KernelSpec, oos
from repro.serve.batching import (bucket_for, iter_slabs, left_pad_pack,
                                  pow2_buckets)


class _Entry:
    """Minimal iter_slabs entry: payload rows tagged with a request id."""

    def __init__(self, rid, payload):
        self.rid = rid
        self.payload = payload
        self.n = payload.shape[0]


class TestSlabPackingProperties:
    @given(sizes=st.lists(st.integers(1, 20), min_size=1, max_size=12),
           m=st.integers(1, 7),
           min_bucket=st.integers(1, 4),
           factor=st.integers(1, 4))
    @settings(max_examples=40)
    def test_iter_slabs_round_trip(self, sizes, m, min_bucket, factor):
        max_batch = min_bucket * 2 ** factor
        buckets = pow2_buckets(min_bucket, max_batch)
        rng = np.random.default_rng(sum(sizes) + m)
        entries = [
            _Entry(rid, rng.normal(size=(n, m)).astype(np.float32))
            for rid, n in enumerate(sizes)]
        stream = np.concatenate([e.payload for e in entries])
        owner_ref = np.concatenate(
            [np.full(e.n, e.rid, np.int64) for e in entries])
        rows, owners = [], []
        for slab, take, own in iter_slabs(entries, max_batch, buckets):
            assert slab.shape[0] in buckets       # every width is a bucket
            assert slab.shape == (slab.shape[0], m)
            assert slab.dtype == np.float32
            assert 0 < take <= max_batch
            assert (slab[take:] == 0.0).all()     # padding is all-zero
            rows.append(slab[:take])
            owners.append(own)
        packed = np.concatenate(rows)
        assert packed.shape == stream.shape       # no row lost, none invented
        assert (packed == stream).all()           # exact round-trip
        assert (np.concatenate(owners) == owner_ref).all()

    @given(sizes=st.lists(st.integers(1, 9), min_size=0, max_size=6))
    @settings(max_examples=25)
    def test_iter_slabs_empty_and_total_take(self, sizes):
        entries = [_Entry(i, np.ones((n, 3), np.float32))
                   for i, n in enumerate(sizes)]
        slabs = list(iter_slabs(entries, 8, pow2_buckets(2, 8)))
        assert sum(take for _, take, _ in slabs) == sum(sizes)
        if not sizes:
            assert slabs == []

    @given(lens=st.lists(st.integers(1, 12), min_size=1, max_size=6),
           extra_slots=st.integers(0, 3),
           pad_id=st.sampled_from([0, -1, 99]))
    @settings(max_examples=40)
    def test_left_pad_pack_round_trip(self, lens, extra_slots, pad_id):
        rng = np.random.default_rng(sum(lens) + extra_slots)
        # tokens are drawn off the pad id so padding is distinguishable
        prompts = [[int(t) for t in rng.integers(100, 200, size=n)]
                   for n in lens]
        slots = len(prompts) + extra_slots
        toks, plen = left_pad_pack(prompts, slots, pad_id=pad_id)
        assert toks.shape == (slots, plen)
        assert plen == max(lens)
        for i, p in enumerate(prompts):
            row = toks[i]
            assert list(row[plen - len(p):]) == p     # right-aligned payload
            assert (row[:plen - len(p)] == pad_id).all()
        assert (toks[len(prompts):] == pad_id).all()  # spare slots: all pad


class TestBucketProperties:
    @given(min_bucket=st.integers(1, 64), factor=st.integers(0, 6))
    @settings(max_examples=40)
    def test_pow2_buckets_shape(self, min_bucket, factor):
        max_batch = min_bucket * 2 ** factor
        buckets = pow2_buckets(min_bucket, max_batch)
        assert buckets[0] == min_bucket and buckets[-1] == max_batch
        assert all(a < b for a, b in zip(buckets, buckets[1:]))
        assert all(b == min_bucket * 2 ** i for i, b in enumerate(buckets))

    @given(min_bucket=st.integers(1, 16), factor=st.integers(0, 5),
           size=st.integers(1, 600))
    @settings(max_examples=40)
    def test_bucket_for_is_monotone_and_minimal(self, min_bucket, factor,
                                                size):
        buckets = pow2_buckets(min_bucket, min_bucket * 2 ** factor)
        b = bucket_for(buckets, size)
        assert b in buckets
        if size <= buckets[-1]:
            assert b >= size                      # holds the rows...
            smaller = [x for x in buckets if x < b]
            assert all(x < size for x in smaller)  # ...and is the smallest
        else:
            assert b == buckets[-1]               # overflow: widest bucket
        # monotone: more rows never get a smaller bucket
        assert bucket_for(buckets, size + 1) >= b


class TestRefreshEqualsFromDual:
    @given(n=st.integers(6, 24), m=st.integers(2, 8), c=st.integers(1, 3),
           kind=st.sampled_from(["rbf", "linear"]),
           seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_refresh_matches_full_rebuild(self, n, m, c, kind, seed):
        """Swapping duals via the cached-statistics path is EXACTLY a
        from-scratch ``from_dual`` on the same support set."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n, m)), jnp.float32)
        a0 = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        a1 = jnp.asarray(rng.normal(size=(n, c)), jnp.float32)
        spec = KernelSpec(kind=kind)
        base = oos.from_dual(x, a0, spec, center=True)
        refreshed = oos.refresh_coefficients(base, a1)
        rebuilt = oos.from_dual(x, a1, spec, gamma=base.gamma, center=True)
        np.testing.assert_allclose(np.asarray(refreshed.coefs),
                                   np.asarray(rebuilt.coefs), atol=1e-6)
        np.testing.assert_allclose(np.asarray(refreshed.row_mean_coef),
                                   np.asarray(rebuilt.row_mean_coef),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(refreshed.bias),
                                   np.asarray(rebuilt.bias), atol=1e-5)
        xq = jnp.asarray(rng.normal(size=(5, m)), jnp.float32)
        np.testing.assert_allclose(np.asarray(oos.project(refreshed, xq)),
                                   np.asarray(oos.project(rebuilt, xq)),
                                   atol=1e-5)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=8, deadline=None)
    def test_sharded_refresh_matches_gathered(self, seed):
        """Per-shard refresh then gather == refresh of the gathered model
        (shard order IS pooled order for shard_fitted models)."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(24, 5)), jnp.float32)
        a0 = jnp.asarray(rng.normal(size=(24, 2)), jnp.float32)
        a1 = jnp.asarray(rng.normal(size=(24, 2)), jnp.float32)
        model = oos.from_dual(x, a0, KernelSpec(kind="rbf"), center=True)
        sharded, _ = oos.shard_fitted(model, 3)
        ref_sh = oos.refresh_coefficients(sharded, a1)
        ref_central = oos.refresh_coefficients(model, a1)
        xq = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(oos.project(oos.gather_fitted(ref_sh), xq)),
            np.asarray(oos.project(ref_central, xq)), atol=1e-5)
