"""Test-hygiene rules.

``sleep-in-test`` flags wall-clock sleeps inside the test tree
(``tests/``, including ``conftest.py`` and test helpers). A test that
needs ``time.sleep`` to pass encodes a RACE with real time: it is slow
when the bound is generous and flaky when it is not, and the failure
mode (a scheduler hiccup on a loaded CI box) is exactly the
nondeterminism the chaos/fault suite exists to rule out. Synchronize on
the event you are actually waiting for instead:

- ``threading.Event.wait(timeout)`` / ``Condition.wait_for`` for state,
- ``Thread.join(timeout=...)`` to bound liveness checks,
- ``concurrent.futures.wait`` for async results,
- ``drain()`` / ``settle()`` style helpers for pipelines.

Deliberate duration-shaped sleeps (e.g. manufacturing a measurable span
length for a tracer test) can pragma the line with
``# repro-lint: disable=sleep-in-test``.

Matched forms: ``time.sleep(...)`` through any alias of the ``time``
module, and a bare ``sleep(...)`` when the file does
``from time import sleep`` (aliased or not). Sleeps in src/ are NOT this
rule's business — production backoffs are legitimate (the engine's
retry path uses an interruptible ``Event.wait`` anyway).
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..engine import FileContext, Finding, Rule, register


def _time_aliases(tree: ast.AST) -> Set[str]:
    """Names the ``time`` module is bound to in this file."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    out.add(a.asname or "time")
    return out


def _sleep_aliases(tree: ast.AST) -> Set[str]:
    """Names ``time.sleep`` is bound to via ``from time import sleep``."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name == "sleep":
                    out.add(a.asname or "sleep")
    return out


@register
class SleepInTestRule(Rule):
    name = "sleep-in-test"
    summary = ("tests must not wall-clock sleep — wait on the event "
               "(Event.wait / join(timeout) / futures) instead")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if not ctx.is_test:
            return
        time_names = _time_aliases(ctx.tree)
        sleep_names = _sleep_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            hit = (isinstance(f, ast.Attribute) and f.attr == "sleep"
                   and isinstance(f.value, ast.Name)
                   and f.value.id in time_names) \
                or (isinstance(f, ast.Name) and f.id in sleep_names)
            if hit:
                yield self.finding(
                    ctx, node,
                    "wall-clock sleep in a test is a race with the "
                    "scheduler — synchronize on the condition itself "
                    "(Event.wait(timeout), Thread.join(timeout=...), "
                    "futures.wait) or pragma a deliberate duration sleep")
