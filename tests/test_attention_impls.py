"""Attention implementation equivalences: einsum vs chunked vs SWA-banded,
and MLA absorbed decode vs naive decompressed attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.attention import (_mask_bias, gqa_forward, init_gqa,
                                    init_mla, mla_decode, mla_forward, sdpa)
from repro.models.common import ParamCollector, slice_layer


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=64, head_dim=16,
                param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def _qkv(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, cfg.n_heads, cfg.head_dim))
                    .astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, cfg.n_kv_heads, cfg.head_dim))
                    .astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, cfg.n_kv_heads, cfg.head_dim))
                    .astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("s,qc,kc", [(64, 16, 16), (64, 16, 32), (128, 64, 16)])
def test_chunked_matches_einsum(s, qc, kc):
    cfg_e = _cfg(attention_impl="einsum")
    cfg_c = _cfg(attention_impl="chunked", attn_q_chunk=qc, attn_kv_chunk=kc)
    q, k, v = _qkv(cfg_e, 2, s)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (2, s))
    bias = _mask_bias(pos, pos, True)
    out_e = sdpa(cfg_e, q, k, v, bias)
    out_c = sdpa(cfg_c, q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_e),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 32, 48])
def test_swa_banded_matches_masked(window):
    """§Perf optimization correctness: banded SWA == full masked SWA."""
    s = 128
    cfg_m = _cfg(attn_kind="swa", window=window, attention_impl="chunked",
                 attn_q_chunk=16, attn_kv_chunk=16)
    cfg_b = dataclasses.replace(cfg_m, swa_banded=True)
    q, k, v = _qkv(cfg_m, 2, s, seed=3)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (2, s))
    bias = _mask_bias(pos, pos, True, window)
    out_m = sdpa(cfg_m, q, k, v, bias)
    out_b = sdpa(cfg_b, q, k, v, bias)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_m),
                               rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_matches_forward():
    """Absorbed-latent decode must reproduce the full decompressed attention
    logit-for-logit when processing the same prefix."""
    cfg = _cfg(attn_kind="mla", n_kv_heads=4, q_lora_rank=32,
               kv_lora_rank=24, qk_nope_dim=16, qk_rope_dim=8,
               v_head_dim=16, head_dim=24)
    col = ParamCollector(jax.random.PRNGKey(0), jnp.float32)
    init_mla(col, cfg)
    p = slice_layer(col.params, "attn")
    rng = np.random.default_rng(1)
    b, s = 2, 12
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
                    * 0.3)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_full = mla_forward(p, cfg, x, pos)

    from repro.models.attention import MLACache
    cache = MLACache(jnp.zeros((b, 16, cfg.kv_lora_rank)),
                     jnp.zeros((b, 16, cfg.qk_rope_dim)))
    outs = []
    for t in range(s):
        o, cache = mla_decode(p, cfg, x[:, t:t + 1],
                              jnp.broadcast_to(jnp.asarray([[t]]), (b, 1)),
                              cache, jnp.asarray(t, jnp.int32))
        outs.append(o[:, 0])
    out_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               rtol=5e-3, atol=5e-3)


def test_swa_ring_buffer_decode():
    """Decode beyond the window: ring-buffer cache must agree with a fresh
    full-context forward restricted to the window."""
    cfg = _cfg(attn_kind="swa", window=8, attention_impl="einsum")
    col = ParamCollector(jax.random.PRNGKey(0), jnp.float32)
    init_gqa(col, cfg)
    p = slice_layer(col.params, "attn")
    rng = np.random.default_rng(2)
    b, s = 1, 20
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)).astype(np.float32)
                    * 0.3)
    pos_full = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_full, _ = gqa_forward(p, cfg, x, pos_full, causal=True)

    from repro.models.attention import KVCache
    cache = KVCache(jnp.zeros((b, cfg.window, cfg.n_kv_heads, cfg.head_dim)),
                    jnp.zeros((b, cfg.window, cfg.n_kv_heads, cfg.head_dim)))
    outs = []
    for t in range(s):
        o, cache = gqa_forward(p, cfg, x[:, t:t + 1],
                               jnp.broadcast_to(jnp.asarray([[t]]), (b, 1)),
                               causal=True, cache=cache,
                               cache_len=jnp.asarray(t, jnp.int32))
        outs.append(o[:, 0])
    out_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_dec), np.asarray(out_full),
                               rtol=5e-3, atol=5e-3)
