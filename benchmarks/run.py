# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig3,...]

Paper tables/figures:
    fig3  similarity vs #nodes          (bench_kpca.bench_similarity_vs_nodes)
    fig4  similarity vs local samples   (bench_kpca.bench_similarity_vs_samples)
    fig5  similarity vs #neighbors      (bench_kpca.bench_similarity_vs_neighbors)
    rt    runtime vs central kPCA       (bench_kpca.bench_runtime_vs_central)
plus kernel micro-benches and the roofline summary from the dry-run."""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from benchmarks.bench_kernels import (bench_centering_kernel,  # noqa: E402
                                      bench_gram_kernel)
from benchmarks.bench_kpca import (bench_runtime_vs_central,  # noqa: E402
                                   bench_similarity_vs_neighbors,
                                   bench_similarity_vs_nodes,
                                   bench_similarity_vs_samples)
from benchmarks.bench_roofline import bench_roofline_summary  # noqa: E402
from benchmarks.bench_serve_kpca import bench_serve_kpca  # noqa: E402

SUITES = {
    "fig3": bench_similarity_vs_nodes,
    "fig4": bench_similarity_vs_samples,
    "fig5": bench_similarity_vs_neighbors,
    "rt": bench_runtime_vs_central,
    "kernels": lambda: bench_gram_kernel() + bench_centering_kernel(),
    "roofline": bench_roofline_summary,
    "serve": bench_serve_kpca,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="smaller feature dim for fast CI runs")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for name in names:
        fn = SUITES[name]
        if args.quick and name in ("fig3", "fig4", "fig5", "rt", "serve"):
            rows = fn(m=64)
        else:
            rows = fn()
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
