"""Expose multiple host CPU devices for the in-process sharded-serving
tests (tests/test_sharded_serving.py builds 1/2/4-device meshes).

Must run before jax initializes its backends; conftest import precedes every
test module, and nothing imports jax at collection time before this. The
subprocess-based distributed tests (tests/helpers/*, test_substrate
elastic-reshard) set their own XLA_FLAGS and are unaffected.
"""

import os

_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

# The runtime lock-order detector (tests/helpers/lockcheck.py) registers an
# autouse fixture that instruments every serve-layer lock in tests marked
# @pytest.mark.lockcheck and fails them on a recorded AB/BA cycle.
pytest_plugins = ["helpers.lockcheck"]
