"""qwen3-32b [dense] 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
        n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151936, head_dim=128,
        qk_norm=True, rope_theta=1000000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=160, vocab=512, head_dim=16,
        qk_norm=True, rope_theta=1000000.0, remat="none")
