"""Pure-jnp oracle for the fused local ADMM update."""

from __future__ import annotations

import jax.numpy as jnp


def admm_local_update_reference(v, inv_den, k, b, g, rho_slots):
    """Same contract as ops.admm_local_update_op (J-batched)."""
    rhs = jnp.sum(rho_slots * g - b, axis=2, keepdims=True)      # (J, N, 1)
    t = jnp.einsum("jnm,jn1->jm1", v, rhs) * inv_den
    alpha = jnp.einsum("jnm,jm1->jn1", v, t)
    ka = jnp.einsum("jnm,jm1->jn1", k, alpha)
    b_new = b + rho_slots * (ka - g)
    return alpha, b_new
