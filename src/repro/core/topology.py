"""Network topologies for the decentralized consensus graph.

The paper assumes a symmetric, undirected, connected graph G = (V, E)
(Assumption 1); its experiments use a ring where each node talks to the k
nearest nodes (k/2 on each side). On TPU, that ring maps 1:1 onto the ICI
torus via ``collective_permute`` shifts — see ``ring_shifts``.

This module is pure-numpy/static: topology is resolved at trace time and
baked into the compiled program (messages become static permutations).
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph with per-node ordered neighbor lists.

    nbr[j]  : ordered list of neighbor ids of node j (Omega_j).
    rev[j][d]: index of j within nbr[l] where l = nbr[j][d] (the "reverse
               slot"), needed to pick the right column of B_l = phi(X_l)^T eta_l.
    """

    n_nodes: int
    nbr: tuple  # tuple of tuples
    rev: tuple

    @property
    def degrees(self) -> np.ndarray:
        return np.array([len(o) for o in self.nbr], dtype=np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def is_regular(self) -> bool:
        d = self.degrees
        return bool((d == d[0]).all())

    def validate(self):
        for j, om in enumerate(self.nbr):
            if len(om) == 0:
                raise ValueError(f"node {j} has no neighbors (paper requires |Omega_j| >= 1)")
            if j in om:
                raise ValueError(f"node {j} lists itself as neighbor")
            for d, l in enumerate(om):
                if self.nbr[l][self.rev[j][d]] != j:
                    raise ValueError(f"rev-slot inconsistency at ({j},{l})")
        if not self.connected():
            raise ValueError("graph is not connected (Assumption 1 violated)")

    def connected(self) -> bool:
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for v in self.nbr[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return len(seen) == self.n_nodes

    def neighbor_array(self, pad_to: int | None = None):
        """(J, D) int32 neighbor ids + (J, D) bool mask, padded with 0."""
        d_max = pad_to or self.max_degree
        j = self.n_nodes
        ids = np.zeros((j, d_max), np.int32)
        rev = np.zeros((j, d_max), np.int32)
        mask = np.zeros((j, d_max), bool)
        for u, om in enumerate(self.nbr):
            ids[u, : len(om)] = om
            rev[u, : len(om)] = self.rev[u]
            mask[u, : len(om)] = True
        return ids, rev, mask


def _build(n_nodes: int, nbr: List[List[int]]) -> Graph:
    rev = []
    for j, om in enumerate(nbr):
        rev.append(tuple(nbr[l].index(j) for l in om))
    g = Graph(n_nodes, tuple(tuple(o) for o in nbr), tuple(rev))
    g.validate()
    return g


def ring(n_nodes: int, hops: int = 1) -> Graph:
    """Ring where each node connects to ``hops`` nodes on each side
    (|Omega_j| = 2*hops). The paper's "4 closest neighbors" = ring(J, 2).
    Neighbor slot order is [-hops, ..., -1, +1, ..., +hops] (offsets mod J)."""
    if n_nodes < 2 * hops + 1:
        raise ValueError(f"ring({n_nodes}, hops={hops}) would double-connect")
    offs = list(range(-hops, 0)) + list(range(1, hops + 1))
    nbr = [[(j + o) % n_nodes for o in offs] for j in range(n_nodes)]
    return _build(n_nodes, nbr)


def ring_shifts(hops: int) -> List[int]:
    """Slot-ordered ppermute shifts matching ``ring`` neighbor order."""
    return list(range(-hops, 0)) + list(range(1, hops + 1))


def complete(n_nodes: int) -> Graph:
    nbr = [[q for q in range(n_nodes) if q != j] for j in range(n_nodes)]
    return _build(n_nodes, nbr)


def random_connected(n_nodes: int, extra_edge_prob: float = 0.2,
                     seed: int = 0) -> Graph:
    """Random connected graph: a ring(J,1) backbone + random chords."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((n_nodes, n_nodes), bool)
    for j in range(n_nodes):
        adj[j, (j + 1) % n_nodes] = adj[(j + 1) % n_nodes, j] = True
    chords = rng.random((n_nodes, n_nodes)) < extra_edge_prob
    chords = np.triu(chords, 2)
    adj |= chords | chords.T
    np.fill_diagonal(adj, False)
    nbr = [sorted(np.nonzero(adj[j])[0].tolist()) for j in range(n_nodes)]
    return _build(n_nodes, nbr)


def from_adjacency(adj: np.ndarray) -> Graph:
    adj = np.asarray(adj, bool)
    if not (adj == adj.T).all():
        raise ValueError("adjacency must be symmetric (undirected graph)")
    nbr = [sorted(np.nonzero(adj[j])[0].tolist()) for j in range(adj.shape[0])]
    return _build(adj.shape[0], nbr)


def reknit(graph: Graph, dead: Sequence[int]) -> tuple:
    """Fault tolerance: remove dead nodes and re-knit the survivors.

    Survivors keep their surviving edges; any survivor left isolated (all its
    neighbors died) is reconnected to the nearest surviving node ids on each
    side (ring semantics). Returns (new_graph, survivor_ids) where
    survivor_ids maps new node index -> original node index.

    This models a production cluster losing hosts: the consensus graph is
    rebuilt locally and ADMM continues on the reduced node set (the optimum
    changes — it is now the kPCA of the surviving data — but Theorem 1/2
    still apply since the reduced graph stays connected).
    """
    dead_set = set(int(d) for d in dead)
    survivors = [j for j in range(graph.n_nodes) if j not in dead_set]
    if len(survivors) < 2:
        raise ValueError("fewer than 2 survivors")
    old2new = {o: n for n, o in enumerate(survivors)}
    nbr = []
    for o in survivors:
        kept = [old2new[l] for l in graph.nbr[o] if l not in dead_set]
        nbr.append(kept)
    # reconnect isolated survivors to ring-adjacent survivors
    s = len(survivors)
    for n in range(s):
        if not nbr[n]:
            left, right = (n - 1) % s, (n + 1) % s
            for other in (left, right):
                if other != n and other not in nbr[n]:
                    nbr[n].append(other)
                    nbr[other].append(n)
    # if disconnection remains (a dead node was a cut vertex), add ring edges
    g = _try_build(len(survivors), nbr)
    if g is None:
        for n in range(s):
            nxt = (n + 1) % s
            if nxt not in nbr[n]:
                nbr[n].append(nxt)
                nbr[nxt].append(n)
        g = _try_build(len(survivors), nbr)
        assert g is not None
    return g, np.array(survivors, np.int32)


def _try_build(n_nodes, nbr):
    try:
        return _build(n_nodes, [sorted(o) for o in nbr])
    except ValueError:
        return None
