"""Central kernel PCA — the paper's ground-truth baseline (problem (2)).

Solves the eigenproblem of the (centered) global Gram matrix; the solution
``alpha_gt`` is normalized so that ||w*|| = 1 in feature space, i.e.
||alpha|| = 1/sqrt(lambda_1) (paper §1).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels_math import KernelSpec, center_gram, gram, topk_eigh


@partial(jax.jit, static_argnames=("spec", "n_components", "center"))
def central_kpca(x: jax.Array, spec: KernelSpec, n_components: int = 1,
                 center: bool = True, gamma: Optional[jax.Array] = None):
    """Central kPCA on the full dataset x: (N, M).

    Returns (alpha, lam, k): alpha (N, n_components) with columns normalized
    to 1/sqrt(lam_i); lam (n_components,) descending; k the (centered) Gram.
    """
    k = gram(spec, x, gamma=gamma)
    if center:
        k = center_gram(k)
    lam, vec = topk_eigh(k, n_components)
    lam = jnp.maximum(lam, 1e-12)
    alpha = vec / jnp.sqrt(lam)[None, :]
    return alpha, lam, k


def kpca_project(x_new: jax.Array, x_train: jax.Array, alpha: jax.Array,
                 spec: KernelSpec, gamma: Optional[jax.Array] = None):
    """Project new points onto learned components (paper §1):
    (w*)^T phi_c(x') = sum_i alpha_i [K(x_i, x') - m(x') - m_i + mu_bar].

    Always applies the training kernel-mean correction, matching components
    fit on the *centered* Gram. (The historical raw ``kx @ alpha`` path —
    ``center=False`` — silently disagreed with a centered fit; it went
    through a DeprecationWarning cycle and is now removed. For an
    uncentered fit, build the artifact explicitly:
    ``oos.from_dual(..., center=False)`` + ``oos.project``.)

    NOTE: this is a stateless convenience for one-off projections; every
    call re-derives the kernel-mean statistics from the full (N, N)
    training Gram. Projecting repeatedly against the same fit? Build the
    artifact once (``oos.from_dual`` / ``oos.fit_central``) and call
    ``oos.project`` — that is the serving path.
    """
    from . import oos
    squeeze = alpha.ndim == 1
    model = oos.from_dual(x_train, alpha, spec, gamma=gamma, center=True)
    out = oos.project(model, x_new)
    return out[:, 0] if squeeze else out
