#!/usr/bin/env python
"""Benchmark regression gate (stdlib only; CI step).

Compares a freshly generated bench JSON (``benchmarks/run.py --out``)
against the committed baseline and FAILS when a guarded row's throughput
regressed by more than the tolerance. The guarded rows are the paths the
dispatch-gap and sharded-routing work optimize end to end:

  * ``serve/batch64``          — batched synchronous serving throughput
  * ``serve_async/threads4``   — async futures pipeline under concurrency
  * ``serve/shards4_lmfull``   — adaptively routed sharded serving (the
                                 row the router rescued from losing to
                                 one shard)
  * ``serve/shards4_N4096_b4096`` — the data-parallel large-support drain,
                                 the config where shards>1 beats shards=1

    python scripts/check_bench_regression.py \
        --baseline BENCH_10.json --current bench-fresh.json

Tolerance is deliberately wide (30% qps drop) because CI boxes are noisy
and shared: the gate exists to catch a dispatch-path pessimization (2-5x
regressions, the kind PR 9 removed), not 5% jitter. Rows missing from
either file fail loudly — a silently dropped row is how a regression
hides. Exit codes: 0 ok, 1 regression/missing row, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import re
import sys

GUARDED_ROWS = ("serve/batch64", "serve_async/threads4",
                "serve/shards4_lmfull", "serve/shards4_N4096_b4096")
_QPS = re.compile(r"(?:^|;)qps=([0-9.eE+-]+)")


def load_qps(path: str) -> dict:
    """name -> qps for every row carrying a ``qps=`` derived field."""
    with open(path) as f:
        payload = json.load(f)
    out = {}
    for row in payload.get("rows", []):
        m = _QPS.search(row.get("derived", "") or "")
        if m:
            out[row["name"]] = float(m.group(1))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed bench JSON (e.g. BENCH_10.json)")
    ap.add_argument("--current", required=True,
                    help="freshly generated bench JSON to check")
    ap.add_argument("--rows", nargs="*", default=list(GUARDED_ROWS),
                    help="row names to guard (default: the dispatch-path "
                         "pair)")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max fractional qps drop before failing "
                         "(default 0.30)")
    args = ap.parse_args(argv)

    base = load_qps(args.baseline)
    cur = load_qps(args.current)
    failures = []
    for name in args.rows:
        if name not in base:
            failures.append(f"{name}: missing from baseline "
                            f"{args.baseline}")
            continue
        if name not in cur:
            failures.append(f"{name}: missing from current {args.current}")
            continue
        drop = 1.0 - cur[name] / base[name]
        status = "REGRESSED" if drop > args.tolerance else "ok"
        print(f"{name}: baseline={base[name]:.0f} qps "
              f"current={cur[name]:.0f} qps "
              f"delta={-drop * 100:+.1f}% [{status}]")
        if drop > args.tolerance:
            failures.append(
                f"{name}: {cur[name]:.0f} qps is "
                f"{drop * 100:.1f}% below baseline {base[name]:.0f} "
                f"(tolerance {args.tolerance * 100:.0f}%)")
    for msg in failures:
        print(f"FAIL {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
