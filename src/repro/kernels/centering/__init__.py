from .ops import center_op
from .ref import center_reference

__all__ = ["center_op", "center_reference"]
