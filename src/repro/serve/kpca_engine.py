"""Batched kPCA projection-serving engine (fit once, serve many).

The serving workload is the mirror image of ``DecodeEngine``: stateless
per-query math instead of a KV cache, so the engine's whole job is shaping
traffic for the compiled step. Variable-size requests are packed head-to-
tail into fixed-width slabs and padded up to POWER-OF-TWO shape buckets, so
a bounded set of compiled programs (log2(max_batch) of them) serves any
request mix with zero recompiles in steady state — the classic bucketing
trick from LM serving applied to kernel projection. The queue/bucket/slab
machinery itself lives in ``repro.serve.batching`` (shared with the decode
engine).

The request path is an ASYNC pipeline: ``submit`` returns a
``concurrent.futures`` future immediately; a background flusher thread
(``start``/``close``) drains the queue on a size-OR-deadline trigger and
resolves the futures, so query batching overlaps with callers' work the
same way the solver overlaps computation with communication. ``flush`` is
the synchronous drain (same packing, same math — the async path is
result-exact against it), and ``project_many`` the one-call convenience.

Guarantees and knobs:
  * results are exactly what ``repro.core.oos.project`` returns for each
    request alone — padding rows are sliced off and row-wise kernel math
    makes valid rows independent of them (asserted to float32 resolution in
    tests/test_kpca_engine.py; the only packing residue is XLA choosing a
    different gemm code path per slab shape, <= 4e-9 observed);
  * admission control: ``queue_factor=k`` bounds the queue at
    ``max_batch * k`` rows — beyond it ``submit`` rejects
    (``QueueFullError``) or sheds the oldest queued requests, per
    ``cfg.admission``; counters surface in ``EngineStats``;
  * ``use_pallas`` routes through the fused Pallas projection kernel;
  * ``query_dtype=jnp.bfloat16`` halves query-slab HBM traffic (accumulation
    stays fp32 inside the kernel) for throughput-bound fleets;
  * per-request latency/queue-wait and queries/s accounting built in
    (served straight into benchmarks/bench_serve_async.py).
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
import warnings
from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core import oos
from ..core.oos import FittedKpca, ShardedFittedKpca
from ..faults.errors import DeadlineExceededError
from ..obs import metrics, trace
from .batching import (EngineStats, FlushSlots, QueueFullError,
                       RequestFuture, RequestQueue, RequestStats, SlabArena,
                       SlotFuture, pack_slabs, pow2_buckets)
from .publisher import ModelHandle
from .sharded import ShardedRouter, ShardedScores

# Donation is declared unconditionally on the serve entry points; backends
# that cannot reuse the query slab's buffer for the output (CPU: shapes
# differ) silently fall back to a copy, which XLA reports per compiled
# shape. That fallback is this engine's documented behavior, not a bug to
# surface on every warmup — keep the filter as narrow as the message.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")


@dataclasses.dataclass
class KpcaServeConfig:
    max_batch: int = 128          # widest bucket = compiled slab width
    min_bucket: int = 8           # narrowest bucket (absorbs tiny tails)
    use_pallas: bool = False      # fused Pallas kernel (interpret off-TPU)
    query_dtype: Any = None       # e.g. jnp.bfloat16 for cheaper slabs
    interpret: Optional[bool] = None  # forwarded to the Pallas wrapper
    # -- async flusher / admission control --------------------------------
    queue_factor: Optional[int] = None  # queue bound = max_batch * k rows;
    #                                     None = unbounded, no admission
    admission: str = "reject"     # "reject" new or "shed" oldest when full
    flush_max_wait_s: float = 0.005   # deadline trigger: max queue wait of
    #                                   the oldest request before a flush
    flush_min_queries: Optional[int] = None  # size trigger (None: max_batch)
    flush_eager: bool = True      # idle flusher drains on ANY queued work
    #                               instead of sleeping out the deadline;
    #                               batching still emerges under load (the
    #                               queue fills while a flush is in flight)
    flush_coalesce_s: float = 0.0002  # pipelined-mode arrival damper: while
    #                               a previous drain still occupies the
    #                               device runner, keep waiting in slices of
    #                               this quantum as long as rows keep
    #                               arriving, so one wave of submitters
    #                               drains as one slab. Only charged when
    #                               the wait is free (device busy); an idle
    #                               pipeline never waits (0: off)
    # -- hot-path plumbing (docs/PERFORMANCE.md) ---------------------------
    donate: bool = True           # dispatch via donate_argnums entry points
    warmup: bool = True           # compile all pow2 buckets at start()
    arena_factor: int = 4         # staging ring >= max_batch * factor rows
    pipeline_depth: int = 2       # max in-flight drains when the flusher
    #                               pipelines resolve through the device-
    #                               runner thread (fail-fast configs only)
    # -- sharded routing (docs/PERFORMANCE.md: sharded drain anatomy) ------
    routing: str = "auto"         # sharded models: "auto" routes per slab
    #                               via the crossover table; "mp"/"dp"/
    #                               "single" force one policy
    crossover: Any = None         # CrossoverTable override for "auto"
    #                               (None: container-measured defaults;
    #                               repro.serve.sharded.measure_crossover
    #                               builds a host-specific one)
    # -- fault tolerance (docs/FAULT_TOLERANCE.md) -------------------------
    max_retries: int = 0          # extra serve attempts per drain; 0 keeps
    #                               the fail-fast contract (a failed batch
    #                               fails exactly its own futures)
    retry_backoff_s: float = 0.02     # base backoff, doubled per attempt
    #                                   (skipped when on_fault healed it)
    request_deadline_s: Optional[float] = None  # submit -> serve budget;
    #                               expired requests fail with
    #                               DeadlineExceededError instead of being
    #                               served late (None = no deadline)

    def buckets(self) -> List[int]:
        """Power-of-two widths: min_bucket, 2*min_bucket, ..., max_batch."""
        return pow2_buckets(self.min_bucket, self.max_batch)

    def queue_capacity(self) -> Optional[int]:
        if self.queue_factor is None:
            return None
        if self.queue_factor < 1:
            raise ValueError(
                f"queue_factor must be >= 1, got {self.queue_factor}")
        return self.max_batch * self.queue_factor


class KpcaEngine:
    """Micro-batching projection server over a fitted kPCA artifact.

    Accepts either a single-device ``FittedKpca`` (scored via
    ``repro.core.oos.project``) or a multi-device ``ShardedFittedKpca``,
    dispatched through a ``repro.serve.sharded.ShardedRouter``: each slab
    is routed model-parallel (support sharded, queries replicated, psum),
    data-parallel (query rows sharded, no reduction), or single-device per
    ``cfg.routing`` and the measured crossover table, against a
    per-version cached device placement of the model. The
    batching/bucketing layer is identical for both model kinds, so the
    engine's traffic shaping composes with device sharding unchanged.

    Request API: ``submit`` enqueues and returns a future; results arrive
    when a drain happens — synchronously via ``flush`` (or ``project_many``),
    or from the background flusher thread between ``start`` and ``close``
    (the engine is also a context manager doing exactly that). Both drains
    run the same packing and the same compiled programs, so async results
    are exact against the synchronous path.

    Live updates: the engine reads its model THROUGH a versioned
    ``repro.serve.publisher.ModelHandle`` (a bare model is wrapped in a
    private one). Each drain snapshots (model, version) once, so every
    slab of that drain — and therefore every in-flight request — is scored
    against one consistent version even if a publish lands mid-drain; the
    next drain picks up the new version. For sharded models a per-shard
    coefficient refresh is still one atomic whole-model publish
    (``ModelHandle.refresh_shard``), so no request can ever see a mix of
    shard versions. ``RequestStats.model_version`` records which version
    served each request.
    """

    def __init__(self,
                 model: Union[FittedKpca, ShardedFittedKpca, ModelHandle],
                 cfg: KpcaServeConfig = None, mesh=None,
                 inject_fault=None, on_fault=None):
        """Args:
          model: servable artifact (plain or sharded) or a ``ModelHandle``
            wrapping one (live-publishable).
          cfg: batching/bucketing/backend/admission knobs
            (``KpcaServeConfig``).
          mesh: for sharded models only — 1-D device mesh with
            ``model.n_shards`` devices; None builds one over local devices
            (or falls back to a same-math single-device reduction).
          inject_fault: optional ``model -> None`` hook called at the top
            of every drain attempt with the snapshotted model; raising
            aborts the attempt. The deterministic chaos tests use it
            (``repro.faults.serving.ShardLossInjector``) to stand in for
            a dead shard host — production engines leave it None.
          on_fault: optional ``(exc, handle) -> bool`` recovery hook
            called when a drain attempt fails and retries remain.
            Returning True means "handled — retry immediately" (e.g.
            ``ShardRebalancer`` republished a survivor model, which the
            next attempt picks up because every attempt re-reads the
            handle); False falls back to exponential backoff.
        """
        self.handle = model if isinstance(model, ModelHandle) \
            else ModelHandle(model)
        model = self.handle.current()
        self.cfg = cfg or KpcaServeConfig()
        self._inject_fault = inject_fault
        self._on_fault = on_fault
        self._buckets = self.cfg.buckets()
        # _dispatch_lock orders concurrent drains' device programs; it is
        # held only across the (async) dispatch calls, never across a
        # device sync — the blocking host<->device copies happen outside
        # it (see _serve). _stats_lock guards the host-side accounting
        # that submitters and drains both touch.
        self._dispatch_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._compiled_shapes = set()         # guarded-by: _stats_lock
        self.stats = EngineStats()            # guarded-by: _stats_lock
        # Submit-time staging ring: sized to hold at least the queue bound
        # (so an admitted request practically always fits) and never less
        # than arena_factor full slabs.
        cap = self.cfg.queue_capacity()
        arena_rows = max(cap or 0, self.cfg.max_batch * self.cfg.arena_factor)
        self._arena = SlabArena(model.n_features, arena_rows)
        self._queue = RequestQueue(max_queries=cap,
                                   policy=self.cfg.admission,
                                   slot_futures=True,
                                   on_shed=self._release_entries)
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        # Device-runner thread (created by start()): on backends where jit
        # calls block on compute inline (CPU), it keeps the flusher's
        # dispatch phase enqueue-only so packing the next drain overlaps
        # the device work of this one.
        self._device_pool: Optional[concurrent.futures.ThreadPoolExecutor] \
            = None
        # Cached metric handles, resolved once: the hot path must not pay
        # a registry lookup per drain (and pays nothing per submit — all
        # metric publication happens at the per-drain commit point).
        self._m_requests = metrics.counter(
            "serve_requests_total", "Requests served")
        self._m_queries = metrics.counter(
            "serve_queries_total", "Query rows served")
        self._m_padded = metrics.counter(
            "serve_padded_rows_total", "Wasted pad rows computed")
        self._m_rejected = metrics.counter(
            "serve_rejected_total", "Admissions refused (QueueFullError)")
        self._m_shed = metrics.counter(
            "serve_shed_total", "Queued requests shed to admit newer ones")
        self._m_flushes = metrics.counter(
            "serve_flushes_total", "Drain cycles that served >= 1 request")
        self._m_depth = metrics.gauge(
            "serve_queue_depth_rows", "Queued rows after the last drain")
        self._m_version = metrics.gauge(
            "serve_model_version", "Model version the last drain served")
        self._m_latency = metrics.histogram(
            "serve_request_latency_seconds", "Per-request device wall time")
        self._m_wait = metrics.histogram(
            "serve_queue_wait_seconds", "Submit -> start-of-serve wait")
        self._m_retries = metrics.counter(
            "serve_retries_total", "Drain attempts retried after a fault")
        self._m_expired = metrics.counter(
            "serve_deadline_expired_total",
            "Requests failed on the per-request deadline")
        self._m_zero_copy = metrics.counter(
            "serve_zero_copy_slabs_total",
            "Slabs dispatched as arena slices (no pack copy)")
        self._m_donated = metrics.counter(
            "serve_donated_total", "Slabs dispatched with buffer donation")
        self._m_arena_fallback = metrics.counter(
            "serve_arena_fallback_total",
            "Submits that missed the staging ring (malloc fallback)")
        self._m_warmup = metrics.counter(
            "serve_warmup_compiles_total",
            "Programs compiled by the start() warmup pass")

        if isinstance(model, ShardedFittedKpca):
            from ..launch.mesh import make_serving_mesh
            if mesh is None:
                mesh = make_serving_mesh(model.n_shards)
            # The router owns the whole sharded hot path: the per-slab
            # policy decision (model-parallel psum vs data-parallel vs
            # single-device), per-policy donated jit entry points, and a
            # model placement cache keyed on the handle version — so
            # steady-state drains never re-transfer the model.
            self._router = ShardedRouter(
                mesh, use_pallas=self.cfg.use_pallas,
                interpret=self.cfg.interpret, policy=self.cfg.routing,
                crossover=self.cfg.crossover, donate=self.cfg.donate)
            self._proj = self._proj_donated = None
        else:
            if mesh is not None:
                raise ValueError("mesh is only meaningful for a "
                                 "ShardedFittedKpca model")
            if self.cfg.routing != "auto":
                raise ValueError("cfg.routing is only meaningful for a "
                                 "ShardedFittedKpca model")
            self._router = None

            def _proj(m, xq):
                return oos.project(m, xq, use_pallas=self.cfg.use_pallas,
                                   interpret=self.cfg.interpret)

            self._proj = jax.jit(_proj)
            # Donated twin: XLA may reuse the query slab's buffer for an
            # intermediate/output instead of allocating. The slab is
            # staged fresh per dispatch and never read afterwards, so
            # donation is unconditionally safe; ``cfg.donate`` picks which
            # entry point the serve path (and the start() warmup) uses.
            self._proj_donated = jax.jit(_proj, donate_argnums=(1,)) \
                if self.cfg.donate else self._proj

    @property
    def model(self):
        """The live model (read through the handle)."""
        return self.handle.current()

    def _release_entries(self, entries) -> None:
        """Return entries' staged arena rows (shed/expired/failed/served)."""
        for e in entries:
            if e.arena_start is not None:
                self._arena.release(e.arena_start)
                e.arena_start = None

    # ---- request API -----------------------------------------------------

    def submit(self, x_query) -> SlotFuture:
        """Enqueue one request; returns its result future immediately.

        Args:
          x_query: (Q, M) array-like, M = model.n_features; cast to fp32
            host-side (the engine re-casts per ``cfg.query_dtype`` at slab
            build time).

        Returns:
          A ``concurrent.futures`` future resolving to this request's
          (Q, C) float32 scores at the next drain — the background
          flusher's (when running) or an explicit ``flush``. The future
          also carries ``request_id``, the request's key in the dict
          ``flush`` returns.

        Raises:
          QueueFullError: admission control refused the request
            (``cfg.queue_factor`` bound exceeded under policy "reject", or
            the request alone exceeds the whole queue capacity).
        """
        x = np.asarray(x_query, np.float32)
        if x.ndim != 2 or x.shape[1] != self.model.n_features:
            raise ValueError(
                f"request must be (Q, {self.model.n_features}), "
                f"got {x.shape}")
        # Stage the rows into the arena NOW so the flusher's pack is a
        # slice; a full ring falls back to the request's own array.
        arena_start = self._arena.stage(x) if x.shape[0] else None
        if arena_start is None and x.shape[0]:
            self._m_arena_fallback.inc()
        try:
            fut, shed = self._queue.put(x, n=x.shape[0],
                                        arena_start=arena_start)
        except QueueFullError:
            if arena_start is not None:
                self._arena.release(arena_start)
            with self._stats_lock:
                self.stats.n_rejected += 1
            self._m_rejected.inc()
            trace.instant("serve.rejected", n=x.shape[0])
            raise
        if shed:
            with self._stats_lock:
                self.stats.n_shed += len(shed)
            self._m_shed.inc(len(shed))
        return fut

    def flush(self) -> dict:
        """Serve every queued request synchronously; resolves the futures
        and returns {request_id: (Q, C) scores}.

        On failure (after ``cfg.max_retries`` attempts) the still-live
        queued requests are restored (ahead of anything submitted
        meanwhile), so a crashed flush can simply be retried. Requests
        past ``cfg.request_deadline_s`` fail with
        ``DeadlineExceededError`` instead of being restored.
        """
        entries = self._queue.drain()
        if not entries:
            return {}
        entries = list(entries)
        try:
            out, served = self._serve_with_recovery(entries)
        except BaseException:
            # `entries` was pruned in place: expired futures are already
            # failed and must not re-enter the queue.
            self._queue.restore(entries)
            raise
        self._resolve(served, out)
        return out

    def project_many(self, requests: Sequence[Any]) -> List[np.ndarray]:
        """Convenience: submit + flush a list of (Q_i, M) arrays; returns
        the per-request (Q_i, C) score arrays in submission order."""
        futs = [self.submit(x) for x in requests]
        self.flush()
        return [f.result() for f in futs]

    # ---- background flusher ----------------------------------------------

    def start(self) -> "KpcaEngine":
        """Start the background flusher thread (idempotent).

        The flusher sleeps on the queue and drains it whenever a trigger
        fires: with ``cfg.flush_eager`` (default) any queued work wakes an
        idle flusher immediately — batching emerges from backpressure
        while a flush is in flight; otherwise it waits for
        ``cfg.flush_min_queries`` rows (default: one full ``max_batch``
        slab) or the oldest request hitting ``cfg.flush_max_wait_s``. A
        failed drain fails exactly the futures of that batch (no retry
        loop) and keeps serving.

        Also brings up the rest of the steady-state hot path: the
        device-runner thread (dispatch becomes enqueue-only) and — unless
        ``cfg.warmup`` is off — a warmup pass compiling every pow2
        bucket's program so traffic never sees a compile
        (``stats.n_compiles`` stays 0; warmup builds are counted in
        ``stats.n_warmup_compiles``).
        """
        if self._flusher is not None:
            return self
        if self._device_pool is None:
            self._device_pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="kpca-device")
        if self.cfg.warmup:
            self.warmup()
        self._stop.clear()
        self._flusher = threading.Thread(
            target=self._flush_loop, name="kpca-engine-flusher", daemon=True)
        self._flusher.start()
        return self

    def warmup(self) -> int:
        """Compile the serve entry point for every pow2 bucket (idempotent
        per shape); returns the number of programs built. Runs the REAL
        dispatch path (donated entry point included; for sharded models
        the router's policy-and-placement path, so the policy the router
        will pick for each bucket is the one compiled) — steady-state
        traffic is guaranteed cache hits."""
        model, version = self.handle.get()
        with self._stats_lock:
            built0 = self.stats.n_warmup_compiles
        with trace.span("serve.warmup", n_buckets=len(self._buckets)):
            for b in self._buckets:
                slab = np.zeros((b, model.n_features), np.float32)
                # The dispatch entry itself, not _run_slab: the
                # fault-injection seam wraps _run_slab and must only see
                # real traffic, while the compile cache this fills is
                # keyed on the entry point + shapes either way. Routing is
                # deterministic in (rows, model), so warming the chosen
                # policy per bucket covers everything traffic can hit.
                if self._router is not None:
                    policy = self._router.choose(b, model)
                    xq = self._stage_slab(slab, warmup=True, policy=policy)
                    np.asarray(self._router.dispatch(
                        model, version, xq, policy).scores)
                else:
                    xq = self._stage_slab(slab, warmup=True)
                    np.asarray(self._proj_donated(model, xq))
        with self._stats_lock:
            built = self.stats.n_warmup_compiles - built0
        if built:
            self._m_warmup.inc(built)
        return built

    def close(self, drain: bool = True) -> None:
        """Stop the flusher thread (joined) and settle the queue: serve
        everything still queued when ``drain`` (default), else cancel the
        pending futures. Safe to call twice; ``flush``/``submit`` keep
        working afterwards (synchronous mode)."""
        if self._flusher is not None:
            self._stop.set()
            self._queue.kick()
            self._flusher.join(timeout=30.0)
            if self._flusher.is_alive():       # pragma: no cover
                raise RuntimeError("flusher thread failed to stop")
            self._flusher = None
        if drain:
            self.flush()
        else:
            dropped = self._queue.drain()
            self._release_entries(dropped)
            for e in dropped:
                e.future.cancel()
        if self._device_pool is not None:
            self._device_pool.shutdown(wait=True)
            self._device_pool = None

    @property
    def running(self) -> bool:
        return self._flusher is not None

    def __enter__(self) -> "KpcaEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=exc[0] is None)

    def _flush_loop(self) -> None:
        # Eager mode: an idle flusher drains on ANY queued work instead of
        # sleeping toward flush_max_wait_s waiting for a full slab. Under
        # load the queue refills while a flush is in flight, so big slabs
        # still form — without load there is nothing to batch against and
        # waiting only adds latency.
        trigger = 1 if self.cfg.flush_eager \
            else (self.cfg.flush_min_queries or self.cfg.max_batch)
        # Pipelined drains hand the device wait + result assembly + future
        # resolution to the device-runner thread, so submitter wakeups and
        # the NEXT drain's pack overlap this drain's compute. Retries,
        # deadlines, and recovery hooks need the synchronous drain (they
        # re-attempt with restored state), so those configs keep it.
        pipelined = (self._device_pool is not None
                     and self.cfg.max_retries == 0
                     and self.cfg.request_deadline_s is None
                     and self._on_fault is None)
        inflight: collections.deque = collections.deque()
        last_n = 0                    # requests in the previous drain
        try:
            while True:
                has_work = self._queue.wait_for_work(
                    trigger, self.cfg.flush_max_wait_s, self._stop)
                if self._stop.is_set():
                    return            # close() settles whatever remains
                if not has_work:
                    continue
                while inflight and inflight[0].done():
                    inflight.popleft().result()
                if inflight:
                    # Dynamic batching: the device runner is busy, so
                    # cutting a drain now buys nothing — the new slab
                    # would only queue behind it. Hold the drain open
                    # until the runner frees or a full batch forms;
                    # every request arriving meanwhile rides one slab.
                    while (not inflight[0].done() and not self._stop.is_set()
                           and self._queue.depth < self.cfg.max_batch):
                        time.sleep(5e-5)
                    while inflight and inflight[0].done():
                        inflight.popleft().result()
                    if last_n > 1:
                        # The drain that just finished resolved a wave;
                        # give its submitters one stall window to
                        # resubmit so the wave stays together instead of
                        # splitting across two half-size drains.
                        self._queue.coalesce(self.cfg.max_batch,
                                             self.cfg.flush_coalesce_s,
                                             self._stop)
                elif last_n > 1:
                    # Idle runner but the last drain resolved a WAVE of
                    # submitters, who are all waking to resubmit right
                    # now — yield until the wave lands so it drains as
                    # one slab. A lone submitter (last_n <= 1) never
                    # waits: there is no wave to collect, only latency
                    # to add.
                    self._queue.coalesce(self.cfg.max_batch,
                                         self.cfg.flush_coalesce_s,
                                         self._stop)
                entries = self._queue.drain()
                if not entries:
                    continue
                entries = list(entries)
                last_n = len(entries)
                if pipelined:
                    while len(inflight) >= self.cfg.pipeline_depth:
                        inflight.popleft().result()
                    try:
                        inflight.append(self._dispatch_async(entries))
                    except BaseException as e:   # fail THIS batch only
                        self._fail_entries(entries, e)
                    with self._stats_lock:
                        if len(inflight) > self.stats.max_inflight_drains:
                            self.stats.max_inflight_drains = len(inflight)
                    continue
                try:
                    out, served = self._serve_with_recovery(entries)
                except BaseException as e:   # fail THIS batch, keep serving
                    self._fail_entries(entries, e)
                    continue
                self._resolve(served, out)
        finally:
            # Settle in-flight pipelined drains before the thread exits,
            # so close() observes every submitted future resolved.
            while inflight:
                inflight.popleft().result()

    def _fail_entries(self, entries, exc: BaseException) -> None:
        """Fail one drain's futures with ``exc`` (arena rows released)."""
        self._release_entries(entries)
        for en in entries:
            if not en.future.done():
                en.future.set_exception(exc)

    @staticmethod
    def _resolve(entries, out: dict) -> None:
        """Resolve one drain's futures. SlotFutures resolve through a
        shared per-flush slot table — one list publish + ONE event
        broadcast for the whole drain; anything else (decode-style
        RequestFutures) falls back to per-future set_result."""
        with trace.span("serve.resolve", n_requests=len(entries)):
            slot_pairs, results = [], []
            for e in entries:
                if isinstance(e.future, SlotFuture):
                    slot_pairs.append((e.future, len(results)))
                    results.append(out[e.rid])
                elif not e.future.done():    # skip caller-cancelled futures
                    e.future.set_result(out[e.rid])
            if slot_pairs:
                slots = FlushSlots()
                slots.results = results
                SlotFuture.bind(slot_pairs, slots)   # skips cancelled
                slots.event.set()

    # ---- internals -------------------------------------------------------

    def _expire(self, entries: list) -> list:
        """Split off deadline-expired requests; their futures fail NOW
        with ``DeadlineExceededError`` (typed, never served late).
        Returns the still-live entries."""
        ddl = self.cfg.request_deadline_s
        if ddl is None:
            return entries
        now = time.monotonic()
        live, expired = [], []
        for e in entries:
            waited = now - e.t_submit
            if waited > ddl:
                expired.append(e)
                if not e.future.done():
                    e.future.set_exception(DeadlineExceededError(waited, ddl))
            else:
                live.append(e)
        n_expired = len(expired)
        if n_expired:
            self._release_entries(expired)
            with self._stats_lock:
                self.stats.n_deadline_expired += n_expired
            self._m_expired.inc(n_expired)
            if trace.is_enabled():
                trace.instant("serve.deadline_expired", n=n_expired)
        return live

    def _serve_with_recovery(self, entries: list) -> tuple:
        """``_serve`` under the fault-tolerance contract: drop expired
        requests before every attempt, retry up to ``cfg.max_retries``
        times after a failure (invoking ``on_fault`` between attempts —
        every attempt re-reads the handle, so a recovery publish heals
        the retry), and raise only once retries are exhausted.

        Prunes ``entries`` IN PLACE to the still-live subset (callers
        use it for restore-on-error) and returns ``(out, served)``.
        With ``max_retries=0`` and no deadline this is exactly one
        ``_serve`` call — the pre-fault-layer behavior.
        """
        attempt = 0
        while True:
            live = self._expire(entries)
            entries[:] = live
            if not live:
                return {}, []
            try:
                return self._serve(live), live
            except BaseException as e:
                if attempt >= self.cfg.max_retries:
                    raise
                attempt += 1
                handled = False
                if self._on_fault is not None:
                    # A recovery-hook crash must not eat the original
                    # fault: log it into the trace and fall back to
                    # plain backoff.
                    try:
                        handled = bool(self._on_fault(e, self.handle))
                    except BaseException:
                        handled = False
                with self._stats_lock:
                    self.stats.n_retries += 1
                self._m_retries.inc()
                if trace.is_enabled():
                    trace.instant("serve.retry", attempt=attempt,
                                  error=type(e).__name__, handled=handled)
                if not handled:
                    # Interruptible backoff: close() must not wait it out.
                    self._stop.wait(
                        self.cfg.retry_backoff_s * (2 ** (attempt - 1)))

    def _serve(self, entries) -> dict:
        # One consistent (model, version) snapshot for the whole drain:
        # in-flight slabs finish on it even if a publish lands mid-drain.
        model, version = self.handle.get()
        if self._inject_fault is not None:
            self._inject_fault(model)
        t_start = time.monotonic()

        # Three-phase drain so no device sync ever happens under a lock:
        #   1. plan-pack (arena slices, not gather-concat) — pure slicing;
        #   2. dispatch every slab under _dispatch_lock — enqueue-only:
        #      with the device-runner thread up (start()), the critical
        #      section is a handful of executor submits even on backends
        #      where a jit call blocks on compute inline (staging and the
        #      jit call both happen in ``_run_slab`` on that thread);
        #   3. blocking gather (no lock), plan-based result assembly
        #      (pure slicing), then one stats commit.
        with trace.span("serve.pack", n_requests=len(entries)):
            slabs, plan, frames = pack_slabs(
                entries, self.cfg.max_batch, self._buckets, self._arena)
        try:
            pool = self._device_pool
            with trace.span("serve.dispatch", n_slabs=len(slabs)):
                with self._dispatch_lock:
                    if pool is not None:
                        launched = [pool.submit(self._run_slab, model,
                                                version, slab)
                                    for slab, _, _ in slabs]
                    else:
                        launched = [self._run_slab(model, version, slab)
                                    for slab, _, _ in slabs]
            with trace.span("serve.gather", n_slabs=len(slabs)):
                done = [d.result() if pool is not None else d
                        for d in launched]
                dts, host, padded, zero_copy, policies = \
                    self._collect(slabs, done)
        finally:
            # Frames go back to the pool even when a dispatch fails — the
            # staged device copies already happened, nothing reads them.
            for f in frames:
                self._arena.release_frame(f)
        return self._commit(entries, plan, dts, host, padded, zero_copy,
                            policies, len(slabs), model, version, t_start)

    def _dispatch_async(self, entries):
        """Pipelined drain (background flusher, fail-fast configs): pack
        and enqueue here, then hand the gather + assembly + future
        resolution to the device-runner thread as one more pool task —
        FIFO pool order guarantees it runs after this drain's slabs.
        Returns that task's future (the flusher bounds how many are
        in flight via ``cfg.pipeline_depth``)."""
        model, version = self.handle.get()
        if self._inject_fault is not None:
            self._inject_fault(model)
        t_start = time.monotonic()
        with trace.span("serve.pack", n_requests=len(entries)):
            slabs, plan, frames = pack_slabs(
                entries, self.cfg.max_batch, self._buckets, self._arena)
        pool = self._device_pool
        with trace.span("serve.dispatch", n_slabs=len(slabs)):
            with self._dispatch_lock:
                launched = [pool.submit(self._run_slab, model, version, slab)
                            for slab, _, _ in slabs]
        return pool.submit(self._finalize, entries, slabs, plan, frames,
                           launched, model, version, t_start)

    def _finalize(self, entries, slabs, plan, frames, launched, model,
                  version, t_start) -> None:
        """Device-runner half of a pipelined drain: gather (instant — the
        slab tasks ran before this one on the same serial pool), assemble,
        commit stats, resolve futures. Never raises: a failed slab fails
        exactly this drain's futures, matching the synchronous flusher
        contract."""
        try:
            try:
                done = [d.result() for d in launched]
                dts, host, padded, zero_copy, policies = \
                    self._collect(slabs, done)
            finally:
                for f in frames:
                    self._arena.release_frame(f)
            out, touched = self._assemble(entries, plan, dts, host, model)
            self._release_entries(entries)
        except BaseException as e:           # fail THIS batch only
            self._fail_entries(entries, e)
            return
        # Wake submitters FIRST: the stats/metrics tail runs in the shadow
        # of their next submit instead of on the request's critical path.
        self._resolve(entries, out)
        self._account(entries, dts, touched, padded, zero_copy, policies,
                      len(slabs), version, t_start)

    @staticmethod
    def _collect(slabs, done):
        """Device->host gets for one drain's finished slabs. Returns
        (per-slab seconds, host score arrays, pad rows, zero-copy count,
        per-slab routing policies — None for single-device models).

        For a model-parallel slab the blocking read IS the psum drain —
        dispatch returned before the reduction ran — so it gets its own
        ``serve.psum`` span; the flight recorder shows it overlapping the
        next slab's ``serve.shard_dispatch`` when drains pipeline.
        """
        dts, host, policies = [], [], []
        padded, zero_copy = 0, 0
        for (slab, take, zc), (dev, dt) in zip(slabs, done):
            policy = None
            if isinstance(dev, ShardedScores):
                dev, policy = dev.scores, dev.policy
            t0 = time.perf_counter()
            if policy == "mp" and trace.is_enabled():
                with trace.span("serve.psum", rows=int(slab.shape[0])):
                    scores = np.asarray(dev)     # device->host (+ psum)
            else:
                scores = np.asarray(dev)         # device->host
            dts.append(dt + time.perf_counter() - t0)
            host.append(scores)
            policies.append(policy)
            padded += slab.shape[0] - take
            zero_copy += bool(zc)
        return dts, host, padded, zero_copy, policies

    def _commit(self, entries, plan, dts, host, padded, zero_copy,
                policies, n_slabs, model, version, t_start) -> dict:
        """Assembly + accounting tail for the synchronous drain (the
        pipelined finalize calls the two halves itself, with future
        resolution in between)."""
        out, touched = self._assemble(entries, plan, dts, host, model)
        # Served: the staged rows are consumable again.
        self._release_entries(entries)
        self._account(entries, dts, touched, padded, zero_copy, policies,
                      n_slabs, version, t_start)
        return out

    @staticmethod
    def _assemble(entries, plan, dts, host, model):
        """Build per-request results straight off the pack plan: a request
        living in one slab gets a VIEW of that slab's scores, split
        requests copy each segment once. Returns (rid->scores,
        rid->device seconds touched)."""
        empty = np.zeros((0, model.n_components), np.float32)
        out, touched = {}, {}
        for e, segs in zip(entries, plan):
            if not segs:
                out[e.rid] = empty
                touched[e.rid] = 0.0
                continue
            if len(segs) == 1:
                si, row, _off, m = segs[0]
                out[e.rid] = host[si][row:row + m]
            else:
                buf = np.empty((e.n, host[segs[0][0]].shape[1]), np.float32)
                for si, row, off, m in segs:
                    buf[off:off + m] = host[si][row:row + m]
                out[e.rid] = buf
            touched[e.rid] = sum(dts[si] for si in {s[0] for s in segs})
        return out, touched

    def _account(self, entries, dts, touched, padded, zero_copy, policies,
                 n_slabs, version, t_start) -> None:
        """Stats + metric publication for one served drain. Runs only
        after every slab resolved, so a failed-then-retried flush doesn't
        double-count its slabs."""
        waits = [max(0.0, t_start - e.t_submit) for e in entries]
        donated = n_slabs if self.cfg.donate else 0
        routed = collections.Counter(p for p in policies if p)
        with self._stats_lock:
            self.stats.n_routed_mp += routed.get("mp", 0)
            self.stats.n_routed_dp += routed.get("dp", 0)
            self.stats.n_routed_single += routed.get("single", 0)
            self.stats.n_padded += padded
            self.stats.total_time_s += sum(dts)
            self.stats.n_requests += len(entries)
            self.stats.n_queries += sum(e.n for e in entries)
            self.stats.n_flushes += 1
            self.stats.n_zero_copy_slabs += zero_copy
            self.stats.n_donated += donated
            self.stats.n_arena_fallback = self._arena.n_fallback
            for e, wait in zip(entries, waits):
                self.stats.per_request.append(RequestStats(
                    e.rid, e.n, touched[e.rid], version, queue_wait_s=wait))
        # Metric publication rides the same per-drain commit point (one
        # batch of updates per drain, nothing on the submit hot path).
        self._m_requests.inc(len(entries))
        self._m_queries.inc(sum(e.n for e in entries))
        self._m_padded.inc(padded)
        self._m_flushes.inc()
        self._m_depth.set(self._queue.depth)
        self._m_version.set(version)
        if zero_copy:
            self._m_zero_copy.inc(zero_copy)
        if donated:
            self._m_donated.inc(donated)
        self._m_latency.observe_many(list(touched.values()))
        self._m_wait.observe_many(waits)
        if trace.is_enabled():
            for e, wait in zip(entries, waits):
                # Backdated complete event: the submit->serve gap renders
                # as its own "queue_wait" phase without any submit-side
                # instrumentation.
                trace.complete("serve.queue_wait", wait, rid=e.rid, n=e.n)

    def _stage_slab(self, slab: np.ndarray, warmup: bool = False,
                    policy: Optional[str] = None) -> np.ndarray:
        """Dtype cast + compile-cache bookkeeping for one packed slab —
        runs outside every lock but the stats lock, on whichever thread
        dispatches the slab. The slab stays HOST numpy: jit dispatch does
        the host->device transfer inline, which is one dispatch instead
        of an explicit ``jnp.asarray`` put followed by the call (~2x
        cheaper per slab on CPU). The transfer copies, so arena rows are
        free for reuse the moment their entries resolve.

        Compile bookkeeping is keyed (shape, policy): a sharded engine
        compiles one program per (bucket, routing policy), so a warmup
        that only touched the single-device entry must not mask an mp/dp
        compile as "steady state" — this key is what the ``n_compiles==0``
        regression tests actually check."""
        if self.cfg.query_dtype is not None:
            xq = slab.astype(self.cfg.query_dtype, copy=False)
        else:
            xq = slab
        key = (xq.shape, policy)
        with self._stats_lock:
            if key not in self._compiled_shapes:
                self._compiled_shapes.add(key)
                if warmup:
                    self.stats.n_warmup_compiles += 1
                else:
                    self.stats.n_compiles += 1
        return xq

    def _run_slab(self, model, version, slab):
        """Stage + dispatch one packed slab on the CALLING thread (the
        device-runner when ``start()`` is up, so the ~flat per-transfer
        cost overlaps the flusher's next pack). Returns
        ``(device scores, seconds)``; for sharded models the scores carry
        the routing policy (``ShardedScores``) and the version keys the
        router's placement cache. Dispatch transfers the host slab
        itself; the on-device copy it makes is dead after the call when
        donation is on, and the caller owns the device->host get."""
        t0 = time.perf_counter()
        with trace.span("serve.device", rows=int(slab.shape[0])):
            if self._router is not None:
                policy = self._router.choose(int(slab.shape[0]), model)
                xq = self._stage_slab(slab, policy=policy)
                out = self._router.dispatch(model, version, xq, policy)
            else:
                xq = self._stage_slab(slab)
                out = self._proj_donated(model, xq)
        return out, time.perf_counter() - t0


__all__ = ["EngineStats", "KpcaEngine", "KpcaServeConfig", "QueueFullError",
           "RequestFuture", "RequestStats"]
