"""Observability stack: tracer, metrics registry, communication ledger, and
their wiring through the solver + serving layers.

Covers the PR-7 acceptance criteria: the disabled tracer is an identity
no-op with no per-call retention, the enabled tracer stays within a
per-span overhead budget, Chrome-trace export round-trips through JSON with
the schema Perfetto expects, and — the load-bearing one — per-iteration
bytes measured by the ``CommLedger`` from the REAL transports match the
analytic counts derived independently from the graph topology, for both
the dense reference transport and the SPMD ring.
"""

import json
import threading
import time
import tracemalloc
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, build_setup, oos, solver
from repro.core.solver import run_chunked
from repro.core.topology import ring
from repro.data import kpca_dataset, node_dataset
from repro.obs import metrics, trace
from repro.obs.comm import CommLedger, CommProfile
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NOOP_SPAN, Tracer
from repro.serve.batching import PER_REQUEST_WINDOW, EngineStats, RequestStats
from repro.serve.kpca_engine import KpcaEngine, KpcaServeConfig
from repro.serve.publisher import ModelHandle, stream_chunks

SPEC = KernelSpec(kind="rbf", gamma=None)


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Tests must not leak an enabled process-wide tracer."""
    yield
    trace.disable()


# ---------------------------------------------------------------------------
# tracer


class TestTracer:
    def test_span_records_duration_and_attrs(self):
        t = Tracer()
        with t.span("work", n=3):
            # a real measurable duration is the POINT of this test
            time.sleep(0.002)  # repro-lint: disable=sleep-in-test
        (ev,) = t.events()
        ph, name, t0, dur, tid, attrs = ev
        assert (ph, name) == ("X", "work")
        assert dur >= 2e6                    # >= 2ms in ns
        assert attrs == {"n": 3}
        assert tid == threading.get_ident()

    def test_span_records_on_exception_path(self):
        t = Tracer()
        with pytest.raises(RuntimeError):
            with t.span("boom"):
                raise RuntimeError("x")
        assert [e[1] for e in t.events()] == ["boom"]

    def test_annotate_mid_span(self):
        t = Tracer()
        with t.span("s") as s:
            s.annotate(rows=7)
        assert t.events()[0][5] == {"rows": 7}

    def test_ring_keeps_latest_and_counts_drops(self):
        t = Tracer(capacity=4)
        for i in range(10):
            t.instant(f"e{i}")
        assert t.n_recorded == 10 and t.n_dropped == 6
        assert [e[1] for e in t.events()] == ["e6", "e7", "e8", "e9"]

    def test_complete_backdates(self):
        t = Tracer()
        t.complete("queue_wait", 0.5, rid=1)
        (ev,) = t.events()
        assert ev[0] == "X" and ev[3] == int(0.5e9)

    def test_durations_filters_by_name(self):
        t = Tracer()
        with t.span("a"):
            pass
        t.complete("b", 0.25)
        t.instant("a")                       # instants are not durations
        assert t.durations("b") == [0.25]
        assert len(t.durations("a")) == 1

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)

    def test_threads_record_concurrently(self):
        t = Tracer()
        gate = threading.Barrier(4)  # all alive at once, so tids differ

        def worker():
            gate.wait()
            for _ in range(200):
                with t.span("w"):
                    pass

        ts = [threading.Thread(target=worker) for _ in range(4)]
        for th in ts:
            th.start()
        for th in ts:
            th.join()
        assert t.n_recorded == 800
        tids = {e[4] for e in t.events()}
        assert len(tids) == 4


class TestDisabledTracerIsFree:
    def test_identity_noop_singleton(self):
        trace.disable()
        # deliberate unentered spans — the identity check IS the test
        # repro-lint: disable=span-not-closed
        assert trace.span("hot") is NOOP_SPAN
        assert trace.span("other", a=1) is NOOP_SPAN  # repro-lint: disable=span-not-closed
        assert not trace.is_enabled() and trace.active() is None
        trace.instant("nothing")             # no-ops, no error
        trace.complete("nothing", 1.0)

    def test_no_per_call_retention(self):
        trace.disable()
        with trace.span("warm"):             # warm any lazy interning
            pass
        tracemalloc.start()
        base = tracemalloc.take_snapshot()
        for _ in range(5000):
            with trace.span("hot"):
                pass
        snap = tracemalloc.take_snapshot()
        tracemalloc.stop()
        stats = snap.compare_to(base, "filename")
        grown = sum(s.size_diff for s in stats if s.size_diff > 0)
        # 5000 disabled spans must retain nothing (tracemalloc's own
        # bookkeeping noise stays far under this bound; a single retained
        # span per call would blow it by orders of magnitude)
        assert grown < 64 * 1024, f"retained {grown} bytes"

    def test_export_raises_while_disabled(self):
        trace.disable()
        with pytest.raises(RuntimeError):
            trace.export("/dev/null")

    def test_fault_paths_allocate_nothing_while_disabled(self):
        """The fault-injection layer must be observability-free when
        tracing is off: FaultyComm censoring and the engine's
        retry/recovery loop emit through pre-created module-level
        counters and ``is_enabled()``-guarded trace calls — no per-call
        metric creation, no span retention."""
        from repro.data import kpca_dataset
        from repro.faults import FaultyComm, transient_faults
        from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle

        trace.disable()
        src = np.array([[0, 1], [1, 0]], np.int32)
        comm = FaultyComm(solver.DenseComm(src, np.zeros((2, 2), np.int32)),
                          jnp.ones((2, 2), jnp.float32))
        cols = jnp.ones((2, 2, 3), jnp.float32)
        model = oos.fit_central(jnp.asarray(kpca_dataset(24, m=6, seed=0)),
                                KernelSpec(kind="rbf"), n_components=2)

        def retry_once():
            eng = KpcaEngine(
                ModelHandle(model),
                KpcaServeConfig(max_batch=8, min_bucket=8, max_retries=2,
                                retry_backoff_s=0.0),
                inject_fault=transient_faults(1))
            eng.submit(np.zeros((2, 6), np.float32))
            eng.flush()

        comm.exchange(cols)                  # warm lazy jit/interning
        retry_once()
        keys_before = len(metrics.snapshot())
        tracemalloc.start()
        base = tracemalloc.take_snapshot()
        for _ in range(200):
            comm.exchange(cols)
        retry_once()
        snap = tracemalloc.take_snapshot()
        tracemalloc.stop()
        assert len(metrics.snapshot()) == keys_before  # no new metric keys
        stats = snap.compare_to(base, "filename")
        grown = sum(s.size_diff for s in stats
                    if s.size_diff > 0
                    and ("/obs/" in (s.traceback[0].filename or "")
                         or "/faults/" in (s.traceback[0].filename or "")))
        assert grown < 16 * 1024, f"obs/faults retained {grown} bytes"


class TestEnabledTracerBudget:
    def test_per_span_overhead_budget(self):
        n = 20_000
        t = trace.enable(capacity=1024)
        t0 = time.perf_counter()
        for _ in range(n):
            with trace.span("bench"):
                pass
        per_span = (time.perf_counter() - t0) / n
        trace.disable()
        assert t.n_recorded == n
        # measured ~2us on CI-class CPUs; 100us still catches a lock
        # convoy or accidental per-span export
        assert per_span < 100e-6, f"{per_span * 1e6:.1f}us per span"

    def test_install_hands_back_prior_tracer_with_events(self):
        outer = trace.enable()
        trace.instant("before")
        inner = Tracer()
        trace.install(inner)
        assert trace.active() is inner
        trace.install(outer)
        assert trace.active() is outer
        assert [e[1] for e in outer.events()] == ["before"]


class TestChromeExport:
    def test_round_trip_schema(self, tmp_path):
        t = Tracer()
        with t.span("phase", rows=3, note="x"):
            time.sleep(0.001)  # repro-lint: disable=sleep-in-test
        t.instant("mark", ok=True)
        path = tmp_path / "trace.json"
        n = t.export(str(path))
        doc = json.loads(path.read_text())
        assert len(doc["traceEvents"]) == n
        assert doc["displayTimeUnit"] == "ms"
        by_ph = {}
        for ev in doc["traceEvents"]:
            by_ph.setdefault(ev["ph"], []).append(ev)
        (meta,) = by_ph["M"]                 # thread_name metadata
        assert meta["name"] == "thread_name"
        (x,) = by_ph["X"]
        assert x["name"] == "phase"
        assert x["dur"] >= 1e3               # microseconds
        assert x["args"] == {"rows": 3, "note": "x"}
        assert {"pid", "tid", "ts"} <= set(x)
        (i,) = by_ph["i"]
        assert i["s"] == "t" and i["args"] == {"ok": True}

    def test_non_json_attrs_stringified(self):
        t = Tracer()
        t.instant("e", arr=np.zeros(2))
        doc = t.to_chrome()
        json.dumps(doc)                      # must not raise
        ev = [e for e in doc["traceEvents"] if e["ph"] == "i"][0]
        assert isinstance(ev["args"]["arr"], str)


# ---------------------------------------------------------------------------
# metrics


class TestMetrics:
    def test_counter_monotonic(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total", "help")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_set_and_inc(self):
        reg = MetricsRegistry()
        g = reg.gauge("depth")
        g.set(5)
        g.inc(-2)
        assert g.value == 3.0

    def test_histogram_cumulative_buckets(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0, 10.0))
        h.observe(0.05)
        h.observe_many([0.5, 0.5, 5.0, 50.0])
        snap = h.snapshot()
        assert snap["count"] == 5
        assert snap["sum"] == pytest.approx(56.05)
        assert snap["buckets"] == [[0.1, 1], [1.0, 3], [10.0, 4]]

    def test_histogram_rejects_bad_buckets(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError):
            reg.histogram("h", buckets=(1.0, 1.0))
        with pytest.raises(ValueError):
            reg.histogram("h2", buckets=())

    def test_get_or_create_identity_and_kind_conflict(self):
        reg = MetricsRegistry()
        a = reg.counter("n_total", label="x")
        b = reg.counter("n_total", label="x")
        c = reg.counter("n_total", label="y")
        assert a is b and a is not c
        with pytest.raises(TypeError):
            reg.gauge("n_total", label="x")

    def test_snapshot_shape_and_json(self):
        reg = MetricsRegistry()
        reg.counter("a_total", "ha").inc(2)
        reg.gauge("b").set(1)
        reg.histogram("c_seconds", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        json.dumps(snap)
        kinds = {m["name"]: m["kind"] for m in snap["metrics"]}
        assert kinds == {"a_total": "counter", "b": "gauge",
                         "c_seconds": "histogram"}

    def test_write_json(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("a_total").inc()
        path = tmp_path / "metrics.json"
        reg.write_json(str(path))
        assert json.loads(path.read_text())["metrics"][0]["value"] == 1

    def test_prometheus_text_format(self):
        reg = MetricsRegistry()
        reg.counter("req_total", "requests", transport="ring").inc(3)
        reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)) \
            .observe_many([0.05, 0.5])
        text = reg.prometheus_text()
        assert "# HELP req_total requests" in text
        assert "# TYPE req_total counter" in text
        assert 'req_total{transport="ring"} 3' in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_sum 0.55" in text
        assert "lat_seconds_count 2" in text
        assert text.endswith("\n")

    def test_reset_drops_everything(self):
        reg = MetricsRegistry()
        reg.counter("a_total").inc()
        reg.reset()
        assert reg.snapshot() == {"metrics": []}

    def test_default_registry_helpers_route_to_one_instance(self):
        c = metrics.counter("test_obs_helper_total")
        assert metrics.counter("test_obs_helper_total") is c
        assert any(m["name"] == "test_obs_helper_total"
                   for m in metrics.snapshot()["metrics"])


# ---------------------------------------------------------------------------
# communication ledger


class TestCommLedger:
    def test_routes_setup_vs_iteration(self):
        led = CommLedger()
        led.record_exchange(100, 2)          # before any iteration -> setup
        led.begin_iteration()
        led.record_exchange(10)
        led.record_collective(4)
        led.end_iteration()
        assert led.setup.bytes == 100 and led.setup.messages == 2
        assert led.per_iter.bytes == 10 and led.per_iter.messages == 1
        assert led.per_iter.collectives == 1
        assert led.per_iter.collective_bytes == 4

    def test_totals_scale_by_iterations(self):
        led = CommLedger()
        led.record_exchange(100)
        led.begin_iteration()
        led.record_exchange(10, 3)
        led.end_iteration()
        led.add_iterations(7)
        tot = led.totals()
        assert tot.bytes == 100 + 70
        assert tot.messages == 1 + 21

    def test_snapshot_is_json_ready(self):
        led = CommLedger()
        led.begin_iteration()
        led.record_exchange(8)
        led.end_iteration()
        led.add_iterations(2)
        snap = led.snapshot()
        json.dumps(snap)
        assert snap["iterations"] == 2
        assert snap["totals"]["bytes"] == 16

    def test_profile_scaled(self):
        p = CommProfile(bytes=3, messages=2, collectives=1,
                        collective_bytes=4)
        q = p.scaled(5)
        assert (q.bytes, q.messages, q.collectives, q.collective_bytes) \
            == (15, 10, 5, 20)


def _dense_setup(j=8, n=16, hops=2):
    nodes, _ = node_dataset(n_nodes=j, n_per_node=n, m=12, seed=0)
    return build_setup(jnp.asarray(nodes), ring(j, hops=hops), SPEC)


class TestDenseCommAccounting:
    def test_measured_bytes_match_analytic_count(self):
        """MEASURED: trace-time hooks in DenseComm.exchange during a real
        run. EXPECTED: derived independently from the topology — the ADMM
        step makes 3 exchanges per iteration (alpha, K^-1 B columns,
        z-projections), each moving one fp32 N-vector over every directed
        off-diagonal edge of the neighbor graph, network-wide."""
        j, n, hops = 8, 16, 2
        setup = _dense_setup(j, n, hops)
        led = CommLedger()
        chunks = list(run_chunked(setup, n_iters=6, chunk=3, ledger=led))

        src = np.asarray(setup.src)
        mask = np.asarray(setup.mask)
        own = np.arange(j)[:, None]
        directed_edges = int(np.sum((src != own) & (mask > 0)))
        assert directed_edges == j * 2 * hops          # ring(j, hops)

        expected_per_iter = 3 * directed_edges * n * 4  # fp32
        assert led.per_iter.bytes == expected_per_iter
        assert led.per_iter.messages == 3 * directed_edges
        assert led.iterations == 6
        assert led.totals().bytes == 6 * expected_per_iter
        # every chunk carries its own share
        assert [c.comm_bytes for c in chunks] \
            == [3 * expected_per_iter] * 2
        assert [c.comm_messages for c in chunks] \
            == [3 * 3 * directed_edges] * 2

    def test_no_ledger_means_zero_fields(self):
        setup = _dense_setup()
        chunk = next(iter(run_chunked(setup, n_iters=2, chunk=2)))
        assert chunk.comm_bytes == 0 and chunk.comm_messages == 0

    def test_solver_spans_recorded(self):
        t = trace.enable()
        setup = _dense_setup()
        list(run_chunked(setup, n_iters=4, chunk=2))
        names = {e[1] for e in t.events()}
        trace.disable()
        assert {"solver.step", "solver.rho2"} <= names


@pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 devices")
class TestRingCommAccounting:
    def test_measured_per_node_bytes_match_analytic_count(self):
        """RingComm counts ONE node's egress (SPMD: each device runs the
        same program). Per iteration each node ppermutes one fp32 N-vector
        to each of its 2*hops neighbors, three times, plus one scalar
        psum for the residual."""
        from jax.sharding import Mesh
        from repro.core.dkpca import dkpca_distributed

        j, n, m, hops, iters = 4, 16, 12, 1, 5
        mesh = Mesh(np.array(jax.devices()[:j]).reshape(j, 1),
                    ("data", "model"))
        x = jnp.asarray(node_dataset(n_nodes=j, n_per_node=n, m=m,
                                     seed=1)[0])
        led = CommLedger()
        dkpca_distributed(x, mesh, hops=hops, n_iters=iters, ledger=led)

        expected_per_iter = 3 * (2 * hops) * n * 4      # fp32, per node
        assert led.per_iter.bytes == expected_per_iter
        assert led.per_iter.messages == 3 * (2 * hops)
        assert led.per_iter.collectives == 1            # residual psum
        assert led.iterations == iters
        # setup: raw-data exchange (2*hops X-blocks) + centering sweep
        # (j rotations of X) + m_slots shifts (2*hops N-vectors)
        expected_setup = (2 * hops) * n * m * 4 + j * n * m * 4 \
            + (2 * hops) * n * 4
        assert led.setup.bytes == expected_setup
        assert led.setup.collectives == 1               # centering pmean


# ---------------------------------------------------------------------------
# serving integration


def _engine(n=128, m=16, **cfg_kw):
    x = jnp.asarray(kpca_dataset(n, m=m, seed=0))
    model = oos.fit_central(x, SPEC, n_components=2, center=True)
    return KpcaEngine(model, KpcaServeConfig(
        max_batch=32, min_bucket=8, **cfg_kw)), m


class TestEngineObservability:
    def test_drain_phases_and_queue_wait_traced(self):
        eng, m = _engine()
        t = trace.enable()
        rng = np.random.default_rng(0)
        futs = [eng.submit(rng.normal(size=(q, m)).astype(np.float32))
                for q in (3, 5, 2)]
        eng.flush()
        for f in futs:
            f.result(timeout=10)
        names = {e[1] for e in t.events()}
        assert {"serve.pack", "serve.dispatch", "serve.device",
                "serve.resolve", "serve.queue_wait"} <= names
        waits = [e for e in t.events() if e[1] == "serve.queue_wait"]
        assert len(waits) == 3
        assert {w[5]["rid"] for w in waits} == {f.request_id for f in futs}
        trace.disable()

    def test_serving_identical_with_tracing_off_and_on(self):
        eng, m = _engine()
        rng = np.random.default_rng(1)
        xq = rng.normal(size=(6, m)).astype(np.float32)
        (off,) = eng.project_many([xq])
        trace.enable()
        (on,) = eng.project_many([xq])
        trace.disable()
        np.testing.assert_array_equal(off, on)

    def test_drain_commits_metrics(self):
        eng, m = _engine()
        before = metrics.counter("serve_requests_total").value
        before_q = metrics.counter("serve_queries_total").value
        rng = np.random.default_rng(2)
        eng.project_many([rng.normal(size=(4, m)).astype(np.float32),
                          rng.normal(size=(7, m)).astype(np.float32)])
        assert metrics.counter("serve_requests_total").value == before + 2
        assert metrics.counter("serve_queries_total").value == before_q + 11
        assert metrics.gauge("serve_queue_depth_rows").value == 0


class TestBoundedPerRequest:
    def test_window_is_bounded(self):
        st = EngineStats()
        for i in range(PER_REQUEST_WINDOW + 100):
            st.per_request.append(RequestStats(i, 1, float(i)))
        assert len(st.per_request) == PER_REQUEST_WINDOW
        # oldest-first eviction: the ring holds the most recent window
        assert st.per_request[0].request_id == 100
        assert st.per_request[-1].request_id == PER_REQUEST_WINDOW + 99

    def test_percentiles_over_window(self):
        st = EngineStats()
        for i in range(PER_REQUEST_WINDOW + 500):
            st.per_request.append(RequestStats(i, 1, 1.0))
        p50, p99 = st.latency_percentiles()
        assert p50 == p99 == 1.0
        assert st.latency_percentiles(qs=(0,)) == (1.0,)

    def test_empty_window_is_zero(self):
        assert EngineStats().latency_percentiles() == (0.0, 0.0)


class TestRefreshDecisionMetrics:
    @staticmethod
    def _chunk(residual, t):
        return solver.ChunkResult(
            state=SimpleNamespace(alpha=np.zeros(3), t=t),
            alpha_hist=None, lagrangian=None,
            primal_residual=np.asarray([residual], np.float32),
            rho_hist=None)

    def test_fire_and_censor_counters(self):
        fired = metrics.counter("solver_refresh_fired_total",
                                policy="EveryK")
        censored = metrics.counter("solver_refresh_censored_total",
                                   policy="EveryK")
        f0, c0 = fired.value, censored.value

        published = []
        handle = SimpleNamespace(refresh=lambda a: published.append(a))
        chunks = [self._chunk(1.0, t) for t in (2, 4, 6, 8, 10)]
        stream_chunks(iter(chunks), handle, every=2)
        # EveryK(2): fires on chunks 2 and 4; chunks 1/3/5 censored, the
        # trailing pending chunk still publishes (not a policy decision)
        assert fired.value - f0 == 2
        assert censored.value - c0 == 3
        assert len(published) == 3

    def test_decisions_traced_with_policy_label(self):
        t = trace.enable()
        handle = SimpleNamespace(refresh=lambda a: None)
        stream_chunks(iter([self._chunk(1.0, 3)]), handle, every=1)
        evs = [e for e in t.events() if e[1] == "solver.refresh_decision"]
        trace.disable()
        assert len(evs) == 1
        assert evs[0][5] == {"fired": True, "policy": "EveryK", "t": 3}


class TestModelHandleObservability:
    def test_publish_swap_traced_and_counted(self):
        x = jnp.asarray(kpca_dataset(64, m=8, seed=0))
        model = oos.fit_central(x, SPEC, n_components=2, center=True)
        handle = ModelHandle(model)
        before = metrics.counter("publish_swaps_total").value
        t = trace.enable()
        v = handle.publish(model)
        trace.disable()
        assert v == 1
        assert metrics.counter("publish_swaps_total").value == before + 1
        evs = [e for e in t.events() if e[1] == "publish.swap"]
        assert evs and evs[0][5]["version"] == 1
