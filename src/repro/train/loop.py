"""Training loop: jitted step with sharding + donation, checkpoint/restart,
NaN-guard with rollback-and-skip, straggler monitoring, and the DKPCA
activation probe (the paper's technique as a first-class training feature).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..distributed.sharding import Rules, spec_for
from ..optim import AdamWConfig, adamw_init, adamw_update

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    probe_every: int = 0          # 0 = off; DKPCA activation probe period
    straggler_factor: float = 3.0
    seed: int = 0


def shardings_for_params(axes: Dict[str, tuple], shapes: Dict[str, Any],
                         rules: Rules, mesh):
    return {k: NamedSharding(mesh, spec_for(shapes[k].shape, axes[k], rules,
                                            mesh))
            for k in axes}


def build_train_step(model, opt_cfg: AdamWConfig, mesh=None,
                     rules: Optional[Rules] = None,
                     batch_sharding=None):
    """Returns (init_fn, step_fn). step_fn is jitted with donated state."""
    cfg = model.cfg

    def init_fn(key):
        params, axes = model.init(key)
        opt = adamw_init(params)
        state = {"params": params, "m": opt["m"], "v": opt["v"],
                 "step": opt["step"]}
        if mesh is not None:
            shapes = {k: v for k, v in params.items()}
            sh = shardings_for_params(axes, shapes, rules, mesh)
            state["params"] = {k: jax.device_put(v, sh[k])
                               for k, v in params.items()}
            state["m"] = {k: jax.device_put(v, sh[k])
                          for k, v in state["m"].items()}
            state["v"] = {k: jax.device_put(v, sh[k])
                          for k, v in state["v"].items()}
        return state, axes

    def step_fn(state, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, opt_state, opt_metrics = adamw_update(
            opt_cfg, state["params"],
            grads, {"m": state["m"], "v": state["v"], "step": state["step"]})
        # NaN guard (in-graph): skip the update when loss/grads are not
        # finite — keeps the jitted step deterministic under data spikes.
        ok = jnp.isfinite(loss) & jnp.isfinite(opt_metrics["grad_norm"])
        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(ok, x, y), a, b)
        new_state = {
            "params": sel(new_params, state["params"]),
            "m": sel(opt_state["m"], state["m"]),
            "v": sel(opt_state["v"], state["v"]),
            "step": opt_state["step"],
        }
        metrics = dict(metrics, **opt_metrics, skipped=~ok)
        return new_state, {k: v.astype(jnp.float32) if hasattr(v, "astype")
                           else v for k, v in metrics.items()}

    donate = (0,)
    jitted = jax.jit(step_fn, donate_argnums=donate)
    return init_fn, jitted


@dataclasses.dataclass
class StragglerMonitor:
    """Flags steps slower than factor x running median (at 1000-node scale
    this signal feeds the scheduler; here it logs and counts)."""
    factor: float = 3.0
    times: list = dataclasses.field(default_factory=list)
    flagged: int = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times[-50:]))
        if dt > self.factor * med:
            self.flagged += 1
            log.warning("straggler step: %.3fs vs median %.3fs", dt, med)
            return True
        return False


def train(model, opt_cfg: AdamWConfig, data_iter, tcfg: TrainConfig,
          mesh=None, rules=None, probe_fn: Optional[Callable] = None):
    """Run the loop; returns (final state, history dict)."""
    init_fn, step_fn = build_train_step(model, opt_cfg, mesh, rules)
    state, axes = init_fn(jax.random.PRNGKey(tcfg.seed))
    start_step = 0
    if tcfg.ckpt_dir and latest_step(tcfg.ckpt_dir) is not None:
        flat, meta, start_step = restore_checkpoint(tcfg.ckpt_dir)
        state = _unflatten_state(flat)
        if "data_state" in meta and hasattr(data_iter, "restore"):
            data_iter.restore(meta["data_state"])
        log.info("restored checkpoint at step %d", start_step)

    monitor = StragglerMonitor(tcfg.straggler_factor)
    history = {"loss": [], "step_time": [], "probe": []}
    for step in range(start_step, tcfg.steps):
        batch = data_iter.next_batch()
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.record(dt)
        history["loss"].append(loss)
        history["step_time"].append(dt)
        if tcfg.log_every and step % tcfg.log_every == 0:
            log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
        if probe_fn and tcfg.probe_every and step % tcfg.probe_every == 0:
            history["probe"].append((step, probe_fn(state, batch)))
        if (tcfg.ckpt_dir and tcfg.ckpt_every
                and (step + 1) % tcfg.ckpt_every == 0):
            save_checkpoint(tcfg.ckpt_dir, step + 1, _flatten_state(state),
                            metadata={"data_state": getattr(
                                data_iter, "state", lambda: {})()})
    history["straggler_flags"] = monitor.flagged
    return state, history


def _flatten_state(state):
    out = {}
    for group in ("params", "m", "v"):
        for k, v in state[group].items():
            out[f"{group}::{k}"] = v
    out["step::step"] = state["step"]
    return out


def _unflatten_state(flat):
    state = {"params": {}, "m": {}, "v": {}}
    for k, v in flat.items():
        group, key = k.split("::", 1)
        if group == "step":
            state["step"] = v
        else:
            state[group][key] = v
    return state
