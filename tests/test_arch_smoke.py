"""Per-architecture smoke tests: reduced same-family configs, one forward/
train step + one decode step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, concrete_train_batch, get_config
from repro.models import build_model

B, S = 2, 32


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name, smoke=True)
            model = build_model(cfg)
            params, axes = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params, axes)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_loss(built, name):
    cfg, model, params, axes = built(name)
    batch = concrete_train_batch(cfg, B, S)
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss)), (name, float(loss))
    assert float(loss) > 0
    # CE at init should be near log(vocab)
    assert abs(float(metrics["loss"]) - np.log(cfg.vocab)) < 2.5, \
        (name, float(metrics["loss"]), np.log(cfg.vocab))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step(built, name):
    cfg, model, params, axes = built(name)
    batch = concrete_train_batch(cfg, B, S)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in grads.values()))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name
    # one SGD step must change the loss
    params2 = {k: v - 0.1 * grads[k].astype(v.dtype)
               for k, v in params.items()}
    loss2 = loss_fn(params2)
    assert np.isfinite(float(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_step(built, name):
    cfg, model, params, axes = built(name)
    max_len = 16
    if cfg.family == "audio":
        batch = concrete_train_batch(cfg, B, 8)
        logits, cache = model.prefill(params, batch, max_len)
    else:
        cache = model.init_cache(B, max_len)
        logits = None
    toks = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        cache_len = jnp.asarray(8 + step if cfg.family == "audio" else step,
                                jnp.int32)
        logits, cache = model.decode_step(params, cache, toks, cache_len)
        assert logits.shape == (B, cfg.vocab), (name, logits.shape)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), name
        toks = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("name", ["llama3.2-3b", "mixtral-8x22b",
                                  "falcon-mamba-7b", "zamba2-1.2b",
                                  "deepseek-v2-236b"])
def test_decode_matches_forward(built, name):
    """Greedy decode logits must match the teacher-forced forward logits at
    the same positions (cache correctness)."""
    cfg, model, params, axes = built(name)
    rng = np.random.default_rng(0)
    s = 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, s), dtype=np.int32))

    # forward logits via loss path is awkward; use prefill-style full pass:
    cache = model.init_cache(B, 16)
    # feed tokens one by one, collect logits
    dec_logits = []
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.asarray(t, jnp.int32))
        dec_logits.append(np.asarray(lg, np.float32))
    dec_logits = np.stack(dec_logits, axis=1)       # (B, s, V)

    # fresh cache, feed the whole prompt at once (prefill path)
    cache2 = model.init_cache(B, 16)
    lg_all, _ = model.decode_step(params, cache2, toks,
                                  jnp.asarray(0, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_all, np.float32),
                               dec_logits[:, -1], rtol=2e-2, atol=2e-2)


def test_full_configs_param_counts():
    """Full configs should be in the right parameter-count ballpark."""
    expected = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "llama3-405b": (3.6e11, 4.6e11),
        "qwen3-32b": (2.6e10, 4.0e10),
        "phi4-mini-3.8b": (3.0e9, 5.0e9),
        "deepseek-v2-236b": (1.9e11, 2.8e11),
        "mixtral-8x22b": (1.2e11, 1.6e11),
        "internvl2-76b": (6.3e10, 8.5e10),
        "zamba2-1.2b": (0.9e9, 1.7e9),
        "falcon-mamba-7b": (6.0e9, 8.5e9),
    }
    for name, (lo, hi) in expected.items():
        n = get_config(name).n_params()
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
