"""Typed fault errors.

This module is a dependency LEAF: ``serve`` imports it (deadline/retry
surfaces these to futures) and ``faults.*`` imports it, so it must not
import anything from ``repro`` beyond the stdlib. Every failure the fault
layer injects — and every failure the recovery machinery gives up on —
resolves in-flight futures with a subclass of :class:`FaultError`, never a
hang and never a bare ``Exception`` that callers cannot route on.
"""

from __future__ import annotations


class FaultError(RuntimeError):
    """Base class for injected faults and exhausted-recovery failures."""


class ShardLostError(FaultError):
    """A serving shard's rows became unreachable (injected or detected).

    ``shard`` is the shard index; the re-balance path
    (:class:`repro.faults.serving.ShardRebalancer`) keys off it.
    """

    def __init__(self, shard: int, detail: str = ""):
        self.shard = int(shard)
        msg = f"shard {shard} lost"
        super().__init__(f"{msg}: {detail}" if detail else msg)


class DeadlineExceededError(FaultError):
    """A request sat in the serving path longer than its deadline.

    Raised onto the request's future — the request is dropped, not served
    late, so recovery storms cannot grow the queue without bound.
    """

    def __init__(self, waited_s: float, deadline_s: float):
        self.waited_s = float(waited_s)
        self.deadline_s = float(deadline_s)
        super().__init__(
            f"request waited {waited_s * 1e3:.1f}ms > "
            f"deadline {deadline_s * 1e3:.1f}ms")


class InjectedCrashError(FaultError):
    """A deliberate crash from a :class:`FaultPlan` (publisher jobs etc.)."""


class NodeDownError(FaultError):
    """An ADMM participant vanished; the driver must re-knit to continue."""

    def __init__(self, nodes, t: int):
        self.nodes = tuple(int(n) for n in nodes)
        self.t = int(t)
        super().__init__(f"node(s) {self.nodes} down at iteration {t}")


__all__ = [
    "FaultError",
    "ShardLostError",
    "DeadlineExceededError",
    "InjectedCrashError",
    "NodeDownError",
]
