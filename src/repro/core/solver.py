"""Shared ADMM solver-driver layer: one step body, two transports.

The paper's Alg. 1 is implemented twice in this repo — the graph-general
reference simulator (``repro.core.admm``, all nodes vectorized in one
process) and the SPMD production path (``repro.core.dkpca``, one node per
device, ``ppermute`` messaging). Both run the SAME per-node math; only the
way slot messages move differs. This module owns that shared math:

  * ``AdmmState`` — the full iterate pytree (alpha, dual B, last z
    projections G, per-node ||z_hat||^2, iteration counter, per-slot rho),
    checkpointable via ``save_state``/``load_state``;
  * ``admm_step`` — ONE pure iteration (paper eq. 10-13 in the per-slot-rho
    generalization), written against a ``Communicator`` protocol:
      - ``DenseComm``: gather/scatter by (src, rsl) indexing over a leading
        node axis; per-node math is ``jax.vmap``-ed (reference simulator);
      - ``RingComm``: ``jax.lax.ppermute`` ring hops inside ``shard_map``;
        per-node math runs directly on the device's block (SPMD path);
  * ``run_chunked`` — the resumable driver: scans ``chunk`` iterations per
    jitted call and yields the live state between chunks, so callers can
    observe residuals, checkpoint (``repro.checkpoint`` layout), re-tune or
    switch rho (pluggable ``RhoSchedule`` / Theorem-2 constant / arbitrary
    ``t -> rho`` callable) and publish serving snapshots mid-run
    (``repro.serve.publisher``) — with residual-based early stopping.

Warm starts: ``AdmmState`` carries (alpha, B) across chunk boundaries, so a
rho switch at a boundary continues from the warm z (the Z-update is a pure
function of the carried state). For a FRESH run, ``init="local"`` starts
alpha at each node's local kPCA solution, which warm-starts z at the pooled
local components — measured to remove the m=24 transient entirely (see
docs/ADMM_CONVERGENCE.md §Ablations).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Iterator, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from .rho import RhoSchedule
from ..obs import metrics, trace
from ..obs.comm import CommLedger


# ---- state ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmmState:
    """Full ADMM iterate. Shapes are per-node; the reference simulator adds
    a leading node axis J to every field (t stays scalar).

    alpha:  (..., N) primal dual-space coefficients.
    b:      (..., N, S) dual variables B_j = phi(X_j)^T eta_j, slot-major.
    g:      (..., N, S) last z projections G_j = phi(X_j)^T Z xi_j.
    znorm2: (...,) last ||z_hat||^2 per node (diagnostic; drives the
            "rescale" gauge).
    t:      () int32 — iterations completed.
    rho:    (..., S) per-slot rho applied at the last step (0 before it).
    """

    alpha: jax.Array
    b: jax.Array
    g: jax.Array
    znorm2: jax.Array
    t: jax.Array
    rho: jax.Array


jax.tree_util.register_pytree_node(
    AdmmState,
    lambda s: ((s.alpha, s.b, s.g, s.znorm2, s.t, s.rho), None),
    lambda _, leaves: AdmmState(*leaves))


def init_state(alpha0: jax.Array, n_slots: int, t0: int = 0) -> AdmmState:
    """Fresh state at iteration ``t0`` with zero duals/projections."""
    alpha0 = jnp.asarray(alpha0)
    b = jnp.zeros(alpha0.shape + (n_slots,), alpha0.dtype)
    return AdmmState(
        alpha=alpha0, b=b, g=jnp.zeros_like(b),
        znorm2=jnp.zeros(alpha0.shape[:-1], alpha0.dtype),
        t=jnp.asarray(t0, jnp.int32),
        rho=jnp.zeros(alpha0.shape[:-1] + (n_slots,), alpha0.dtype))


@dataclasses.dataclass(frozen=True)
class SolverOps:
    """Per-node constants the step needs (leading node axis in DenseComm).

    kcross: (S, S, N, N) Gram blocks between slot owners' data.
    k:      (N, N) own (centered) Gram K_j == kcross[0, 0].
    lam:    (N,) floored eigenvalues of K_j, ascending.
    vec:    (N, N) eigenvectors of K_j.
    mask:   (S,) float 1/0 — valid constraint slots.
    """

    kcross: jax.Array
    k: jax.Array
    lam: jax.Array
    vec: jax.Array
    mask: jax.Array


jax.tree_util.register_pytree_node(
    SolverOps,
    lambda o: ((o.kcross, o.k, o.lam, o.vec, o.mask), None),
    lambda _, leaves: SolverOps(*leaves))


# ---- communicators --------------------------------------------------------

class DenseComm:
    """All nodes in one process: exchange == advanced indexing by the
    (src, rsl) slot routing tables; per-node math is vmapped over axis 0.

    Communication accounting (``repro.obs.comm``): the routing tables may
    be tracers here, so the off-node entry count — the number of directed
    edges an exchange actually moves data over — is computed host-side by
    the driver and passed in as ``wire_entries``; each traced ``exchange``
    then reports NETWORK-WIDE bytes (every edge, payload only) into the
    ledger.
    """

    def __init__(self, src: jax.Array, rsl: jax.Array,
                 ledger: Optional[CommLedger] = None,
                 wire_entries: int = 0):
        self.src, self.rsl = src, rsl
        self.ledger = ledger
        self.wire_entries = wire_entries

    def local(self, fn):
        return jax.vmap(fn)

    def exchange(self, cols: jax.Array) -> jax.Array:
        """cols: (J, S, N) per-out-slot columns -> (J, S, N) where in-slot s
        of node j receives cols[src[j,s], rsl[j,s]]."""
        if self.ledger is not None:
            payload = cols.shape[-1] * jnp.dtype(cols.dtype).itemsize
            self.ledger.record_exchange(self.wire_entries * payload,
                                        self.wire_entries)
        return cols[self.src, self.rsl]

    def all_sum(self, x):
        return jnp.sum(x)

    def all_max(self, x):
        return jnp.max(x)


class RingComm:
    """One node per device inside ``shard_map``: exchange == one ppermute
    ring shift per neighbor slot; per-node math runs unmapped.

    message_dtype (e.g. bfloat16) casts neighbor payloads before the wire
    (halving ICI bytes); the self slot and all accumulation stay fp32.

    Communication accounting (``repro.obs.comm``): every ppermute and
    psum/pmax reports its WIRE payload (post-``message_dtype`` cast) into
    the ledger at trace time. The recorded profile is per NODE — this
    class runs inside shard_map, one node per device — so multiply by J
    for network totals.
    """

    def __init__(self, axes: Sequence[str], n_nodes: int,
                 offsets: Sequence[int], rev_slots: Sequence[int],
                 message_dtype=None, ledger: Optional[CommLedger] = None):
        self.axes = tuple(axes)
        self.n_nodes = n_nodes
        self.offsets = tuple(offsets)
        self.rev_slots = tuple(rev_slots)
        self.message_dtype = message_dtype
        self.ledger = ledger

    def local(self, fn):
        return fn

    def _shift(self, v: jax.Array, offset: int) -> jax.Array:
        """result on node m = v from node (m + offset) % J."""
        perm = [((m + offset) % self.n_nodes, m)
                for m in range(self.n_nodes)]
        if self.message_dtype is not None:
            v = v.astype(self.message_dtype)
        if self.ledger is not None:
            self.ledger.record_exchange(
                v.size * jnp.dtype(v.dtype).itemsize)
        r = jax.lax.ppermute(v, self.axes, perm)
        return r.astype(jnp.float32) if self.message_dtype is not None else r

    def exchange(self, cols: jax.Array) -> jax.Array:
        """cols: (S, N) my per-out-slot columns -> (S, N) received values:
        in-slot 0 is self; in-slot d+1 (offset o) receives the sender's
        column rev_slots[d] (its out-slot pointing back at us)."""
        outs = [cols[0]]
        for d, off in enumerate(self.offsets):
            outs.append(self._shift(cols[self.rev_slots[d]], off))
        return jnp.stack(outs)

    def all_sum(self, x):
        if self.ledger is not None:
            self.ledger.record_collective(
                jnp.size(x) * jnp.dtype(jnp.result_type(x)).itemsize)
        return jax.lax.psum(x, self.axes)

    def all_max(self, x):
        if self.ledger is not None:
            self.ledger.record_collective(
                jnp.size(x) * jnp.dtype(jnp.result_type(x)).itemsize)
        return jax.lax.pmax(x, self.axes)


def dense_parts(setup) -> tuple:
    """(SolverOps, DenseComm) for a ``repro.core.admm.DkpcaSetup``."""
    ops = SolverOps(kcross=setup.kcross, k=setup.k, lam=setup.lam,
                    vec=setup.vec,
                    mask=jnp.asarray(setup.mask, setup.k.dtype))
    return ops, DenseComm(setup.src, setup.rsl)


# ---- the shared step ------------------------------------------------------

def _pinv_lam(lam: jax.Array, rel_thresh: float = 1e-5) -> jax.Array:
    """Pseudo-inverse eigenvalues of K_j (drop the null space)."""
    return jnp.where(lam > rel_thresh * lam[-1], 1.0 / lam, 0.0)


def admm_step(ops: SolverOps, comm, state: AdmmState, rho_slots: jax.Array,
              project: str = "ball",
              slot_mask: Optional[jax.Array] = None):
    """One ADMM iteration (paper eq. 10-13, per-slot-rho generalization).

    Args:
      ops: per-node constants (DenseComm: leading J axis on every field).
      comm: ``DenseComm`` or ``RingComm`` transport.
      state: incoming iterate; only (alpha, b, t) drive the update — g,
        znorm2, rho are refreshed outputs.
      rho_slots: (S,) per-node per-slot rho for THIS iteration (DenseComm:
        (J, S)); zero on invalid slots.
      project: "ball" (paper eq. 11), "sphere" (always renormalize), or
        "rescale" (ball + global gauge renormalization; needs comm.all_max).
      slot_mask: optional {0,1} mask over slots (same shape as
        ``rho_slots``) censoring links down for THIS iteration — the
        COKE-style degradation under faults (docs/FAULT_TOLERANCE.md):
        ``rho_bar`` renormalizes over the slots actually heard, the
        censored constraints leave the z/alpha updates AND the residual,
        and their duals freeze (rho = 0 ⇒ the eq. 13 update is a no-op).
        Slot 0 (self) must never be masked. A node isolated outright this
        iteration (every slot censored, possible only without a self
        slot) holds its (alpha, B) instead of collapsing to zero.

    Returns:
      (state', primal_residual) — state' has t+1 and the g/znorm2/rho
      produced by this iteration; the residual is the global
      ||K alpha 1 - G||_F over valid slots.
    """
    ledger = getattr(comm, "ledger", None)
    if ledger is not None:
        # trace-time bracket: everything the transport records until
        # end_iteration is exactly one iteration's traffic (repro.obs.comm)
        ledger.begin_iteration()
    faulty = slot_mask is not None
    if faulty:
        ops = dataclasses.replace(ops, mask=ops.mask * slot_mask)
        rho_slots = rho_slots * slot_mask
    alpha, b = state.alpha, state.b

    # ---- message round 1: K^-1 B columns + alpha --------------------------
    def pack(o, alpha_j, b_j):
        m1 = o.vec @ ((o.vec.T @ b_j) * _pinv_lam(o.lam)[:, None])  # (N, S)
        s, n = b_j.shape[1], b_j.shape[0]
        return (jnp.swapaxes(m1, 0, 1),
                jnp.broadcast_to(alpha_j[None, :], (s, n)))

    cols_m1, cols_a = comm.local(pack)(ops, alpha, b)
    recv_m1 = comm.exchange(cols_m1)
    recv_a = comm.exchange(cols_a)

    # ---- Z-update (eq. 10-11) --------------------------------------------
    def z_update(o, rho_j, rm1, ra):
        rho_bar = jnp.sum(rho_j)
        if faulty:
            # fully-censored node: avoid 0/0 (its update is discarded below)
            rho_bar = jnp.maximum(rho_bar, 1e-30)
        c = ((rm1 + rho_j[:, None] * ra) / rho_bar) * o.mask[:, None]
        znorm2 = jnp.einsum("an,abnm,bm->", c, o.kcross, c)
        rs = jax.lax.rsqrt(jnp.maximum(znorm2, 1e-30))
        if project == "sphere":
            scale = rs
        else:
            scale = jnp.where(znorm2 > 1.0, rs, 1.0)
        p = scale * jnp.einsum("abnm,bm->an", o.kcross, c)     # (S, N)
        return p, znorm2

    p, znorm2 = comm.local(z_update)(ops, rho_slots, recv_m1, recv_a)

    # ---- message round 2: z projections ----------------------------------
    g_slots = comm.exchange(p)

    # ---- alpha-update (eq. 12) + eta-update (eq. 13) ---------------------
    def primal_dual(o, alpha_j, b_j, rho_j, g_s):
        g = jnp.swapaxes(g_s, 0, 1) * o.mask[None, :]          # (N, S)
        rho_bar = jnp.sum(rho_j)
        rhs = jnp.sum(rho_j[None, :] * g - b_j * o.mask[None, :], axis=1)
        lam = o.lam
        den = rho_bar * lam - 2.0 * lam * lam
        # drop (don't invert) directions where the alpha-Hessian is not PD —
        # during rho warm-up large-N kernels can violate Assumption 2 for a
        # few iterations; clamping would amplify those modes into divergence.
        inv = jnp.where((lam > 1e-5 * lam[-1]) & (den > 0), 1.0 / den, 0.0)
        alpha_n = o.vec @ ((o.vec.T @ rhs) * inv)
        ka = o.k @ alpha_n
        b_n = (b_j + rho_j[None, :] * (ka[:, None] - g)) * o.mask[None, :]
        res_part = jnp.sum(o.mask[None, :] * (ka[:, None] - g) ** 2)
        return alpha_n, b_n, g, res_part

    alpha_n, b_n, g, res_part = comm.local(primal_dual)(
        ops, alpha, b, rho_slots, g_slots)
    if faulty:
        # A node that heard nobody this iteration (rho_bar = 0) has no
        # consensus information: den <= 0 zeroes every direction and the
        # naive update would collapse alpha to 0. Hold its state instead.
        live = jnp.sum(rho_slots, axis=-1) > 0.0
        alpha_n = jnp.where(live[..., None], alpha_n, alpha)
        b_n = jnp.where(live[..., None, None], b_n, b)
    res = jnp.sqrt(comm.all_sum(res_part))

    if project == "rescale":
        # Beyond-paper gauge renormalization: while no node's ||z_hat||
        # exceeds 1 the iteration is 1-homogeneous in (alpha, B) jointly, so
        # a global rescale replays the same trajectory in a different gauge —
        # removing the slow decay into the degenerate z=0 stationary point.
        zmax = jnp.sqrt(jnp.maximum(comm.all_max(znorm2), 1e-30))
        gain = jnp.where(zmax < 1.0, 1.0 / zmax, 1.0)
        alpha_n = alpha_n * gain
        b_n = b_n * gain

    new_state = AdmmState(alpha=alpha_n, b=b_n, g=g, znorm2=znorm2,
                          t=state.t + 1, rho=rho_slots)
    if ledger is not None:
        ledger.end_iteration()
    return new_state, res


def lagrangian(ops: SolverOps, alpha, b, g, rho_slots) -> jax.Array:
    """Dual-space augmented Lagrangian eq. (8), summed over nodes
    (DenseComm layout: leading J axis on every argument):
    L = sum_j [ -a^T K^2 a + sum_s B_s^T C_s + sum_s rho_s/2 C_s^T K C_s ],
    C_s = alpha - K^{-1} G_s."""
    def node(o, alpha_j, b_j, g_j, rho_j):
        ka = o.k @ alpha_j
        kinv_g = o.vec @ ((o.vec.T @ g_j) * _pinv_lam(o.lam)[:, None])
        cres = (alpha_j[:, None] - kinv_g) * o.mask[None, :]
        return (-jnp.sum(ka * ka) + jnp.sum(b_j * cres)
                + 0.5 * jnp.sum(rho_j[None, :] * cres * (o.k @ cres)))

    return jnp.sum(jax.vmap(node)(ops, alpha, b, g, rho_slots))


# ---- chunked, resumable driver -------------------------------------------

@dataclasses.dataclass
class ChunkResult:
    """One driver chunk: the live state plus this chunk's per-iteration
    histories (alpha (c, J, N), Lagrangian/residual/rho2 (c,) each)."""

    state: AdmmState
    alpha_hist: jax.Array
    lagrangian: jax.Array
    primal_residual: jax.Array
    rho_hist: jax.Array
    ckpt_path: Optional[str] = None
    stopped: bool = False          # residual-based early stop fired here
    # communication accounting for THIS chunk (0 without a ledger):
    # point-to-point payload bytes / messages moved by its iterations
    comm_bytes: int = 0
    comm_messages: int = 0


# ---- refresh cadence policies ---------------------------------------------
#
# The serving side republishes a refreshed model from the driver's live
# state (``repro.serve.publisher.stream_chunks``). How often is a policy on
# the DRIVER's chunk stream: any object with ``should_refresh(ChunkResult)
# -> bool``, consulted once per chunk (the final chunk always publishes so
# the served model never lags the finished fit).

class EveryK:
    """Fixed cadence: fire on every k-th chunk."""

    def __init__(self, k: int = 1):
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k = k
        self._n = 0

    def should_refresh(self, chunk: "ChunkResult") -> bool:
        self._n += 1
        return self._n % self.k == 0


class ResidualImprovement:
    """Residual-driven cadence: fire only when the primal residual has
    IMPROVED by at least ``rel_drop`` (fractional) since the last firing.

    The serving analogue of COKE's communication censoring: a refresh that
    barely moves the iterate is not worth a publish, while a plateau-then-
    drop (e.g. after a rho switch) publishes immediately. The first chunk
    always fires (there is no baseline yet), so a freshly started stream
    serves real coefficients as soon as possible.
    """

    def __init__(self, rel_drop: float = 0.1):
        if not 0.0 <= rel_drop < 1.0:
            raise ValueError(f"rel_drop must be in [0, 1), got {rel_drop}")
        self.rel_drop = rel_drop
        self._last: Optional[float] = None

    def should_refresh(self, chunk: "ChunkResult") -> bool:
        res = float(chunk.primal_residual[-1])
        if self._last is None or res <= (1.0 - self.rel_drop) * self._last:
            self._last = res
            return True
        return False


def resolve_refresh_policy(policy) -> object:
    """Normalize a refresh-cadence spec to a policy object.

    Accepts an int (every k chunks), the string "residual"
    (``ResidualImprovement`` defaults), any object already exposing
    ``should_refresh``, a bare ``ChunkResult -> bool`` callable, or None
    (every chunk).
    """
    if policy is None:
        return EveryK(1)
    if isinstance(policy, int):
        return EveryK(policy)
    if isinstance(policy, str):
        if policy != "residual":
            raise ValueError(f"unknown refresh policy {policy!r}")
        return ResidualImprovement()
    if hasattr(policy, "should_refresh"):
        return policy
    if callable(policy):
        class _Fn:
            def should_refresh(self, chunk, _fn=policy):
                return bool(_fn(chunk))
        return _Fn()
    raise TypeError(f"cannot interpret refresh policy {policy!r}")


def _slot_rho_dense(mask: jax.Array, rho1, rho2) -> jax.Array:
    """(J, S) per-slot rho from a (J, S) float mask."""
    j, s = mask.shape
    r = jnp.concatenate(
        [jnp.full((j, 1), rho1), jnp.full((j, s - 1), rho2)], axis=1)
    return r * mask


@partial(jax.jit, static_argnames=("n_steps", "project", "ledger",
                                   "wire_entries"))
def _dense_chunk(ops: SolverOps, src, rsl, state: AdmmState,
                 rho1_arr, rho2_arr, n_steps: int, project: str,
                 ledger: Optional[CommLedger] = None,
                 wire_entries: int = 0,
                 link_mask: Optional[jax.Array] = None):
    # ledger/wire_entries are static: the ledger records at trace time
    # (hashed by identity — one ledger per run_chunked call, so at most
    # one extra compilation per run vs the unledgered path).
    # link_mask is a TRACED (n_steps, J, S) {0,1} array (or None — the
    # fault-free trace is byte-identical to before the fault layer
    # existed); row i censors iteration i's links, transport-level via
    # FaultyComm and consensus-level via admm_step(slot_mask=...).
    comm = DenseComm(src, rsl, ledger=ledger, wire_entries=wire_entries)
    if link_mask is not None:
        from ..faults.comm import FaultyComm  # lazy: leaf module, no cycle
        base_comm = comm

    def step(carry, i):
        st = carry
        rho_slots = _slot_rho_dense(ops.mask, rho1_arr[i], rho2_arr[i])
        if link_mask is None:
            new, res = admm_step(ops, comm, st, rho_slots, project)
        else:
            sm = link_mask[i]
            new, res = admm_step(ops, FaultyComm(base_comm, sm), st,
                                 rho_slots, project, slot_mask=sm)
        # Theorem-2 pairing: L(alpha^t, Z^t, eta^t) with Z^t generated from
        # the incoming (alpha^t, eta^t) — i.e. this step's g.
        lag = lagrangian(ops, st.alpha, st.b, new.g, rho_slots)
        return new, (new.alpha, lag, res)

    final, (ahist, lhist, rhist) = jax.lax.scan(
        step, state, jnp.arange(n_steps))
    return final, ahist, lhist, rhist


def resolve_rho2(rho2, setup) -> Callable[[int], float]:
    """Normalize a rho2 policy to a host-side ``t -> float``.

    Accepts a ``RhoSchedule``, the string "theorem2" (Assumption-2 constant
    for this setup), a plain number, or any callable ``t -> rho``.
    """
    if rho2 is None:
        rho2 = RhoSchedule()
    if isinstance(rho2, str):
        if rho2 != "theorem2":
            raise ValueError(f"unknown rho2 policy {rho2!r}")
        from .admm import theorem2_rho
        r = theorem2_rho(setup)
        return lambda t: r
    if isinstance(rho2, RhoSchedule):
        return lambda t: float(rho2.at(t))
    if callable(rho2):
        return rho2
    r = float(rho2)
    return lambda t: r


def run_chunked(setup, n_iters: int = 30, chunk: int = 10,
                rho1: float = 100.0,
                rho2: Union[RhoSchedule, str, float, Callable, None] = None,
                project: str = "ball", init: str = "local", seed: int = 0,
                alpha0: Optional[jax.Array] = None,
                state: Optional[AdmmState] = None,
                tol: float = 0.0,
                ckpt_dir: Optional[str] = None,
                ckpt_every: int = 1,
                ledger: Optional[CommLedger] = None,
                link_mask: Optional[np.ndarray] = None
                ) -> Iterator[ChunkResult]:
    """Resumable chunked driver for the reference path (Alg. 1).

    Scans ``chunk`` iterations per jitted call and yields a ``ChunkResult``
    after each, so callers can observe/checkpoint/re-tune/publish mid-run.
    The SPMD equivalent is threading (alpha, b, t0) through repeated
    ``repro.core.dkpca.dkpca_distributed`` calls.

    Concurrency contract: the driver itself is single-threaded and holds no
    locks — it must be advanced from ONE thread. Yielded ``ChunkResult``s
    are immutable snapshots (device arrays are never mutated in place), so
    handing ``result.state.alpha`` to another thread — e.g.
    ``repro.serve.publisher.BackgroundPublisher.refresh`` — is safe without
    synchronization on this side; the publisher's own condition variable
    guards the handoff.

    Args:
      setup: ``repro.core.admm.DkpcaSetup``.
      n_iters: total iteration budget (across all chunks, including any
        completed by a resumed ``state``).
      chunk: iterations per jitted chunk (the yield granularity).
      rho1: self-slot rho (ignored when setup.include_self is False).
      rho2: neighbor rho policy — ``RhoSchedule`` (default: paper warm-up),
        "theorem2", a constant, or a callable ``t -> rho``; evaluated
        host-side at chunk boundaries, so switching policy mid-run between
        driver invocations is well-defined (the warm (alpha, B) state
        carries the z warm-start across the switch).
      project: see ``admm_step``.
      init/seed/alpha0: initial alpha when ``state`` is None —
        ``init="local"`` (default) warm-starts z at the pooled local kPCA
        solutions (see module docstring); ``init="paper"`` is the paper's
        unnormalized Gaussian.
      state: resume from a live/restored ``AdmmState`` (its ``t`` counts
        against ``n_iters``).
      tol: early stop when the primal residual drops below this (0 = off).
      ckpt_dir: checkpoint the state every ``ckpt_every`` chunks (and at the
        final chunk) via ``save_state``.
      ledger: a ``repro.obs.CommLedger`` to account per-iteration
        communication into (network-wide bytes for this dense transport);
        each yielded chunk then carries ``comm_bytes``/``comm_messages``.
      link_mask: optional ``(n_iters, J, S)`` {0,1} array censoring links
        per ABSOLUTE iteration index (compiled from a
        ``repro.faults.FaultPlan`` via ``plan.link_mask``); row t is
        applied at iteration t via ``admm_step(slot_mask=...)`` plus a
        transport-level ``FaultyComm`` wrap. None (default) keeps the
        fault-free jit trace unchanged.

    Yields:
      ``ChunkResult`` per chunk; generator ends after the final chunk or
      the first chunk whose result has ``stopped=True``.
    """
    from .admm import initial_alpha  # lazy: admm imports this module
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    if ckpt_every < 1:
        raise ValueError(f"ckpt_every must be >= 1, got {ckpt_every}")
    rho2_fn = resolve_rho2(rho2, setup)
    if state is None:
        if alpha0 is None:
            alpha0 = initial_alpha(setup, init, seed)
        state = init_state(alpha0, setup.n_slots)
    ops, comm = dense_parts(setup)
    rho1_eff = float(rho1) if setup.include_self else 0.0
    if link_mask is not None:
        link_mask = np.asarray(link_mask, np.float32)
        j, s = np.asarray(setup.src).shape
        if link_mask.shape != (n_iters, j, s):
            raise ValueError(
                f"link_mask shape {link_mask.shape} != {(n_iters, j, s)}")

    wire_entries = 0
    if ledger is not None:
        # Off-node routing entries = directed edges one exchange moves
        # data over: slot s of node j is remote iff its source is another
        # node AND the slot is valid. Host-side (setup tables are
        # concrete); DenseComm multiplies by payload size at trace time.
        src_np = np.asarray(setup.src)
        mask_np = np.asarray(setup.mask).astype(bool)
        own = np.arange(src_np.shape[0], dtype=src_np.dtype)[:, None]
        wire_entries = int(np.sum((src_np != own) & mask_np))

    m_iters = metrics.counter(
        "solver_iterations_total", "ADMM iterations executed",
        transport="dense")
    m_chunks = metrics.counter(
        "solver_chunks_total", "driver chunks yielded", transport="dense")
    m_bytes = metrics.counter(
        "comm_bytes_total", "point-to-point ADMM payload bytes",
        transport="dense")
    m_res = metrics.gauge(
        "solver_primal_residual", "last observed primal residual")

    t = int(state.t)
    chunk_idx = 0
    while t < n_iters:
        c = min(chunk, n_iters - t)
        with trace.span("solver.rho2", t=t, steps=c):
            rho2_arr = jnp.asarray([rho2_fn(tt) for tt in range(t, t + c)],
                                   jnp.float32)
        rho1_arr = jnp.full((c,), rho1_eff, jnp.float32)
        # The span times trace + dispatch; execution is async (the device
        # is only awaited where a host value is read, e.g. the residual).
        lm_chunk = None
        if link_mask is not None:
            lm_chunk = jnp.asarray(link_mask[t:t + c])
        with trace.span("solver.step", t=t, steps=c):
            state, ahist, lhist, rhist = _dense_chunk(
                ops, comm.src, comm.rsl, state, rho1_arr, rho2_arr, c,
                project, ledger=ledger, wire_entries=wire_entries,
                link_mask=lm_chunk)
        t += c
        chunk_idx += 1
        comm_bytes = comm_msgs = 0
        if ledger is not None:
            ledger.add_iterations(c)
            per = ledger.per_iter
            comm_bytes, comm_msgs = per.bytes * c, per.messages * c
            m_bytes.inc(comm_bytes)
        m_iters.inc(c)
        m_chunks.inc()
        stopped = False
        if tol > 0.0:
            with trace.span("solver.residual", t=t):
                res_last = float(rhist[-1])
            m_res.set(res_last)
            stopped = res_last < tol
        ckpt_path = None
        if ckpt_dir and (chunk_idx % ckpt_every == 0 or t >= n_iters
                         or stopped):
            with trace.span("solver.checkpoint", t=t):
                ckpt_path = save_state(ckpt_dir, state)
        yield ChunkResult(state=state, alpha_hist=ahist, lagrangian=lhist,
                          primal_residual=rhist, rho_hist=rho2_arr,
                          ckpt_path=ckpt_path, stopped=stopped,
                          comm_bytes=comm_bytes, comm_messages=comm_msgs)
        if stopped:
            return


# ---- persistence (repro.checkpoint layout) --------------------------------

def save_state(ckpt_dir: str, state: AdmmState, keep_last: int = 3) -> str:
    """Checkpoint a live ``AdmmState`` (step number == iteration count)."""
    from ..checkpoint import save_checkpoint
    t = int(state.t)
    tree = {"alpha": state.alpha, "b": state.b, "g": state.g,
            "znorm2": state.znorm2, "rho": state.rho}
    return save_checkpoint(ckpt_dir, t, tree,
                           metadata={"kind": "admm_state", "t": t},
                           keep_last=keep_last)


def load_state(ckpt_dir: str, step: Optional[int] = None) -> AdmmState:
    """Restore an ``AdmmState`` checkpoint (latest step by default)."""
    from ..checkpoint import restore_checkpoint
    tree, meta, step = restore_checkpoint(ckpt_dir, step)
    if meta.get("kind") != "admm_state":
        raise ValueError(f"{ckpt_dir} is not an AdmmState checkpoint: {meta}")
    return AdmmState(alpha=tree["alpha"], b=tree["b"], g=tree["g"],
                     znorm2=tree["znorm2"],
                     t=jnp.asarray(int(meta.get("t", step)), jnp.int32),
                     rho=tree["rho"])


__all__ = [
    "AdmmState", "ChunkResult", "DenseComm", "EveryK", "ResidualImprovement",
    "RingComm", "SolverOps", "admm_step", "dense_parts", "init_state",
    "lagrangian", "load_state", "resolve_refresh_policy", "resolve_rho2",
    "run_chunked", "save_state",
]
