"""The paper's experimental workflow end-to-end (Figs 3/4/5 regimes) plus
the fault-tolerance story: a node dies mid-run, the ring re-knits, ADMM
continues on the survivors.

    PYTHONPATH=src python examples/decentralized_kpca.py [--m 784]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import (KernelSpec, build_setup, central_kpca, run_admm,
                        similarity)
from repro.core.topology import reknit, ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf")


def mean_sim(alphas, nodes, pooled, ag, gamma):
    return float(np.mean([
        float(similarity(alphas[j], jnp.asarray(nodes[j]), ag,
                         jnp.asarray(pooled), SPEC, gamma=gamma))
        for j in range(nodes.shape[0])]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--nodes", type=int, default=20)
    args = ap.parse_args()

    print(f"== decentralized kPCA: J={args.nodes}, N=100, M={args.m} ==")
    nodes, pooled = node_dataset(args.nodes, 100, m=args.m, seed=0)
    graph = ring(args.nodes, hops=2)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    ag, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1, gamma=setup.gamma)
    res = run_admm(setup, n_iters=30)
    for t in (1, 3, 7, 15, 29):
        print(f"  iter {t + 1:3d}: similarity = "
              f"{mean_sim(res.alpha_hist[t], nodes, pooled, ag[:, 0], setup.gamma):.4f}")

    print("== node failure: nodes 5 and 6 die; ring re-knits ==")
    g2, survivors = reknit(graph, [5, 6])
    nodes2 = nodes[survivors]
    pooled2 = nodes2.reshape(-1, nodes2.shape[-1])
    setup2 = build_setup(jnp.asarray(nodes2), g2, SPEC)
    ag2, _, _ = central_kpca(jnp.asarray(pooled2), SPEC, 1,
                             gamma=setup2.gamma)
    res2 = run_admm(setup2, n_iters=30)
    print(f"  survivors' similarity to the *surviving-data* central "
          f"solution: {mean_sim(res2.alpha, nodes2, pooled2, ag2[:, 0], setup2.gamma):.4f}")


if __name__ == "__main__":
    main()
