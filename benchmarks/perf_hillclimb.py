"""§Perf hillclimb replay: runs the before/after variants for the three
chosen cells and writes results/perf_iterations.json.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb [--only a,b,c]

Iterations (hypothesis -> change -> measure; narratives in EXPERIMENTS.md):
  (a) llama3-405b x train_4k:
      a0  RECORDED baseline before the activation-sharding fix (GSPMD
          replicated the batch; 14.2 TB/device of f32 activation
          all-reduces). Numbers archived from the pre-fix measurement —
          the code change is models/common.constrain_act.
      a1  current baseline (constraints on, remat=full)
      a2  remat full -> dots (keep matmul outputs; trade memory for the
          recompute FLOPs)
      a3  bf16 logits CE in f32 via lse only (already default) — replaced
          by: gradient all-reduce precision bf16 (comm term)
  (b) mixtral-8x22b x prefill_32k:
      b1  baseline (chunked attention, full quadratic with masking)
      b2  swa_banded=True (skip out-of-window chunk pairs)
  (c) dkpca-paper (per-ADMM-iteration):
      c1  baseline fp32 messages
      c2  message_dtype=bfloat16 (halve ICI payload)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

# dry-run environment (512 devices) — must import before jax init
from repro.launch import dryrun as dr  # noqa: E402

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "perf_iterations.json")

# archived pre-fix measurement (see EXPERIMENTS.md §Perf (a) iter 1)
A0_RECORDED = {
    "arch": "llama3-405b", "shape": "train_4k", "mesh": "16x16", "ok": True,
    "note": "pre-fix baseline: no activation sharding constraints",
    "flops_per_device": 2.168e16,
    "bytes_accessed_per_device": float("nan"),
    "collectives": {"all-reduce": {"count": 2156, "bytes": 1.5198e13},
                    "all-gather": {"count": 2, "bytes": 3.363e10},
                    "collective-permute": {"count": 1, "bytes": 4.0}},
    "n_devices": 256, "n_params": 405.5e9, "n_active_params": 405.5e9,
}


def cell_a():
    import jax.numpy as jnp  # noqa: F401
    out = {"a0_prefix_baseline": A0_RECORDED}
    cfg, _ = dr.resolve_cfg("llama3-405b", "train_4k")
    r1 = dr.run_cell("llama3-405b", "train_4k", False)
    out["a1_constrained_remat_full"] = dataclasses.asdict(r1)
    cfg2 = dataclasses.replace(cfg, remat="dots")
    r2 = dr.run_cell("llama3-405b", "train_4k", False, cfg=cfg2)
    out["a2_remat_dots"] = dataclasses.asdict(r2)
    return out


def cell_b():
    out = {}
    cfg, _ = dr.resolve_cfg("mixtral-8x22b", "prefill_32k")
    r1 = dr.run_cell("mixtral-8x22b", "prefill_32k", False)
    out["b1_baseline_masked"] = dataclasses.asdict(r1)
    cfg2 = dataclasses.replace(cfg, swa_banded=True)
    r2 = dr.run_cell("mixtral-8x22b", "prefill_32k", False, cfg=cfg2)
    out["b2_swa_banded"] = dataclasses.asdict(r2)
    return out


def cell_c():
    import jax.numpy as jnp
    out = {}
    r1 = dr.run_dkpca_cell(False)
    out["c1_baseline_fp32_msgs"] = dataclasses.asdict(r1)
    r2 = dr.run_dkpca_cell(False, message_dtype=jnp.bfloat16, tag="-bf16msg")
    out["c2_bf16_messages"] = dataclasses.asdict(r2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="a,b,c")
    args = ap.parse_args()
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    results = {}
    if os.path.exists(RESULTS):
        results = json.load(open(RESULTS))
    for which in args.only.split(","):
        print(f"[perf] running cell ({which}) ...", flush=True)
        results.update({"a": cell_a, "b": cell_b, "c": cell_c}[which]())
        with open(RESULTS, "w") as f:
            json.dump(results, f, indent=1)
    for k, v in results.items():
        if not isinstance(v, dict) or not v.get("ok"):
            continue
        coll = sum(c["bytes"] for c in v.get("collectives", {}).values())
        print(f"{k}: flops/dev={v.get('flops_per_device', 0):.4g} "
              f"coll/dev={coll / 2**30:.2f}GiB")


if __name__ == "__main__":
    main()
