"""Out-of-sample projection: the fitted-model artifact for serving kPCA.

The product of the whole fitting pipeline (central eigensolve, Alg.-1 ADMM
consensus, or top-k deflation) is a set of dual coefficient vectors; what a
*serving* system needs is the centered out-of-sample score (paper §1):

    score_c(x') = (w*)^T phi_c(x')
                = sum_i alpha_i [K(x_i, x') - m(x') - m_i + mu_bar]

with m(x') = mean_t K(x', t) over the training set, m_i = mean_t K(x_i, t)
and mu_bar the grand mean (the same ``kernel_mean_stats`` quantities the
decentralized fit centers with). Grouping terms, every model this module
produces — centered, uncentered, or landmark-compressed — serves through ONE
formula:

    score(x') = K(x', X_s) @ coefs + mean_l K(x', x_l) * row_mean_coef + bias

i.e. a single (B, L) kernel block against the support set X_s with a fused
row-mean + bias epilogue. ``repro.kernels.project`` implements exactly this
contract as a tiled Pallas kernel; this module is the numerical ground truth
and the artifact container.

Landmark compression (``compress``) projects each component w = Phi(X) a_eff
onto span{phi(z_l)} of L landmarks (Nystrom, in the spirit of Balcan et
al.'s communication-efficient distributed kPCA): beta = K_ZZ^+ K_ZX a_eff.
Because it is an orthogonal projection in the RKHS, the reconstruction error
||w - w_hat||_H is computable exactly at compress time (returned alongside
the model) and is monotonically non-increasing in L for nested landmark
sets, which ``landmark_schedule``'s fixed-seed prefixes guarantee.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .kernels_math import KernelSpec, gram, resolve_gamma

# Shape conventions used throughout this module:
#   B = query batch, M = features, L = support rows, C = components,
#   S = shards, Lp = per-shard padded support capacity.


@dataclasses.dataclass(frozen=True)
class FittedKpca:
    """Servable kPCA model: support set + dual coefficients + centering.

    x_support:     (L, M) training samples or landmarks.
    coefs:         (L, C) dual coefficients, one column per component.
    row_mean_coef: (C,) weight of mean_l K(x', x_l) in the score
                   (``-sum_i alpha_i`` for a centered fit; 0 otherwise).
    bias:          (C,) constant score offset (``mu_bar sum_i alpha_i
                   - m . alpha`` for a centered fit; 0 otherwise).
    gamma:         () resolved RBF bandwidth actually used at fit time.
    k_row_mean:    optional (L,) cached kernel mean statistics
                   m_i = mean_t K(x_i, t) over the training set — kept so
                   ``refresh_coefficients`` can rebuild the centering terms
                   for NEW coefficients without re-forming the training
                   Gram (None for uncentered or compressed models).
    k_grand_mean:  optional () cached grand mean mu_bar (same caveat).
    spec:          kernel spec (static pytree metadata).
    """

    x_support: jax.Array
    coefs: jax.Array
    row_mean_coef: jax.Array
    bias: jax.Array
    gamma: jax.Array
    k_row_mean: Optional[jax.Array] = None
    k_grand_mean: Optional[jax.Array] = None
    spec: KernelSpec = KernelSpec()

    @property
    def n_support(self) -> int:
        return self.x_support.shape[0]

    @property
    def n_features(self) -> int:
        return self.x_support.shape[1]

    @property
    def n_components(self) -> int:
        return self.coefs.shape[1]


def _flatten(m: FittedKpca):
    return ((m.x_support, m.coefs, m.row_mean_coef, m.bias, m.gamma,
             m.k_row_mean, m.k_grand_mean), m.spec)


def _unflatten(spec, leaves):
    return FittedKpca(*leaves, spec=spec)


jax.tree_util.register_pytree_node(FittedKpca, _flatten, _unflatten)


def _as_2d(alpha: jax.Array) -> jax.Array:
    alpha = jnp.asarray(alpha)
    return alpha[:, None] if alpha.ndim == 1 else alpha


def from_dual(x_train: jax.Array, alpha: jax.Array, spec: KernelSpec,
              gamma: Optional[jax.Array] = None,
              center: bool = True) -> FittedKpca:
    """Build the serving artifact from any dual solution.

    Args:
      x_train: (N, M) training samples — become the support set.
      alpha: (N,) or (N, C) dual coefficients (central eigensolve, ADMM
        consensus, deflation — anything living in the dual space).
      spec: kernel spec used at fit time.
      gamma: () fit-time RBF bandwidth; resolved from ``spec`` (median
        heuristic on ``x_train``) when None.
      center: True => bake the centered-score terms (row_mean_coef/bias)
        from the kernel mean statistics.

    Returns:
      ``FittedKpca`` with coefs (N, C) float32.

    For ``center=True`` the *uncentered* training Gram is formed once here
    (fit-time cost) to extract the kernel mean statistics the centered score
    needs; serving never touches the training Gram again.
    """
    x_train = jnp.asarray(x_train)
    alpha = _as_2d(alpha).astype(jnp.float32)
    g = resolve_gamma(spec, x_train) if gamma is None else jnp.asarray(gamma)
    c = alpha.shape[1]
    if center:
        k_raw = gram(spec, x_train, gamma=g)
        m = jnp.mean(k_raw, axis=1)                       # (N,)
        mu_bar = jnp.mean(k_raw)
        alpha_sum = jnp.sum(alpha, axis=0)                # (C,)
        row_mean_coef = -alpha_sum
        bias = mu_bar * alpha_sum - m @ alpha
        stats = dict(k_row_mean=m, k_grand_mean=mu_bar)
    else:
        row_mean_coef = jnp.zeros((c,), jnp.float32)
        bias = jnp.zeros((c,), jnp.float32)
        stats = {}
    return FittedKpca(x_support=x_train, coefs=alpha,
                      row_mean_coef=row_mean_coef, bias=bias,
                      gamma=g.astype(jnp.float32), spec=spec, **stats)


def fit_central(x: jax.Array, spec: KernelSpec, n_components: int = 1,
                center: bool = True,
                gamma: Optional[jax.Array] = None) -> FittedKpca:
    """Fit central kPCA (paper problem (2)) and package it for serving.

    Args:
      x: (N, M) pooled training data.
      spec/gamma/center: as in ``from_dual``.
      n_components: C, number of kernel principal components to keep.

    Returns:
      ``FittedKpca`` with support (N, M) and coefs (N, C).
    """
    from .central import central_kpca
    x = jnp.asarray(x)
    g = resolve_gamma(spec, x) if gamma is None else jnp.asarray(gamma)
    alpha, _, _ = central_kpca(x, spec, n_components, center=center, gamma=g)
    return from_dual(x, alpha, spec, gamma=g, center=center)


def from_decentralized(x_nodes: jax.Array,
                       alpha: Union[jax.Array, Sequence[jax.Array]],
                       spec: KernelSpec, gamma: Optional[jax.Array] = None,
                       center: bool = True) -> FittedKpca:
    """Package an Alg.-1 consensus solution for serving.

    x_nodes: (J, N, M); alpha: (J, N) from ``run_admm`` or a list of (J, N)
    from ``run_admm_topk``. At consensus every node's w_j = phi(X_j) alpha_j
    approximates the same global component, so the pooled dual vector
    concat_j(alpha_j) / J represents their average on the pooled support
    set. ``center=True`` matches fits built with ``build_setup(...,
    center="global")`` (same global kernel-mean statistics).
    """
    x_nodes = jnp.asarray(x_nodes)
    j, n, m = x_nodes.shape
    if not isinstance(alpha, (list, tuple)):
        alpha = [alpha]
    pooled_alpha = jnp.stack(
        [jnp.reshape(a, (j * n,)) for a in alpha], axis=1) / j
    return from_dual(x_nodes.reshape(j * n, m), pooled_alpha, spec,
                     gamma=gamma, center=center)


def _pool_alpha(alpha: Union[jax.Array, Sequence[jax.Array]],
                l_full: int) -> jax.Array:
    """Normalize any live dual solution to pooled (L, C) float32.

    Accepts (L,) / (L, C) pooled coefficients, node-major (J, N[, C]) live
    solver state, or a list of per-component (J, N) solutions; node-major
    input is pooled exactly like ``from_decentralized`` (concat / J).
    """
    if isinstance(alpha, (list, tuple)):
        first = jnp.asarray(alpha[0])
        j = first.shape[0] if first.ndim == 2 else 1
        alpha = jnp.stack(
            [jnp.reshape(jnp.asarray(a), (-1,)) for a in alpha], axis=1)
    else:
        alpha = jnp.asarray(alpha)
        j = 1
        if alpha.ndim == 3 or (alpha.ndim == 2 and alpha.shape[0] != l_full):
            # node-major (J, N[, C]) live solver state
            j = alpha.shape[0]
            alpha = alpha.reshape(j * alpha.shape[1], -1)
    if alpha.shape[0] != l_full:
        raise ValueError(
            f"alpha with leading dim {alpha.shape[0]} does not match "
            f"the support set ({l_full} rows); compressed models "
            f"cannot be refreshed — refit and re-compress instead")
    return _as_2d(alpha).astype(jnp.float32) / j


def refresh_coefficients(model: Union[FittedKpca, "ShardedFittedKpca"],
                         alpha: Union[jax.Array, Sequence[jax.Array]]
                         ) -> Union[FittedKpca, "ShardedFittedKpca"]:
    """Rebuild a fitted model around NEW dual coefficients — the
    streaming-alpha path: a still-running ADMM driver hands its live
    ``AdmmState.alpha`` here every few chunks and publishes the result
    (``repro.serve.publisher.ModelHandle``) without ever re-forming the
    training Gram.

    The support set, bandwidth and kernel spec are reused as-is; the
    centering terms (row_mean_coef, bias) are recomputed from the CACHED
    kernel mean statistics (``k_row_mean``/``k_grand_mean``, recorded at
    fit time by ``from_dual(center=True)``) — an O(L*C) update instead of
    the O(L^2) Gram pass. A ``ShardedFittedKpca`` refreshes the same way
    per shard: each shard's coefficient rows are swapped against its own
    cached kernel-mean slice and the GLOBAL centering terms are rebuilt
    from the per-shard partial sums (see also
    ``refresh_shard_coefficients`` for swapping a single shard).

    Args:
      model: centered fit carrying its kernel-mean cache (or an uncentered
        fit, for which the centering terms stay zero). Compressed models
        lost the support-set/coefficient correspondence and are rejected.
      alpha: the new dual solution — (L,) / (L, C) on the pooled support
        set (sharded models: shard-concatenation order, which IS the
        pooled order for ``shard_fitted`` models), a node-major (J, N) /
        (J, N, C) live solver state, or a list of (J, N) per-component
        solutions; node-major input is pooled exactly like
        ``from_decentralized`` (concat / J).

    Returns:
      A new model of the same type (the input model is unchanged).
    """
    if isinstance(model, ShardedFittedKpca):
        return _refresh_sharded(model, alpha)
    if not isinstance(model, FittedKpca):
        raise TypeError(
            f"refresh_coefficients takes a FittedKpca or "
            f"ShardedFittedKpca, got {type(model).__name__}")
    alpha = _pool_alpha(alpha, model.n_support)
    c = alpha.shape[1]

    if model.k_row_mean is not None:
        alpha_sum = jnp.sum(alpha, axis=0)
        row_mean_coef = -alpha_sum
        bias = model.k_grand_mean * alpha_sum - model.k_row_mean @ alpha
    else:
        if bool(np.any(np.asarray(model.row_mean_coef))) or \
                bool(np.any(np.asarray(model.bias))):
            raise ValueError(
                "model is centered but carries no kernel-mean cache "
                "(k_row_mean/k_grand_mean) — refit with "
                "from_dual(center=True) to enable refresh_coefficients")
        row_mean_coef = jnp.zeros((c,), jnp.float32)
        bias = jnp.zeros((c,), jnp.float32)
    return FittedKpca(x_support=model.x_support, coefs=alpha,
                      row_mean_coef=row_mean_coef, bias=bias,
                      gamma=model.gamma, k_row_mean=model.k_row_mean,
                      k_grand_mean=model.k_grand_mean, spec=model.spec)


def project(model: FittedKpca, x_query: jax.Array,
            use_pallas: bool = False,
            interpret: Optional[bool] = None) -> jax.Array:
    """Centered out-of-sample scores for a query batch.

    Args:
      model: fitted artifact (support set (L, M), coefs (L, C)).
      x_query: (B, M) query batch.
      use_pallas: route through the fused Pallas kernel
        (``repro.kernels.project.project_op``) instead of the dense jnp
        oracle below; both implement the same one-formula contract.
      interpret: forwarded to the Pallas wrapper (default: interpret
        everywhere except real TPU).

    Returns:
      (B, C) float32 scores
      ``K(x_query, X_s) @ coefs + rowmean(K) * row_mean_coef + bias``.
    """
    x_query = jnp.asarray(x_query)
    if use_pallas:
        from ..kernels.project import project_op
        return project_op(model.spec, x_query, model.x_support, model.coefs,
                          row_mean_coef=model.row_mean_coef, bias=model.bias,
                          gamma=model.gamma, interpret=interpret)
    k = gram(model.spec, x_query, model.x_support, gamma=model.gamma)
    return (k @ model.coefs
            + jnp.mean(k, axis=1, keepdims=True) * model.row_mean_coef[None]
            + model.bias[None, :])


def effective_coefs(model: FittedKpca) -> jax.Array:
    """Fold the row-mean term into the dual coefficients.

    mean_l K(x', x_l) * c == K(x', X_s) @ (c/L * 1), so
    w = Phi(X_s) @ (coefs + row_mean_coef / L). Returns the (L, C) folded
    coefficients; used by ``compress`` and per-shard compression in
    ``shard_fitted`` (the folded form has no row-mean term left to center).
    """
    return model.coefs + model.row_mean_coef[None, :] / model.n_support


def landmark_schedule(n_support: int, seed: int = 0) -> np.ndarray:
    """Fixed random permutation (length ``n_support``) of support indices;
    taking prefixes of it yields NESTED landmark sets, so compression error
    is monotone non-increasing in the landmark count for a fixed seed."""
    return np.random.default_rng(seed).permutation(n_support)


def _nystrom_project(spec: KernelSpec, gamma: jax.Array, x: jax.Array,
                     a_eff: jax.Array, idx, rel_thresh: float
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Project w = Phi(x) a_eff onto span{phi(x[idx])} in the RKHS.

    Returns (z, beta, wh2): landmarks z = x[idx], landmark coefficients
    beta = K_ZZ^+ K_ZX a_eff, and wh2_c = ||w_hat_c||_H^2 (exact — used with
    ||w||_H^2 and the Pythagorean identity to get the projection error).
    """
    z = x[jnp.asarray(idx)]
    kzz = gram(spec, z, gamma=gamma)
    kzx = gram(spec, z, x, gamma=gamma)
    t = kzx @ a_eff                                      # (L, C) = Phi(Z)^T w
    lam, v = jnp.linalg.eigh(kzz)
    inv = jnp.where(lam > rel_thresh * jnp.maximum(lam[-1], 1e-30),
                    1.0 / lam, 0.0)
    beta = v @ (inv[:, None] * (v.T @ t))                # K_ZZ^+ Phi(Z)^T w
    wh2 = jnp.sum(beta * (kzz @ beta), axis=0)           # ||w_hat||_H^2
    return z, beta, wh2


def compress(model: FittedKpca, n_landmarks: int,
             seed: int = 0, rel_thresh: float = 1e-7
             ) -> Tuple[FittedKpca, jax.Array]:
    """Nystrom landmark compression of the support set.

    Projects each component w = Phi(X_s) a_eff onto span{phi(z_l)} of
    ``n_landmarks`` support points: beta = K_ZZ^+ K_ZX a_eff. Serving cost
    per query drops from O(L_full * M) to O(n_landmarks * M).

    Args:
      model: fitted artifact to compress.
      n_landmarks: landmark count in [1, model.n_support].
      seed: landmark-schedule seed; same seed => nested landmark sets.
      rel_thresh: relative eigenvalue cutoff for the K_ZZ pseudo-inverse.

    Returns:
      (compressed model, rel_err (C,)) with
      rel_err_c = ||w_c - w_hat_c||_H / ||w_c||_H, exact (computed from the
      Pythagorean identity for the RKHS projection).
    """
    l_full = model.n_support
    if not 0 < n_landmarks <= l_full:
        raise ValueError(f"n_landmarks={n_landmarks} not in [1, {l_full}]")
    idx = landmark_schedule(l_full, seed)[:n_landmarks]
    a_eff = effective_coefs(model)
    z, beta, wh2 = _nystrom_project(model.spec, model.gamma, model.x_support,
                                    a_eff, idx, rel_thresh)

    kxx = gram(model.spec, model.x_support, gamma=model.gamma)
    w2 = jnp.sum(a_eff * (kxx @ a_eff), axis=0)          # ||w||_H^2
    rel_err = jnp.sqrt(jnp.clip(w2 - wh2, 0.0) / jnp.maximum(w2, 1e-30))

    compressed = FittedKpca(
        x_support=z, coefs=beta,
        row_mean_coef=jnp.zeros_like(model.row_mean_coef),
        bias=model.bias, gamma=model.gamma, spec=model.spec)
    return compressed, rel_err


# ---- sharded artifact (multi-device serving) ------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedFittedKpca:
    """Device-sharded servable kPCA model (support-set partition).

    The projection score is a sum over support points, so it shards
    embarrassingly: shard j holds a contiguous slice of the support set and
    the matching dual-coefficient rows, computes the raw partial
    ``K(x', X_j) @ coefs_j`` plus the raw kernel row-sum (via the indicator
    column), and partials are psum-reduced across shards. The global
    centering terms — row-mean weight and bias, which depend on the FULL
    support set — are applied exactly once after the reduction
    (``finalize_partial_scores``). ``repro.serve.sharded`` is the execution
    path (shard_map over a device mesh, with a same-math single-device
    fallback).

    x_support:     (S, Lp, M) per-shard support slices, zero-padded to the
                   common per-shard capacity Lp.
    coefs_ext:     (S, Lp, C+1) per-shard coefficient rows; column C is the
                   valid-row indicator (1.0 on real rows, 0.0 on padding),
                   which makes each shard's raw kernel row-sum come out as
                   one extra column of the same matmul.
    row_mean_coef: (C,) global centering weight (zeros for models built
                   with per-shard landmark compression — the row-mean term
                   is folded into the coefficients first).
    bias:          (C,) global score offset, applied once post-reduction.
    gamma:         () fit-time RBF bandwidth, shared by all shards.
    n_support:     total TRUE support rows across shards (static; the 1/L
                   of the row-mean term).
    shard_sizes:   per-shard true row counts (static).
    k_row_mean:    optional (S, Lp) per-shard slices of the cached kernel
                   mean statistics m_i (zero on padding rows) — lets each
                   shard's coefficients refresh independently
                   (``refresh_coefficients``/``refresh_shard_coefficients``)
                   without re-forming any Gram (None for compressed or
                   uncentered models).
    k_grand_mean:  optional () cached grand mean mu_bar (same caveat).
    spec:          kernel spec (static pytree metadata).
    """

    x_support: jax.Array
    coefs_ext: jax.Array
    row_mean_coef: jax.Array
    bias: jax.Array
    gamma: jax.Array
    n_support: int
    shard_sizes: Tuple[int, ...]
    k_row_mean: Optional[jax.Array] = None
    k_grand_mean: Optional[jax.Array] = None
    spec: KernelSpec = KernelSpec()

    @property
    def n_shards(self) -> int:
        return self.x_support.shape[0]

    @property
    def shard_capacity(self) -> int:
        return self.x_support.shape[1]

    @property
    def n_features(self) -> int:
        return self.x_support.shape[2]

    @property
    def n_components(self) -> int:
        return self.coefs_ext.shape[2] - 1


def _flatten_sharded(m: ShardedFittedKpca):
    return ((m.x_support, m.coefs_ext, m.row_mean_coef, m.bias, m.gamma,
             m.k_row_mean, m.k_grand_mean),
            (m.n_support, m.shard_sizes, m.spec))


def _unflatten_sharded(aux, leaves):
    n_support, shard_sizes, spec = aux
    return ShardedFittedKpca(*leaves[:5], n_support=n_support,
                             shard_sizes=shard_sizes, k_row_mean=leaves[5],
                             k_grand_mean=leaves[6], spec=spec)


jax.tree_util.register_pytree_node(ShardedFittedKpca, _flatten_sharded,
                                   _unflatten_sharded)


def finalize_partial_scores(partials: jax.Array, row_mean_coef: jax.Array,
                            bias: jax.Array, n_support: int) -> jax.Array:
    """Global centering epilogue for reduced per-shard partials.

    Args:
      partials: (B, C+1) SUM over shards of ``K(x', X_j) @ coefs_ext_j`` —
        columns :C raw scores, column C the raw kernel row-sum over all
        true support rows.
      row_mean_coef: (C,) global centering weight.
      bias: (C,) global score offset.
      n_support: total true support rows (turns the row-sum into the mean).

    Returns:
      (B, C) final scores — identical to ``project`` on the gathered model.
    """
    c = partials.shape[-1] - 1
    kmean = partials[:, c] / n_support
    return (partials[:, :c] + kmean[:, None] * row_mean_coef[None, :]
            + bias[None, :])


def shard_fitted(model: FittedKpca, n_shards: int,
                 landmarks_per_shard: Optional[int] = None, seed: int = 0,
                 rel_thresh: float = 1e-7
                 ) -> Tuple[ShardedFittedKpca, jax.Array]:
    """Partition a ``FittedKpca`` across ``n_shards`` for sharded serving.

    The support set (and the matching dual-coefficient rows) is split into
    contiguous row slices; uneven L is handled by zero-padding every shard
    to the largest slice, with the indicator column zeroed on padding rows
    so padded rows contribute nothing to scores or row-sums.

    With ``landmarks_per_shard`` set, each shard's slice of the EFFECTIVE
    coefficients (row-mean term folded in — see ``effective_coefs``) is
    Nystrom-compressed onto min(landmarks_per_shard, shard size) landmarks
    chosen by a per-shard fixed-seed schedule (nested across landmark
    counts), in the spirit of the per-node subsampling of
    communication-efficient distributed kPCA (Balcan et al.) / COKE.

    Args:
      model: fitted artifact to shard.
      n_shards: shard count S in [1, model.n_support].
      landmarks_per_shard: per-shard landmark budget; None = no compression.
      seed: base seed for the per-shard landmark schedules.
      rel_thresh: pseudo-inverse cutoff (see ``compress``).

    Returns:
      (sharded model, rel_err_bound (C,)). The bound is the aggregate
      relative RKHS error sum_j ||w_j - w_hat_j||_H / ||w||_H — each
      per-shard term is exact (Pythagorean identity) and the sum bounds the
      error of the summed component by the triangle inequality. Zeros when
      no compression is requested (sharding alone is exact).
    """
    l_full, c = model.n_support, model.n_components
    if not 0 < n_shards <= l_full:
        raise ValueError(f"n_shards={n_shards} not in [1, {l_full}]")
    splits = np.array_split(np.arange(l_full), n_shards)

    if landmarks_per_shard is None:
        parts = [(np.asarray(model.x_support[jnp.asarray(ix)]),
                  np.asarray(model.coefs[jnp.asarray(ix)])) for ix in splits]
        row_mean_coef, bias = model.row_mean_coef, model.bias
        rel_err = jnp.zeros((c,), jnp.float32)
    else:
        if landmarks_per_shard < 1:
            raise ValueError(f"landmarks_per_shard={landmarks_per_shard} < 1")
        a_eff = effective_coefs(model)
        kxx = gram(model.spec, model.x_support, gamma=model.gamma)
        w2 = jnp.sum(a_eff * (kxx @ a_eff), axis=0)      # ||w||_H^2, global
        parts, err_abs = [], jnp.zeros((c,), jnp.float32)
        for j, ix in enumerate(splits):
            xj = model.x_support[jnp.asarray(ix)]
            aj = a_eff[jnp.asarray(ix)]
            order = landmark_schedule(len(ix), seed=seed + 7919 * j)
            z, beta, wh2 = _nystrom_project(
                model.spec, model.gamma, xj, aj,
                order[:min(landmarks_per_shard, len(ix))], rel_thresh)
            kjj = kxx[jnp.asarray(ix)][:, jnp.asarray(ix)]
            wj2 = jnp.sum(aj * (kjj @ aj), axis=0)       # ||w_j||_H^2
            err_abs = err_abs + jnp.sqrt(jnp.clip(wj2 - wh2, 0.0))
            parts.append((np.asarray(z), np.asarray(beta)))
        # The row-mean term was folded into a_eff, so it (and the per-query
        # row-sum it needs) vanishes from the compressed model.
        row_mean_coef = jnp.zeros_like(model.row_mean_coef)
        bias = model.bias
        rel_err = err_abs / jnp.sqrt(jnp.maximum(w2, 1e-30))

    sizes = tuple(int(x.shape[0]) for x, _ in parts)
    lp, m = max(sizes), model.n_features
    xs = np.zeros((n_shards, lp, m), np.float32)
    ae = np.zeros((n_shards, lp, c + 1), np.float32)
    for j, (xj, aj) in enumerate(parts):
        xs[j, :sizes[j]] = xj
        ae[j, :sizes[j], :c] = aj
        ae[j, :sizes[j], c] = 1.0                        # indicator column
    # Carry the kernel-mean cache per shard (zero on padding rows) so each
    # shard's coefficients can refresh independently; compression breaks
    # the support/coefficient correspondence, so the cache is dropped.
    stats = {}
    if landmarks_per_shard is None and model.k_row_mean is not None:
        kr = np.zeros((n_shards, lp), np.float32)
        m_full = np.asarray(model.k_row_mean, np.float32)
        for j, ix in enumerate(splits):
            kr[j, :sizes[j]] = m_full[ix]
        stats = dict(k_row_mean=jnp.asarray(kr),
                     k_grand_mean=model.k_grand_mean)
    return ShardedFittedKpca(
        x_support=jnp.asarray(xs), coefs_ext=jnp.asarray(ae),
        row_mean_coef=jnp.asarray(row_mean_coef, jnp.float32),
        bias=jnp.asarray(bias, jnp.float32), gamma=model.gamma,
        n_support=int(sum(sizes)), shard_sizes=sizes,
        spec=model.spec, **stats), rel_err


def _sharded_centering(model: ShardedFittedKpca, coefs_pad: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Global (row_mean_coef, bias) for new per-shard padded (S, Lp, C)
    coefficients, from the per-shard cached kernel-mean slices. Padding
    rows are zero in both the coefficients and the cache, so plain sums
    over (S, Lp) ARE the true-row sums."""
    alpha_sum = jnp.sum(coefs_pad, axis=(0, 1))          # (C,)
    m_dot = jnp.einsum("sl,slc->c", model.k_row_mean, coefs_pad)
    return -alpha_sum, model.k_grand_mean * alpha_sum - m_dot


def _require_sharded_cache(model: ShardedFittedKpca, c: int
                           ) -> Tuple[jax.Array, jax.Array, bool]:
    """(row_mean_coef, bias, centered) guard shared by the sharded refresh
    paths: with no cache, only an UNCENTERED model (all-zero centering
    terms) may refresh — its terms stay zero."""
    if model.k_row_mean is not None:
        return None, None, True
    if bool(np.any(np.asarray(model.row_mean_coef))) or \
            bool(np.any(np.asarray(model.bias))):
        raise ValueError(
            "sharded model carries centering terms but no per-shard "
            "kernel-mean cache (k_row_mean/k_grand_mean) — re-shard an "
            "uncompressed centered fit to enable coefficient refresh")
    return (jnp.zeros((c,), jnp.float32), jnp.zeros((c,), jnp.float32),
            False)


def _refresh_sharded(model: ShardedFittedKpca,
                     alpha: Union[jax.Array, Sequence[jax.Array]]
                     ) -> ShardedFittedKpca:
    """All-shard coefficient swap (see ``refresh_coefficients``)."""
    alpha = _pool_alpha(alpha, model.n_support)          # (L, C)
    c = alpha.shape[1]
    lp = model.shard_capacity
    rows, off = [], 0
    for n in model.shard_sizes:
        rows.append(jnp.pad(alpha[off:off + n], ((0, lp - n), (0, 0))))
        off += n
    coefs_pad = jnp.stack(rows)                          # (S, Lp, C)
    row_mean_coef, bias, centered = _require_sharded_cache(model, c)
    if centered:
        row_mean_coef, bias = _sharded_centering(model, coefs_pad)
    coefs_ext = jnp.concatenate(
        [coefs_pad, model.coefs_ext[..., -1:]], axis=-1)
    return dataclasses.replace(model, coefs_ext=coefs_ext,
                               row_mean_coef=row_mean_coef, bias=bias)


def refresh_shard_coefficients(model: ShardedFittedKpca, shard: int,
                               alpha: jax.Array) -> ShardedFittedKpca:
    """Swap ONE shard's dual-coefficient rows; all other shards keep
    theirs. The global centering terms are rebuilt from the per-shard
    cached kernel-mean slices — an O(S*Lp*C) update with no Gram contact —
    so the result is exactly ``refresh_coefficients`` with the other
    shards' current coefficients left in place. The returned model is a
    complete new artifact: publishing it through a ``ModelHandle`` is one
    atomic swap, so no request can observe a mix of shard versions.

    Args:
      model: uncompressed sharded artifact carrying its per-shard cache
        (or an uncentered one, whose centering terms stay zero).
      shard: shard index in [0, model.n_shards).
      alpha: (n_j,) or (n_j, C) new coefficients for that shard's TRUE
        rows, n_j = model.shard_sizes[shard]; C must match the model (the
        other shards' column count is fixed).

    Returns:
      A new ``ShardedFittedKpca`` (the input model is unchanged).
    """
    if not isinstance(model, ShardedFittedKpca):
        raise TypeError(f"refresh_shard_coefficients takes a "
                        f"ShardedFittedKpca, got {type(model).__name__}")
    if not 0 <= shard < model.n_shards:
        raise ValueError(
            f"shard {shard} not in [0, {model.n_shards})")
    n_j, c = model.shard_sizes[shard], model.n_components
    alpha = _as_2d(jnp.asarray(alpha)).astype(jnp.float32)
    if alpha.shape != (n_j, c):
        raise ValueError(
            f"shard {shard} takes ({n_j}, {c}) coefficients, "
            f"got {alpha.shape}")
    rows = jnp.pad(alpha, ((0, model.shard_capacity - n_j), (0, 0)))
    coefs_ext = model.coefs_ext.at[shard, :, :c].set(rows)
    row_mean_coef, bias, centered = _require_sharded_cache(model, c)
    if centered:
        row_mean_coef, bias = _sharded_centering(model, coefs_ext[..., :c])
    return dataclasses.replace(model, coefs_ext=coefs_ext,
                               row_mean_coef=row_mean_coef, bias=bias)


def drop_shard(model: ShardedFittedKpca, shard: int) -> ShardedFittedKpca:
    """Shard-loss re-balance: serve on without the lost shard's rows.

    Keeps the shard axis at S — a ``ModelHandle`` pins ``n_shards`` (and
    the engine's mesh matches it), so recovery must not re-shard; instead
    the lost shard becomes an empty participant: its support rows,
    coefficient rows AND indicator column are zeroed, so its psum
    contribution is exactly zero (``K @ 0``), and ``shard_sizes[shard]``
    drops to 0. The global centering epilogue is rebuilt for the
    SURVIVOR support set — ``n_support`` shrinks and, when the model
    carries its per-shard kernel-mean cache, (row_mean_coef, bias) are
    recomputed from the surviving shards' cached sums
    (``_sharded_centering`` with the lost shard's slices zeroed). The
    result equals ``shard_fitted`` of a fresh fit on the survivor
    support set up to the zero padding — pinned by
    tests/test_fault_injection.py against ``gather_fitted`` + central
    ``project``.

    Models without the cache (landmark-compressed, or uncentered) keep
    their existing centering constants: for uncentered fits they are
    zero anyway; for compressed fits the folded row-mean/bias terms are
    per-row and the lost rows are simply gone — a documented
    approximation (docs/FAULT_TOLERANCE.md), not an error, because
    recovery must not refuse to serve.

    Idempotent: dropping an already-empty shard returns the model
    unchanged, which is what makes the re-balance publish exactly-once
    under concurrent retries (``repro.faults.serving.ShardRebalancer``).
    """
    if not isinstance(model, ShardedFittedKpca):
        raise TypeError(
            f"drop_shard takes a ShardedFittedKpca, got "
            f"{type(model).__name__}")
    if not 0 <= shard < model.n_shards:
        raise ValueError(f"shard {shard} not in [0, {model.n_shards})")
    if model.shard_sizes[shard] == 0:
        return model
    sizes = tuple(0 if j == shard else n
                  for j, n in enumerate(model.shard_sizes))
    n_support = int(sum(sizes))
    if n_support == 0:
        raise ValueError("cannot drop the last non-empty shard")
    c = model.n_components
    x_support = model.x_support.at[shard].set(0.0)
    coefs_ext = model.coefs_ext.at[shard].set(0.0)
    k_row_mean = model.k_row_mean
    row_mean_coef, bias = model.row_mean_coef, model.bias
    if k_row_mean is not None:
        k_row_mean = k_row_mean.at[shard].set(0.0)
        survivor = dataclasses.replace(model, k_row_mean=k_row_mean)
        row_mean_coef, bias = _sharded_centering(survivor,
                                                 coefs_ext[..., :c])
    return dataclasses.replace(
        model, x_support=x_support, coefs_ext=coefs_ext,
        row_mean_coef=row_mean_coef, bias=bias, n_support=n_support,
        shard_sizes=sizes, k_row_mean=k_row_mean)


def gather_fitted(sharded: ShardedFittedKpca) -> FittedKpca:
    """Reassemble a single-device ``FittedKpca`` from a sharded model.

    Drops per-shard padding rows and concatenates the true support slices
    and coefficient rows; the gathered model's ``project`` output is
    bit-identical in exact arithmetic to the psum-reduced sharded scores
    (tested to fp32 tolerance in tests/test_sharded_serving.py).
    """
    xs = jnp.concatenate(
        [sharded.x_support[j, :n]
         for j, n in enumerate(sharded.shard_sizes)], axis=0)
    coefs = jnp.concatenate(
        [sharded.coefs_ext[j, :n, :-1]
         for j, n in enumerate(sharded.shard_sizes)], axis=0)
    stats = {}
    if sharded.k_row_mean is not None:
        stats = dict(
            k_row_mean=jnp.concatenate(
                [sharded.k_row_mean[j, :n]
                 for j, n in enumerate(sharded.shard_sizes)]),
            k_grand_mean=sharded.k_grand_mean)
    return FittedKpca(x_support=xs, coefs=coefs,
                      row_mean_coef=sharded.row_mean_coef, bias=sharded.bias,
                      gamma=sharded.gamma, spec=sharded.spec, **stats)


# ---- persistence (repro.checkpoint layout) --------------------------------

def save_fitted(ckpt_dir: str, model: FittedKpca) -> str:
    """Write the artifact with the atomic checkpoint writer (step 0).

    Layout: one ``step_00000000`` directory under ``ckpt_dir`` with a
    manifest (shapes/dtypes + ``kind``/``spec`` metadata) and one .npy per
    field — see ``repro.checkpoint``. Returns the checkpoint path.
    """
    from ..checkpoint import save_checkpoint
    tree = {"x_support": model.x_support, "coefs": model.coefs,
            "row_mean_coef": model.row_mean_coef, "bias": model.bias,
            "gamma": model.gamma}
    if model.k_row_mean is not None:
        tree["k_row_mean"] = model.k_row_mean
        tree["k_grand_mean"] = model.k_grand_mean
    meta = {"kind": "fitted_kpca", "spec": dataclasses.asdict(model.spec)}
    return save_checkpoint(ckpt_dir, 0, tree, metadata=meta, keep_last=1)


def load_fitted(ckpt_dir: str) -> FittedKpca:
    """Restore a ``save_fitted`` checkpoint; validates the artifact kind."""
    from ..checkpoint import restore_checkpoint
    tree, meta, _ = restore_checkpoint(ckpt_dir)
    if meta.get("kind") != "fitted_kpca":
        raise ValueError(f"{ckpt_dir} is not a FittedKpca checkpoint: {meta}")
    spec = KernelSpec(**meta["spec"])
    return FittedKpca(x_support=tree["x_support"], coefs=tree["coefs"],
                      row_mean_coef=tree["row_mean_coef"],
                      bias=tree["bias"], gamma=tree["gamma"],
                      k_row_mean=tree.get("k_row_mean"),
                      k_grand_mean=tree.get("k_grand_mean"), spec=spec)


def save_sharded(ckpt_dir: str, model: ShardedFittedKpca) -> str:
    """Write a sharded artifact (same atomic layout as ``save_fitted``;
    static partition metadata rides in the manifest). Returns the path."""
    from ..checkpoint import save_checkpoint
    tree = {"x_support": model.x_support, "coefs_ext": model.coefs_ext,
            "row_mean_coef": model.row_mean_coef, "bias": model.bias,
            "gamma": model.gamma}
    if model.k_row_mean is not None:
        tree["k_row_mean"] = model.k_row_mean
        tree["k_grand_mean"] = model.k_grand_mean
    meta = {"kind": "sharded_fitted_kpca",
            "spec": dataclasses.asdict(model.spec),
            "n_support": model.n_support,
            "shard_sizes": list(model.shard_sizes)}
    return save_checkpoint(ckpt_dir, 0, tree, metadata=meta, keep_last=1)


def load_sharded(ckpt_dir: str) -> ShardedFittedKpca:
    """Restore a ``save_sharded`` checkpoint; validates the artifact kind.

    The restored model is mesh-independent (full logical arrays); re-placing
    it on a device mesh is the serving path's job (``repro.serve.sharded``).
    """
    from ..checkpoint import restore_checkpoint
    tree, meta, _ = restore_checkpoint(ckpt_dir)
    if meta.get("kind") != "sharded_fitted_kpca":
        raise ValueError(
            f"{ckpt_dir} is not a ShardedFittedKpca checkpoint: {meta}")
    return ShardedFittedKpca(
        x_support=tree["x_support"], coefs_ext=tree["coefs_ext"],
        row_mean_coef=tree["row_mean_coef"], bias=tree["bias"],
        gamma=tree["gamma"], n_support=int(meta["n_support"]),
        shard_sizes=tuple(int(s) for s in meta["shard_sizes"]),
        k_row_mean=tree.get("k_row_mean"),
        k_grand_mean=tree.get("k_grand_mean"),
        spec=KernelSpec(**meta["spec"]))


__all__ = [
    "FittedKpca", "ShardedFittedKpca", "compress", "drop_shard",
    "effective_coefs", "finalize_partial_scores", "fit_central", "from_dual",
    "from_decentralized", "gather_fitted", "landmark_schedule", "load_fitted",
    "load_sharded", "project", "refresh_coefficients",
    "refresh_shard_coefficients", "save_fitted", "save_sharded",
    "shard_fitted",
]
