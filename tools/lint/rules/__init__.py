"""Rule modules register themselves on import (``@register``)."""

from . import concurrency, jaxrules  # noqa: F401

__all__ = ["concurrency", "jaxrules"]
