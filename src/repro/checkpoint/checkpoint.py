"""Checkpointing: atomic, keep-last-k, async, elastic (mesh-independent).

Layout (one directory per step):
    ckpt_dir/step_000042/manifest.json      tree structure + shapes/dtypes
    ckpt_dir/step_000042/<escaped-key>.npy  one file per leaf

Leaves are saved as FULL logical arrays (gathered), so a checkpoint written
on one mesh restores onto any other mesh/sharding ("elastic scaling") — at
1000-node scale the same layout shards the .npy files per host; the manifest
format already carries everything needed.

Writes are atomic: a temp dir is renamed into place only after fsync, so a
killed job never sees a torn checkpoint (tests/test_checkpoint.py simulates
mid-write failure)."""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SAFE = {"/": "__", ".": "_d_"}


def _escape(key: str) -> str:
    for a, b in _SAFE.items():
        key = key.replace(a, b)
    return key


def _unescape(key: str) -> str:
    for a, b in _SAFE.items():
        key = key.replace(b, a)
    return key


def save_checkpoint(ckpt_dir: str, step: int, tree: Dict[str, Any],
                    metadata: Optional[dict] = None, keep_last: int = 3):
    """tree: flat dict path -> array (nested pytrees: flatten first)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, val in tree.items():
        arr = np.asarray(jax.device_get(val))
        fname = _escape(key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _cleanup(ckpt_dir, keep_last)
    return final


def save_checkpoint_async(ckpt_dir: str, step: int, tree, metadata=None,
                          keep_last: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write in a background thread
    (training continues while the disk write proceeds)."""
    snapshot = {k: np.asarray(jax.device_get(v)) for k, v in tree.items()}
    t = threading.Thread(
        target=save_checkpoint,
        args=(ckpt_dir, step, snapshot),
        kwargs={"metadata": metadata, "keep_last": keep_last}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Optional[Dict[str, Any]] = None):
    """Returns (tree, metadata). With ``shardings`` (path -> NamedSharding),
    leaves are placed onto the target mesh — which may differ from the mesh
    that wrote the checkpoint (elastic restore)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    tree = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(d, info["file"]))
        if shardings and key in shardings:
            tree[key] = jax.device_put(arr, shardings[key])
        else:
            tree[key] = jax.numpy.asarray(arr)
    return tree, manifest["metadata"], step


def _cleanup(ckpt_dir: str, keep_last: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
