"""Ablations from docs/ADMM_CONVERGENCE.md on the m=24 fixture.

Runs the three planned ablations (rho2 schedule variants, Theorem-2 rho,
z warm-start via local-solution alpha init) and prints mean node-vs-central
similarity at the 30-iteration test budget plus trajectory milestones.

    PYTHONPATH=src python scripts/ablate_admm.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (KernelSpec, RhoSchedule, build_setup, central_kpca,
                        local_kpca, run_admm, similarity, theorem2_rho)
from repro.core.topology import ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf", gamma=None)


def mean_sim(alpha_nodes, nodes, pooled, alpha_gt, gamma):
    sims = [float(similarity(alpha_nodes[j], jnp.asarray(nodes[j]),
                             alpha_gt, jnp.asarray(pooled), SPEC, gamma=gamma))
            for j in range(nodes.shape[0])]
    return float(np.mean(sims))


def main():
    nodes, pooled = node_dataset(n_nodes=8, n_per_node=60, m=24, seed=0)
    graph = ring(8, hops=2)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1,
                                  gamma=setup.gamma)
    alpha_gt = alpha_gt[:, 0]
    rho_t2 = theorem2_rho(setup)
    loc = local_kpca(jnp.asarray(nodes), SPEC, gamma=setup.gamma)
    sim_local = mean_sim(loc[..., 0], nodes, pooled, alpha_gt, setup.gamma)
    print(f"theorem2_rho = {rho_t2:.1f}; local baseline = {sim_local:.3f}")

    schedules = {
        "paper-warmup(10,50,100@0/10/20)": RhoSchedule(),
        "constant-100": RhoSchedule.constant(100.0),
        "constant-50": RhoSchedule.constant(50.0),
        "long-warmup(10,50,100@0/20/40)": RhoSchedule((0, 20, 40),
                                                      (10.0, 50.0, 100.0)),
        f"theorem2({rho_t2:.0f})": RhoSchedule.constant(rho_t2),
    }
    milestones = (5, 10, 20, 30, 50, 60)
    print("setting | " + " | ".join(f"sim@{t}" for t in milestones))
    for init in ("paper", "local"):
        for name, sched in schedules.items():
            res = run_admm(setup, n_iters=60, rho2=sched, init=init)
            row = [mean_sim(np.asarray(res.alpha_hist)[t - 1], nodes, pooled,
                            alpha_gt, setup.gamma) for t in milestones]
            print(f"init={init:5s} {name:32s} | "
                  + " | ".join(f"{s:.3f}" for s in row), flush=True)


if __name__ == "__main__":
    main()
