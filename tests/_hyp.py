"""Optional-``hypothesis`` shim for the test suite.

The property-based tests use hypothesis when it is installed (see
requirements-dev.txt); without it, only the ``@given`` tests are skipped —
the rest of each module still runs. Import from here instead of hypothesis:

    from _hyp import given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _AnyStrategy:
        """Stands in for ``strategies.*`` calls made at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
