"""Subprocess helper (8 host devices): data-parallel sharded train step must
match the single-device step bit-for-bit-ish, the sharded MoE layer must
match the dense reference, and compressed gradient psum must approximate the
dense psum."""

import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.data.tokens import TokenStream  # noqa: E402
from repro.distributed.sharding import default_rules  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.train.loop import build_train_step  # noqa: E402


def check_dp_equivalence():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                     head_dim=16, tie_embeddings=True, remat="none",
                     param_dtype="float32", compute_dtype="float32")
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = default_rules(multi_pod=False)
    data = TokenStream(vocab=cfg.vocab, batch=8, seq=16, seed=0)
    batch = data.next_batch()

    # single-device
    model1 = build_model(cfg)
    init1, step1 = build_train_step(model1, AdamWConfig(lr=1e-2))
    s1, _ = init1(jax.random.PRNGKey(0))
    s1n, m1 = step1(s1, batch)

    # sharded
    model2 = build_model(cfg, mesh=mesh)
    init2, step2 = build_train_step(model2, AdamWConfig(lr=1e-2), mesh=mesh,
                                    rules=rules)
    s2, _ = init2(jax.random.PRNGKey(0))
    sharded_batch = {
        k: jax.device_put(v, NamedSharding(mesh, P("data", None)))
        for k, v in batch.items()}
    s2n, m2 = step2(s2, sharded_batch)

    d_loss = abs(float(m1["loss"]) - float(m2["loss"]))
    assert d_loss < 1e-4, d_loss
    for k in s1n["params"]:
        a = np.asarray(s1n["params"][k])
        b = np.asarray(s2n["params"][k])
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4, err_msg=k)
    print("DP-EQUIV-OK")


def check_moe_sharded_vs_ref():
    from repro.models.moe import (init_moe, moe_forward, moe_forward_ref)
    from repro.models.common import ParamCollector
    cfg = ArchConfig(name="m", family="moe", n_layers=1, d_model=32,
                     n_heads=2, n_kv_heads=1, d_ff=64, vocab=64,
                     head_dim=16, n_experts=8, top_k=2, d_ff_expert=32,
                     param_dtype="float32", compute_dtype="float32")
    mesh = make_mesh((2, 4), ("data", "model"))
    col = ParamCollector(jax.random.PRNGKey(0), jnp.float32)
    init_moe(col, cfg, "moe")
    p = {k[len("moe/"):]: v for k, v in col.params.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
    y_ref, aux_ref = moe_forward_ref(p, cfg, x)
    y_sh, aux_sh = moe_forward(p, cfg, x, mesh)
    np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-4)
    print("MOE-OK")


def check_compressed_psum():
    from repro.optim import compressed_psum_grads, init_compression_state
    mesh = make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    # per-shard gradients: shared low-rank signal + per-worker noise
    u = rng.normal(size=(8, 16, 3)).astype(np.float32)
    v = rng.normal(size=(12, 3)).astype(np.float32)
    g_shards = jnp.asarray(np.einsum("wmr,nr->wmn", u, v))
    params = {"w": jnp.zeros((16, 12))}
    state = init_compression_state(params, rank=3)

    def body(g_loc, p_prev, err):
        st = {"w": {"p": p_prev, "err": err}}
        out, new_state = compressed_psum_grads({"w": g_loc}, st, mesh)
        return out["w"], new_state["w"]["p"], new_state["w"]["err"]

    from repro.distributed.compat import shard_map
    f = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None, None), P(None, None), P(None, None)),
        out_specs=(P(None, None), P(None, None), P(None, None)),
        check_vma=False))
    p_prev = jnp.asarray(state["w"]["p"])
    err = jnp.zeros((16, 12))
    approx = None
    for _ in range(4):   # a few rounds align the consensus subspace
        approx, p_prev, err = f(g_shards, p_prev, err)
    dense = np.asarray(jnp.mean(g_shards, axis=0))
    rel = np.linalg.norm(np.asarray(approx) - dense) / np.linalg.norm(dense)
    assert rel < 0.05, rel
    print("COMPRESS-OK")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dp"):
        check_dp_equivalence()
    if which in ("all", "moe"):
        check_moe_sharded_vs_ref()
    if which in ("all", "compress"):
        check_compressed_psum()
    print("OK")
