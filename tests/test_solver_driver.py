"""Tests for the shared solver layer (repro.core.solver): the chunked
resumable driver, mid-run checkpoint/restore parity on BOTH transports
(reference simulator and the in-process 4-device SPMD path), rho policy
plumbing, and residual-based early stopping."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, RhoSchedule, build_setup, run_admm, solver
from repro.core.topology import ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf", gamma=None)


@pytest.fixture(scope="module")
def setup():
    nodes, _ = node_dataset(n_nodes=8, n_per_node=16, m=12, seed=0)
    return build_setup(jnp.asarray(nodes), ring(8, hops=2), SPEC)


def _drain(it):
    out = list(it)
    assert out, "driver yielded no chunks"
    return out


class TestChunkedDriver:
    def test_matches_whole_history_run(self, setup):
        """Chunked scan == one whole-history scan, bit-for-bit: same step,
        same rho sequence, only the jit boundaries differ."""
        ref = run_admm(setup, n_iters=30, seed=3)
        chunks = _drain(solver.run_chunked(setup, n_iters=30, chunk=7,
                                           seed=3))
        alpha_hist = np.concatenate([np.asarray(c.alpha_hist)
                                     for c in chunks])
        res_hist = np.concatenate([np.asarray(c.primal_residual)
                                   for c in chunks])
        lag_hist = np.concatenate([np.asarray(c.lagrangian) for c in chunks])
        assert alpha_hist.shape == np.asarray(ref.alpha_hist).shape
        np.testing.assert_allclose(alpha_hist, np.asarray(ref.alpha_hist),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(res_hist,
                                   np.asarray(ref.primal_residual),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(lag_hist, np.asarray(ref.lagrangian),
                                   rtol=1e-5, atol=1e-3)
        assert int(chunks[-1].state.t) == 30

    def test_checkpoint_restore_continue_parity(self, setup, tmp_path):
        """Save AdmmState at t=10, restore, continue to 30 — numerically
        identical to the uninterrupted 30-iteration run."""
        ck = str(tmp_path / "admm")
        first = _drain(solver.run_chunked(setup, n_iters=10, chunk=5,
                                          seed=1, ckpt_dir=ck))
        assert first[-1].ckpt_path is not None
        restored = solver.load_state(ck)
        assert int(restored.t) == 10
        rest = _drain(solver.run_chunked(setup, n_iters=30, chunk=10,
                                         state=restored, seed=1))
        full = _drain(solver.run_chunked(setup, n_iters=30, chunk=30,
                                         seed=1))
        np.testing.assert_allclose(np.asarray(rest[-1].state.alpha),
                                   np.asarray(full[-1].state.alpha),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rest[-1].state.b),
                                   np.asarray(full[-1].state.b),
                                   rtol=1e-6, atol=1e-5)

    def test_load_state_rejects_other_kinds(self, setup, tmp_path):
        from repro.core import oos
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 4)).astype(np.float32))
        oos.save_fitted(str(tmp_path / "f"), oos.fit_central(x, SPEC))
        with pytest.raises(ValueError):
            solver.load_state(str(tmp_path / "f"))

    def test_rho_policy_switch_at_chunk_boundary(self, setup):
        """Warm up on the paper schedule, then switch to the Theorem-2
        constant mid-run: the state (z warm-start) carries across and the
        run keeps converging."""
        warm = _drain(solver.run_chunked(setup, n_iters=10, chunk=5))
        cont = _drain(solver.run_chunked(setup, n_iters=30, chunk=10,
                                         rho2="theorem2",
                                         state=warm[-1].state))
        r_before = float(warm[-1].primal_residual[-1])
        r_after = float(cont[-1].primal_residual[-1])
        assert np.isfinite(r_after) and r_after < r_before
        rho = float(cont[-1].rho_hist[0])
        assert rho > 0 and rho != 100.0   # actually switched policy

    def test_early_stop_on_residual(self, setup):
        chunks = _drain(solver.run_chunked(setup, n_iters=200, chunk=5,
                                           rho2=RhoSchedule.constant(100.0),
                                           tol=1e-2))
        assert chunks[-1].stopped
        assert int(chunks[-1].state.t) < 200
        assert float(chunks[-1].primal_residual[-1]) < 1e-2

    def test_rejects_degenerate_knobs(self, setup):
        with pytest.raises(ValueError):
            next(solver.run_chunked(setup, n_iters=4, chunk=0))
        with pytest.raises(ValueError):
            next(solver.run_chunked(setup, n_iters=4, chunk=2,
                                    ckpt_every=0))

    def test_callable_rho_policy(self, setup):
        chunks = _drain(solver.run_chunked(
            setup, n_iters=6, chunk=3, rho2=lambda t: 50.0 + t))
        np.testing.assert_allclose(np.asarray(chunks[0].rho_hist),
                                   [50.0, 51.0, 52.0])
        np.testing.assert_allclose(np.asarray(chunks[1].rho_hist),
                                   [53.0, 54.0, 55.0])


class TestSharedStepDense:
    def test_admm_iteration_wrapper_unchanged(self, setup):
        """The public admm_iteration API (used by the Pallas admm_step
        kernel tests) still runs the shared step over the dense comm."""
        from repro.core import admm_iteration
        rng = np.random.default_rng(0)
        alpha = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(8, 16, 5)).astype(np.float32))
        a1, b1, g, zn = admm_iteration(setup, alpha, b, 100.0, 10.0)
        assert a1.shape == alpha.shape and b1.shape == b.shape
        assert g.shape == b.shape and zn.shape == (8,)
        assert np.isfinite(np.asarray(a1)).all()


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs 4 host devices (tests/conftest.py sets "
                           "XLA_FLAGS; a caller overriding it loses them)")
class TestSpmdResume:
    """SPMD path: interrupt/resume parity on a REAL 4-device host mesh
    (tests/conftest.py exposes 4 CPU devices)."""

    @pytest.fixture(scope="class")
    def spmd_fixture(self):
        from repro.launch.mesh import make_mesh
        nodes, _ = node_dataset(4, 12, 8, seed=0)
        mesh = make_mesh((4,), ("data",))
        alpha0 = jax.random.normal(jax.random.PRNGKey(0), (4, 12),
                                   jnp.float32)
        return nodes, mesh, alpha0

    def test_interrupted_run_matches_uninterrupted(self, spmd_fixture):
        from repro.core.dkpca import dkpca_distributed
        nodes, mesh, alpha0 = spmd_fixture
        kw = dict(axis_names=("data",), hops=1, spec=SPEC, center="global")
        full = dkpca_distributed(nodes, mesh, n_iters=14, alpha0=alpha0,
                                 **kw)
        part1 = dkpca_distributed(nodes, mesh, n_iters=6, alpha0=alpha0,
                                  **kw)
        # round-trip the restart state through a checkpoint, like a real
        # preemption would
        st = solver.AdmmState(
            alpha=part1.alpha, b=part1.b,
            g=jnp.zeros_like(part1.b),
            znorm2=jnp.zeros((part1.alpha.shape[0],), jnp.float32),
            t=jnp.asarray(6, jnp.int32),
            rho=jnp.zeros(part1.b.shape[:1] + part1.b.shape[2:],
                          jnp.float32))
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            solver.save_state(d, st)
            back = solver.load_state(d)
        part2 = dkpca_distributed(nodes, mesh, n_iters=8,
                                  alpha0=back.alpha, b0=back.b,
                                  t0=int(back.t), **kw)
        a_full = np.asarray(full.alpha)
        a_resumed = np.asarray(part2.alpha)
        scale = max(np.abs(a_full).max(), 1e-6)
        assert np.abs(a_full - a_resumed).max() < 1e-5 * scale + 1e-6
        # histories line up too (t0 only offsets the rho schedule)
        np.testing.assert_allclose(
            np.asarray(part2.alpha_hist)[-1], np.asarray(full.alpha_hist)[-1],
            rtol=1e-5, atol=1e-5)

    def test_spmd_default_init_is_local_warm_start(self, spmd_fixture):
        """dkpca_distributed's default init matches run_admm's: the local
        z warm-start, computed per-node inside the SPMD program."""
        from repro.core.dkpca import dkpca_distributed
        nodes, mesh, _ = spmd_fixture
        setup4 = build_setup(jnp.asarray(nodes), ring(4, hops=1), SPEC)
        sim = run_admm(setup4, n_iters=6)            # default init="local"
        dist = dkpca_distributed(nodes, mesh, axis_names=("data",), hops=1,
                                 spec=SPEC, center="global", n_iters=6)
        a_s, a_d = np.asarray(sim.alpha), np.asarray(dist.alpha)
        scale = max(np.abs(a_s).max(), 1e-6)
        assert np.abs(a_s - a_d).max() < 5e-3 * scale + 1e-4

    def test_spmd_matches_reference_through_shared_step(self, spmd_fixture):
        """In-process (subprocess-free) parity: the SPMD transport and the
        dense transport run the same admm_step."""
        from repro.core.dkpca import dkpca_distributed
        nodes, mesh, alpha0 = spmd_fixture
        setup4 = build_setup(jnp.asarray(nodes), ring(4, hops=1), SPEC)
        sim = run_admm(setup4, n_iters=8, alpha0=alpha0)
        dist = dkpca_distributed(nodes, mesh, axis_names=("data",), hops=1,
                                 spec=SPEC, center="global", n_iters=8,
                                 alpha0=alpha0)
        a_s, a_d = np.asarray(sim.alpha), np.asarray(dist.alpha)
        scale = max(np.abs(a_s).max(), 1e-6)
        assert np.abs(a_s - a_d).max() < 5e-3 * scale + 1e-4


class TestStatePytree:
    def test_state_is_a_jit_friendly_pytree(self, setup):
        st = solver.init_state(jnp.ones((8, 16)), setup.n_slots)
        leaves = jax.tree_util.tree_leaves(st)
        assert len(leaves) == 6
        st2 = jax.jit(lambda s: dataclasses.replace(s, t=s.t + 1))(st)
        assert int(st2.t) == 1
