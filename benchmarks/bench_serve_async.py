"""Async serving benchmark: futures pipeline under concurrent submitters.

Measures what the synchronous serve bench cannot: end-to-end request
latency (submit -> future resolved, queue wait included) and wall-clock
throughput when several client threads race one background flusher —
with and without admission control. The sync ``project_many`` row on the
same request mix is the baseline; the async rows show what the
size-or-deadline trigger costs in latency and buys in batching.

Rows follow the harness convention (name, us_per_call, derived).
"""

from __future__ import annotations

import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, oos
from repro.data import kpca_dataset
from repro.serve import KpcaEngine, KpcaServeConfig, QueueFullError
from repro.serve.batching import format_latency

SPEC = KernelSpec(kind="rbf")


def _fit(n=512, m=128, c=2, seed=0):
    x = jnp.asarray(kpca_dataset(n, m=m, seed=seed))
    return oos.fit_central(x, SPEC, n_components=c, center=True)


def _request_mix(n_requests, m, max_q=32, seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(1, max_q + 1, size=n_requests)
    return [rng.normal(size=(int(q), m)).astype(np.float32) for q in sizes]


def _warm(eng, m):
    eng.warmup()                   # compile every pow2 bucket once
    eng.stats = type(eng.stats)()  # rows report steady-state compiles=0


def _drive_async(eng, reqs, n_threads):
    """Submit ``reqs`` round-robin from ``n_threads`` threads; returns
    (wall_s, e2e_latencies list, n_rejected)."""
    lat = [None] * len(reqs)
    rejected = [0] * n_threads

    def submitter(tid):
        for i in range(tid, len(reqs), n_threads):
            t0 = time.perf_counter()
            try:
                fut = eng.submit(reqs[i])
                fut.result(timeout=60.0)
            except QueueFullError:             # rejected at submit
                rejected[tid] += 1
                continue
            except Exception:                  # shed while queued
                continue
            lat[i] = time.perf_counter() - t0

    t0 = time.perf_counter()
    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return wall, [x for x in lat if x is not None], sum(rejected)


def bench_serve_async(m: int = 128):
    rows = []
    n_train, n_requests = 512, 192
    model = _fit(n=n_train, m=m)
    reqs = _request_mix(n_requests, m, seed=1)
    n_q = sum(r.shape[0] for r in reqs)

    # ---- sync baseline: same mix, one blocking project_many ---------------
    cfg = KpcaServeConfig(max_batch=128, min_bucket=8)
    eng = KpcaEngine(model, cfg)
    _warm(eng, m)
    t0 = time.perf_counter()
    eng.project_many(reqs)         # blocking; returns host numpy
    dt = time.perf_counter() - t0
    rows.append(("serve_async/sync_baseline", dt / n_requests * 1e6,
                 f"qps={n_q / dt:.0f};requests={n_requests};"
                 f"compiles={eng.stats.n_compiles}"))

    # ---- async futures pipeline vs submitter concurrency ------------------
    for n_threads in (1, 2, 4):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=128, min_bucket=8, flush_max_wait_s=0.002))
        _warm(eng, m)
        with eng:
            wall, lat, _ = _drive_async(eng, reqs, n_threads)
        p50 = float(np.percentile(lat, 50))
        p99 = float(np.percentile(lat, 99))
        rows.append((
            f"serve_async/threads{n_threads}", wall / n_requests * 1e6,
            f"qps={n_q / wall:.0f};e2e_p50={format_latency(p50)};"
            f"e2e_p99={format_latency(p99)};flushes={eng.stats.n_flushes};"
            f"compiles={eng.stats.n_compiles};"
            f"zero_copy={eng.stats.n_zero_copy_slabs}"))

    # ---- admission control: bounded queue under the same burst ------------
    for factor, policy in ((None, "off"), (2, "reject"), (2, "shed")):
        eng = KpcaEngine(model, KpcaServeConfig(
            max_batch=128, min_bucket=8, flush_max_wait_s=0.002,
            queue_factor=factor,
            admission=policy if factor else "reject"))
        _warm(eng, m)
        with eng:
            wall, lat, rejected = _drive_async(eng, reqs, 4)
        served = len(lat)
        p99 = float(np.percentile(lat, 99)) if lat else 0.0
        rows.append((
            f"serve_async/admission_{policy}", wall / n_requests * 1e6,
            f"served={served}/{n_requests};rejected={rejected};"
            f"shed={eng.stats.n_shed};e2e_p99={format_latency(p99)};"
            f"depth_bound={eng.cfg.queue_capacity()}"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_serve_async():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
