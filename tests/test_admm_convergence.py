"""End-to-end tests of paper Alg. 1: convergence (Theorem 2), quality vs.
the central solution (Figs 3-5 regime), and baseline orderings."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelSpec, RhoSchedule, build_setup, central_kpca,
                        local_kpca, run_admm, similarity, theorem2_rho)
from repro.core.topology import ring
from repro.data import node_dataset

SPEC = KernelSpec(kind="rbf", gamma=None)

# The fixture's m=24 / seed=0 regime converged ~3x slower than the paper's
# 30-iteration budget under the paper's Gaussian init (mean similarity 0.577
# @ 30 iters; transient dip to 0.40 during the rho2 warm-up). The measured
# fix — now run_admm's default — is the local-solution z warm-start
# (init="local"): similarity 0.991 after ONE iteration and >= 0.997 by 10
# under every rho schedule tried. Ablation tables and the closure note are
# in docs/ADMM_CONVERGENCE.md; test_paper_init_transient_is_characterized
# below keeps the old regime pinned.


@pytest.fixture(scope="module")
def small_problem():
    nodes, pooled = node_dataset(n_nodes=8, n_per_node=60, m=24, seed=0)
    graph = ring(8, hops=2)
    setup = build_setup(jnp.asarray(nodes), graph, SPEC)
    alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1,
                                  gamma=setup.gamma)
    return nodes, pooled, graph, setup, alpha_gt[:, 0]


def _mean_similarity(alpha_nodes, nodes, pooled, alpha_gt, gamma):
    sims = [
        float(similarity(alpha_nodes[j], jnp.asarray(nodes[j]),
                         alpha_gt, jnp.asarray(pooled), SPEC, gamma=gamma))
        for j in range(nodes.shape[0])
    ]
    return float(np.mean(sims)), sims


class TestConvergence:
    def test_similarity_to_central(self, small_problem):
        nodes, pooled, graph, setup, alpha_gt = small_problem
        res = run_admm(setup, n_iters=30)
        mean_sim, sims = _mean_similarity(res.alpha, nodes, pooled, alpha_gt,
                                          setup.gamma)
        # Paper Fig 3 reports > 0.9 similarity; small synthetic should match.
        assert mean_sim > 0.85, f"mean similarity too low: {mean_sim}, {sims}"

    def test_beats_local_baseline(self, small_problem):
        nodes, pooled, graph, setup, alpha_gt = small_problem
        res = run_admm(setup, n_iters=60)
        sim_admm, _ = _mean_similarity(res.alpha, nodes, pooled, alpha_gt,
                                       setup.gamma)
        loc = local_kpca(jnp.asarray(nodes), SPEC, gamma=setup.gamma)
        sim_local, _ = _mean_similarity(loc[..., 0], nodes, pooled, alpha_gt,
                                        setup.gamma)
        # Fig 4: consensus must improve over purely-local solutions.
        assert sim_admm > sim_local - 1e-3, (sim_admm, sim_local)

    def test_similarity_improves_over_iterations(self, small_problem):
        nodes, pooled, graph, setup, alpha_gt = small_problem
        res = run_admm(setup, n_iters=30)
        early, _ = _mean_similarity(res.alpha_hist[0], nodes, pooled,
                                    alpha_gt, setup.gamma)
        late, _ = _mean_similarity(res.alpha_hist[-1], nodes, pooled,
                                   alpha_gt, setup.gamma)
        assert late > early

    def test_primal_residual_decreases(self, small_problem):
        _, _, _, setup, _ = small_problem
        res = run_admm(setup, n_iters=40,
                       rho2=RhoSchedule.constant(100.0))
        r = np.asarray(res.primal_residual)
        assert r[-1] < r[0] * 0.5


class TestTheorem2:
    def test_lagrangian_monotone_decrease(self, small_problem):
        """Theorem 2: with Assumption-2 rho (and the exact Alg. 1 form,
        include_self=False), the augmented Lagrangian decreases.

        Reproduction note (see EXPERIMENTS.md §Paper-validation): the paper's
        Lemma-4 step bounds ||d_eta||_F by ||d_eta E^T||_F, which can fail
        under column cancellation far from consensus — and we indeed measure
        a small transient increase in the first few iterations (<0.3% of
        |L_0|), after which the decrease is strictly monotone. We assert the
        *asymptotic* monotonicity (t >= 5) plus a bounded early transient.
        """
        nodes, _, graph, _, _ = small_problem
        setup = build_setup(jnp.asarray(nodes), graph, SPEC,
                            include_self=False)
        rho = theorem2_rho(setup)
        assert rho > 0
        res = run_admm(setup, n_iters=40, rho2=RhoSchedule.constant(rho))
        lag = np.asarray(res.lagrangian, np.float64)
        diffs = np.diff(lag)
        tol = 1e-4 * max(1.0, np.abs(lag).max())
        assert (diffs[5:] <= tol).all(), f"Lagrangian increased late: {diffs}"
        assert diffs.max() <= 1e-2 * abs(lag[0]), "early transient too large"
        assert lag[-1] < lag[0] - 0.5 * (lag[0] - lag.min())  # overall drop

    def test_small_rho_violates_monotonicity(self, small_problem):
        """Sanity: the monotonicity *check* is not vacuous — with a tiny rho
        the alpha-problem Hessian loses positive-definiteness and the
        iteration diverges (non-monotone Lagrangian and/or blow-up)."""
        nodes, _, graph, _, _ = small_problem
        setup = build_setup(jnp.asarray(nodes), graph, SPEC,
                            include_self=False)
        res = run_admm(setup, n_iters=25, rho2=RhoSchedule.constant(1e-3))
        lag = np.asarray(res.lagrangian, np.float64)
        monotone = np.isfinite(lag).all() and (np.diff(lag) <= 1e-6).all()
        assert not monotone


class TestPaperMode:
    def test_rho_schedule_mode_converges(self, small_problem):
        """Paper §6.1 tuning: rho1=100 fixed, rho2 warm-up 10->50->100."""
        nodes, pooled, graph, setup, alpha_gt = small_problem
        res = run_admm(setup, n_iters=30, rho1=100.0,
                       rho2=RhoSchedule((0, 10, 20), (10.0, 50.0, 100.0)))
        mean_sim, _ = _mean_similarity(res.alpha, nodes, pooled, alpha_gt,
                                       setup.gamma)
        assert mean_sim > 0.85

    def test_paper_init_transient_is_characterized(self, small_problem):
        """Regression pin for the closed m=24 investigation
        (docs/ADMM_CONVERGENCE.md): under the paper's Gaussian init the
        transient still outlasts the 30-iteration budget (0.58 @ 30) but
        the fixed point is right (0.996 @ 100). If this ever flips, the
        doc's characterization is stale."""
        nodes, pooled, graph, setup, alpha_gt = small_problem
        res = run_admm(setup, n_iters=100, init="paper")
        at30, _ = _mean_similarity(res.alpha_hist[29], nodes, pooled,
                                   alpha_gt, setup.gamma)
        at100, _ = _mean_similarity(res.alpha_hist[-1], nodes, pooled,
                                    alpha_gt, setup.gamma)
        assert at30 < 0.85, at30        # the transient is real
        assert at100 > 0.95, at100      # ... and it is only a transient

    def test_more_neighbors_not_worse(self):
        """Fig 5 trend: larger |Omega| should not hurt final similarity."""
        nodes, pooled = node_dataset(n_nodes=10, n_per_node=20, m=16, seed=1)
        sims = []
        for hops in (1, 2):
            graph = ring(10, hops=hops)
            setup = build_setup(jnp.asarray(nodes), graph, SPEC)
            alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), SPEC, 1,
                                          gamma=setup.gamma)
            res = run_admm(setup, n_iters=30)
            s, _ = _mean_similarity(res.alpha, nodes, pooled,
                                    alpha_gt[:, 0], setup.gamma)
            sims.append(s)
        assert sims[1] > sims[0] - 0.05, sims
