"""seamless-m4t-large-v2 [audio] 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf]. The speech
frontend is a STUB: input_specs() provides precomputed frame embeddings."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2", family="audio", n_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
        head_dim=64, is_encdec=True, n_enc_layers=24, enc_seq=4096,
        frontend="audio_stub", rope_theta=10000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2-smoke", family="audio", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        head_dim=16, is_encdec=True, n_enc_layers=2, enc_seq=16,
        frontend="audio_stub", rope_theta=10000.0, remat="none")
