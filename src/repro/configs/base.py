"""Unified architecture configuration.

One dataclass covers all 10 assigned families (dense / MoE / MLA / VLM /
audio enc-dec / hybrid / SSM). Each ``src/repro/configs/<id>.py`` exports
``config()`` (the exact published configuration) and ``smoke_config()``
(a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # misc transformer knobs
    act: str = "silu"
    norm_eps: float = 1e-5
    qk_norm: bool = False
    rope_theta: float = 500000.0
    tie_embeddings: bool = False

    # attention
    attn_kind: str = "full"          # full | swa | mla | none
    window: int = 4096               # SWA window
    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0           # deepseek: first layer(s) dense
    capacity_factor: float = 1.25
    d_ff_dense: int = 0              # dense-layer ffn width when mixed

    # SSM (mamba)
    mamba_version: int = 0           # 0 = none, 1, 2
    ssm_state: int = 0
    d_inner: int = 0
    d_conv: int = 4
    dt_rank: int = 0                 # mamba1
    ssm_head_dim: int = 64           # mamba2
    ssm_chunk: int = 64

    # hybrid (zamba2): shared attention block applied every N mamba blocks
    attn_every: int = 0

    # encoder-decoder (seamless)
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 4096              # stub frame-embedding length

    # modality frontend stub (vlm / audio): prefix of precomputed embeddings
    frontend: str = "none"           # none | vision_stub | audio_stub
    frontend_seq: int = 0            # prefix length within the text sequence

    # implementation knobs (perf-tunable; see EXPERIMENTS.md §Perf)
    attention_impl: str = "einsum"   # einsum | chunked
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    # SWA banding (§Perf hillclimb): chunked attention skips (q, kv) chunk
    # pairs entirely outside the sliding window instead of masking them
    swa_banded: bool = False
    # sequence-parallel attention (§Perf hillclimb): shard the query seq dim
    # over "model" inside attention — the TP fallback when head counts don't
    # divide the model axis (llama3.2/phi4: 24 heads on a 16-way axis would
    # otherwise replicate all attention compute)
    attn_seq_shard: bool = False
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    # cost-measurement mode: fully unroll every internal lax.scan so the XLA
    # cost model (which counts while-loop bodies once) sees all iterations.
    # Only used by reduced-size dry-run cost variants — never at full scale.
    unroll_scans: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # sharding overrides merged into distributed.sharding.default_rules
    sharding_overrides: Tuple[Tuple[str, Optional[str]], ...] = ()

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.mamba_version > 0 and self.attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?"""
        return self.mamba_version > 0 or self.attn_kind == "swa"

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND roofline math)."""
        e, l = self.d_model, self.n_layers
        emb = self.vocab * e * (1 if self.tie_embeddings else 2)
        if self.mamba_version == 1 and self.attn_every == 0:
            # pure mamba1 stack (falcon-mamba)
            per = (e * 2 * self.d_inner + self.d_inner * self.d_conv
                   + self.d_inner * (self.dt_rank + 2 * self.ssm_state)
                   + self.dt_rank * self.d_inner
                   + self.d_inner * self.ssm_state + self.d_inner
                   + self.d_inner * e)
            return emb + l * per
        if self.mamba_version == 2 and self.attn_every > 0:
            # hybrid (zamba2): mamba2 blocks + ONE shared attn+mlp block
            n_h = self.d_inner // self.ssm_head_dim
            per_m = (e * (2 * self.d_inner + 2 * self.ssm_state + n_h)
                     + self.d_inner * self.d_conv + self.d_inner * e)
            shared = self._attn_params() + 3 * e * self.d_ff
            return emb + l * per_m + shared
        attn = self._attn_params()
        if self.is_moe:
            moe = (3 * self.n_experts * e * self.d_ff_expert
                   + 3 * self.n_shared_experts * e * self.d_ff_expert
                   + e * self.n_experts)
            dense_ff = 3 * e * (self.d_ff_dense or self.d_ff)
            ff = (l - self.first_k_dense) * moe + self.first_k_dense * dense_ff
        else:
            ff = l * 3 * e * self.d_ff
        enc = 0
        if self.is_encdec:
            # encoder stack + decoder cross-attention
            per_enc = (attn // max(l, 1)) + 3 * e * self.d_ff
            enc = self.n_enc_layers * per_enc + l * (attn // max(l, 1))
        return emb + attn + ff + enc

    def _attn_params(self) -> int:
        e, l = self.d_model, self.n_layers
        if self.attn_kind == "mla":
            per = (e * self.kv_lora_rank
                   + e * self.qk_rope_dim
                   + (e * self.q_lora_rank + self.q_lora_rank * self.n_heads
                      * (self.qk_nope_dim + self.qk_rope_dim)
                      if self.q_lora_rank else
                      e * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim))
                   + self.kv_lora_rank * self.n_heads
                   * (self.qk_nope_dim + self.v_head_dim)
                   + self.n_heads * self.v_head_dim * e)
        else:
            per = (e * self.n_heads * self.head_dim
                   + 2 * e * self.n_kv_heads * self.head_dim
                   + self.n_heads * self.head_dim * e)
        n_attn = l if self.attn_every == 0 else 1
        return n_attn * per

    def active_params(self) -> int:
        """Active-per-token parameters (MoE-aware) for MODEL_FLOPS = 6*N*D."""
        if not self.is_moe:
            return self.n_params()
        e, l = self.d_model, self.n_layers
        emb = self.vocab * e * (1 if self.tie_embeddings else 2)
        attn = self._attn_params()
        n_moe = l - self.first_k_dense
        act_ff = n_moe * 3 * e * self.d_ff_expert * (self.top_k
                                                     + self.n_shared_experts) \
            + self.first_k_dense * 3 * e * (self.d_ff_dense or self.d_ff)
        return emb + attn + act_ff
