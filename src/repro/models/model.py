"""Unified Model interface — dispatches per architecture family.

    model = build_model(cfg, mesh=None)
    params, axes = model.init(jax.random.PRNGKey(0))
    loss, metrics = model.loss(params, batch)
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.decode_step(params, cache, tokens, cache_len)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import encdec, hybrid, lm, ssm_lm


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    mesh: Optional[Any]
    _init: Callable
    _loss: Callable
    _init_cache: Callable
    _decode: Callable
    _prefill: Optional[Callable] = None

    def init(self, key):
        return self._init(self.cfg, key, mesh=self.mesh)

    def loss(self, params, batch):
        return self._loss(params, self.cfg, batch, mesh=self.mesh)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return self._init_cache(self.cfg, batch, max_len, dtype)

    def decode_step(self, params, cache, tokens, cache_len):
        return self._decode(params, self.cfg, cache, tokens, cache_len,
                            mesh=self.mesh)

    def prefill(self, params, batch, max_len: int):
        if self._prefill is not None:
            return self._prefill(params, self.cfg, batch, max_len,
                                 mesh=self.mesh)
        # default: decode-step over the whole prompt at cache_len 0
        cache = self.init_cache(batch["tokens"].shape[0], max_len)
        return self.decode_step(params, cache, batch["tokens"],
                                jnp.zeros((), jnp.int32))


def build_model(cfg: ArchConfig, mesh=None) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(cfg, mesh, lm.init_decoder_lm, lm.lm_loss,
                     lm.init_kv_cache, lm.lm_decode_step)
    if fam == "ssm":
        return Model(cfg, mesh, ssm_lm.init_ssm_lm, ssm_lm.ssm_lm_loss,
                     ssm_lm.ssm_init_cache, ssm_lm.ssm_decode_step,
                     _prefill=ssm_lm.ssm_prefill)
    if fam == "hybrid":
        return Model(cfg, mesh, hybrid.init_hybrid_lm, hybrid.hybrid_lm_loss,
                     hybrid.hybrid_init_cache, hybrid.hybrid_decode_step,
                     _prefill=hybrid.hybrid_prefill)
    if fam == "audio":
        return Model(cfg, mesh, encdec.init_encdec, encdec.encdec_loss,
                     encdec.encdec_init_cache, encdec.encdec_decode_step,
                     _prefill=encdec.encdec_prefill)
    raise ValueError(f"unknown family: {fam}")
