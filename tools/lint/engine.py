"""Rule engine: registry, per-file context, pragma suppression, findings.

A rule is a class with a ``name``, a one-line ``summary``, and a
``check(ctx) -> iterable[Finding]``. Rules register themselves with the
``@register`` decorator at import time (``tools.lint.rules`` imports every
rule module). The engine owns everything rule-agnostic:

  * building the ``FileContext`` (AST + comment map via ``tokenize`` +
    parent links) once per file, shared by all rules;
  * the suppression pragma: a ``# repro-lint: disable=RULE[,RULE]``
    comment suppresses matching findings on its own line, or — when the
    line holds nothing but the comment — on the next line. ``disable=all``
    suppresses every rule;
  * the source annotations the concurrency rules consume
    (``# guarded-by: <lock>`` and ``# holds-lock: <lock>``), parsed here
    so every rule sees one canonical comment map;
  * stable ordering and the text/github/json output formats (in ``cli``).
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Type

_PRAGMA = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\-\s]+)")
# the marker may follow prose in the same comment ("# queued rows —
# guarded-by: _cond"), so match anywhere after the hash
_GUARDED_BY = re.compile(r"#.*guarded-by:\s*([A-Za-z_][A-Za-z0-9_]*)")
_HOLDS_LOCK = re.compile(r"#.*holds-lock:\s*([A-Za-z_][A-Za-z0-9_]*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class Rule:
    """Base class: subclasses set ``name``/``summary`` and implement
    ``check``. One instance is created per linted file."""

    name: str = ""
    summary: str = ""

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        raise NotImplementedError

    # -- helpers shared by every rule --------------------------------------

    def finding(self, ctx: "FileContext", node: ast.AST,
                message: str) -> Finding:
        return Finding(self.name, ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding a rule to the global registry."""
    if not cls.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    """name -> rule class for every registered rule (imports the rule
    modules on first use)."""
    from . import rules  # noqa: F401  (registration side effect)
    return dict(_REGISTRY)


class FileContext:
    """Everything rules need about one file, computed once.

    Attributes:
      path: path string used in findings.
      source: full text.
      tree: parsed ``ast.Module`` with parent back-links on every node
        (``node.parent``; the module root has none).
      comments: line number -> raw comment text (``#`` included).
      standalone_comments: line numbers whose only content is a comment.
      is_test: file lives under a tests/ directory or is named test_*.py /
        conftest.py — rules may relax (e.g. ``interpret-literal``).
      guarded_by: (class name, attribute) -> lock name, from
        ``# guarded-by:`` comments on ``self.<attr> = ...`` lines.
      holds_lock: function/lambda line -> lock name, from ``# holds-lock:``
        comments on (or immediately above) a ``def`` line.
    """

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node
        self.comments: Dict[int, str] = {}
        self.standalone_comments: set = set()
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                line = tok.start[0]
                self.comments[line] = tok.string
                if tok.line.strip().startswith("#"):
                    self.standalone_comments.add(line)
        parts = path.replace("\\", "/").split("/")
        base = parts[-1]
        self.is_test = ("tests" in parts[:-1] or base.startswith("test_")
                        or base == "conftest.py")
        self.guarded_by = self._parse_guarded_by()
        self.holds_lock = self._parse_holds_lock()

    # -- annotation parsing -------------------------------------------------

    def comment_for(self, line: int) -> Optional[str]:
        """The comment governing ``line``: trailing on the line itself, or
        a standalone comment on the line directly above."""
        if line in self.comments and line not in self.standalone_comments:
            return self.comments[line]
        if line - 1 in self.standalone_comments:
            return self.comments[line - 1]
        return None

    def _parse_guarded_by(self) -> Dict[tuple, str]:
        out: Dict[tuple, str] = {}
        for cls in ast.walk(self.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for node in ast.walk(cls):
                if not isinstance(node, (ast.Assign, ast.AnnAssign,
                                         ast.AugAssign)):
                    continue
                comment = self.comment_for(node.lineno)
                if not comment:
                    continue
                m = _GUARDED_BY.search(comment)
                if not m:
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        out[(cls.name, t.attr)] = m.group(1)
        return out

    def _parse_holds_lock(self) -> Dict[int, str]:
        out: Dict[int, str] = {}
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            comment = self.comment_for(node.lineno)
            if comment:
                m = _HOLDS_LOCK.search(comment)
                if m:
                    out[node.lineno] = m.group(1)
        return out

    # -- pragma suppression -------------------------------------------------

    def suppressed(self, finding: Finding) -> bool:
        comment = self.comment_for(finding.line)
        if not comment:
            return False
        m = _PRAGMA.search(comment)
        if not m:
            return False
        names = {n.strip() for n in m.group(1).split(",") if n.strip()}
        return "all" in names or finding.rule in names


def lint_source(source: str, path: str = "<string>",
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    """Run (selected) rules over one source string; pragma-filtered and
    sorted by location. A syntax error yields a single ``syntax-error``
    finding instead of raising."""
    registry = all_rules()
    if select is not None:
        unknown = set(select) - set(registry)
        if unknown:
            raise ValueError(f"unknown rule(s): {', '.join(sorted(unknown))}")
        registry = {k: v for k, v in registry.items() if k in select}
    try:
        ctx = FileContext(path, source)
    except SyntaxError as e:
        return [Finding("syntax-error", path, e.lineno or 1, e.offset or 0,
                        f"cannot parse: {e.msg}")]
    findings: List[Finding] = []
    for cls in registry.values():
        for f in cls().check(ctx):
            if not ctx.suppressed(f):
                findings.append(f)
    return sorted(set(findings),
                  key=lambda f: (f.path, f.line, f.col, f.rule))


def lint_file(path: str,
              select: Optional[Iterable[str]] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path, select=select)


def iter_findings(paths: Iterable[str],
                  select: Optional[Iterable[str]] = None
                  ) -> Iterator[Finding]:
    import os
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield from lint_file(os.path.join(root, name),
                                             select=select)
        else:
            yield from lint_file(p, select=select)
