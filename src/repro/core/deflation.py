"""Beyond-paper: top-k decentralized kernel PCA via sequential deflation.

The paper computes only the FIRST kernel principal component. We extend to
top-k by deflating each node's Gram blocks with the *converged consensus
direction* after each round and re-running Alg. 1:

    K'(x, y) = K(x, y) - (phi(x)^T w)(w^T phi(y)) / ||w||^2

Every factor is evaluable at node j for all data it holds: w = phi(X_j)alpha_j
gives phi(x)^T w = K(x, X_j) alpha_j for any x in the neighborhood — so the
deflation is fully decentralized (each node deflates with its own w_j; at
consensus w_j ~= the projection of the shared component, so the deflated
problems stay consistent — validated against central top-k in
tests/test_deflation.py)."""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .admm import DkpcaSetup, run_admm
from .kernels_math import psd_jitter_eigh
from .rho import RhoSchedule


def _deflate_setup(setup: DkpcaSetup, alpha: jax.Array) -> DkpcaSetup:
    """Deflate all Gram blocks with the converged component.

    kcross[j, a, b] -= proj_a proj_b^T / w2_j  where
    proj_a = K(X_src[j,a], X_j) alpha_j  (slot 0 is the node itself)."""
    # phi(X_src[j,a])^T w_j = kcross[j, a, 0] @ alpha_j     (N vectors)
    proj = jnp.einsum("jabnm,jm->jabn", setup.kcross[:, :, 0:1],
                      alpha)[:, :, 0]                      # (J, S, N)
    w2 = jnp.einsum("jn,jnm,jm->j", alpha, setup.k, alpha)  # ||w_j||^2
    w2 = jnp.maximum(w2, 1e-12)
    outer = jnp.einsum("jan,jbm->jabnm", proj, proj) / w2[:, None, None,
                                                          None, None]
    kcross = setup.kcross - outer
    kj = kcross[:, 0, 0]
    lam, vec = jax.vmap(psd_jitter_eigh)(kj)
    return dataclasses.replace(setup, kcross=kcross, k=kj, lam=lam, vec=vec)


def _local_gram_schmidt(k, alpha_new, prev_alphas):
    """Per-node Gram-Schmidt in feature space (local, no communication):
    alpha' = alpha - sum_p <w, w_p>/<w_p, w_p> alpha_p."""
    for ap in prev_alphas:
        num = jnp.einsum("jn,jnm,jm->j", ap, k, alpha_new)
        den = jnp.maximum(jnp.einsum("jn,jnm,jm->j", ap, k, ap), 1e-12)
        alpha_new = alpha_new - (num / den)[:, None] * ap
    return alpha_new


def run_admm_topk(setup: DkpcaSetup, k: int, n_iters: int = 30,
                  rho1: float = 100.0, rho2: RhoSchedule = None,
                  seed: int = 0) -> List[jax.Array]:
    """Sequential-deflation top-k. Returns list of (J, N) alpha arrays.
    After each round, components are locally Gram-Schmidt-orthogonalized
    against the previous ones (deflation guarantees near-orthogonality only
    at exact consensus; the local projection removes the residual)."""
    alphas = []
    cur = setup
    for c in range(k):
        res = run_admm(cur, n_iters=n_iters, rho1=rho1, rho2=rho2,
                       seed=seed + c)
        alpha = _local_gram_schmidt(setup.k, res.alpha, alphas)
        alphas.append(alpha)
        if c + 1 < k:
            cur = _deflate_setup(cur, alpha)
    return alphas
