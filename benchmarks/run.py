# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--smoke] \
        [--only fig3,...] [--json out.json] [--host-devices N]

Paper tables/figures:
    fig3  similarity vs #nodes          (bench_kpca.bench_similarity_vs_nodes)
    fig4  similarity vs local samples   (bench_kpca.bench_similarity_vs_samples)
    fig5  similarity vs #neighbors      (bench_kpca.bench_similarity_vs_neighbors)
    rt    runtime vs central kPCA       (bench_kpca.bench_runtime_vs_central)
plus kernel micro-benches, the roofline summary from the dry-run, and the
serving suites (``serve`` batched engine, ``shard`` sharded multi-device
sweep).

``--smoke`` is the CI entry point: the fast suites (kernels/serve/shard) at
quick dims, with results also written as JSON (default bench-smoke.json) for
artifact upload. ``--host-devices N`` exposes N host CPU devices before jax
initializes so the ``shard`` suite runs on a real mesh off-TPU; argument
parsing therefore happens BEFORE the benchmark modules (which import jax at
module scope) are loaded.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                      # `python benchmarks/run.py ...`
sys.path.insert(0, os.path.join(_ROOT, "src"))

ALL_SUITES = ["fig3", "fig4", "fig5", "rt", "kernels", "roofline", "serve",
              "shard", "async", "obs", "faults"]
QUICK_DIM_SUITES = ("fig3", "fig4", "fig5", "rt", "serve", "shard", "async",
                    "obs", "faults")
SMOKE_SUITES = ["kernels", "serve", "shard", "async", "obs", "faults"]


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite subset")
    ap.add_argument("--quick", action="store_true",
                    help="smaller feature dim for fast CI runs")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fast suites at quick dims + JSON output")
    ap.add_argument("--json", default=None,
                    help="write rows as JSON to this path "
                         "(default bench-smoke.json under --smoke)")
    ap.add_argument("--out", default=None,
                    help="ALSO write the same JSON payload to this path — "
                         "used by CI to persist the repo-root BENCH_<n>.json"
                         " artifact tracking the perf trajectory across PRs")
    ap.add_argument("--host-devices", type=int, default=4,
                    help="host CPU devices to expose for the shard suite "
                         "(0 = leave XLA_FLAGS untouched)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable span tracing across every suite; write "
                         "Chrome-trace JSON (Perfetto) to PATH at exit")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the final metrics snapshot (JSON) to PATH")
    return ap.parse_args()


def _derived_fields(results) -> dict:
    """Lift headline observability numbers out of the obs-suite rows'
    ``derived`` strings into top-level JSON fields, so the committed
    BENCH_<n>.json tracks them as scalars across PRs."""
    kv = {}
    for row in results:
        if not row["name"].startswith("obs/"):
            continue
        for part in row["derived"].split(";"):
            if "=" in part:
                k, v = part.split("=", 1)
                kv[(row["name"], k)] = v
    out = {}
    if ("obs/comm_dense", "bytes_per_iter") in kv:
        out["bytes_per_iter_dense"] = int(
            kv[("obs/comm_dense", "bytes_per_iter")])
    if ("obs/comm_ring", "bytes_per_iter") in kv:
        out["bytes_per_iter_ring"] = int(
            kv[("obs/comm_ring", "bytes_per_iter")])
    for phase in ("pack", "dispatch", "device", "resolve"):
        key = ("obs/flush_phases", f"flush_{phase}_ms")
        if key in kv:
            out[f"flush_{phase}_ms"] = float(kv[key])
    for row in results:
        if row["name"] == "obs/span_disabled":
            out["span_disabled_us"] = round(row["us_per_call"], 4)
    return out


def main() -> None:
    args = _parse_args()
    quick = args.quick or args.smoke
    if args.only:
        names = args.only.split(",")
    elif args.smoke:
        names = list(SMOKE_SUITES)
    else:
        names = ALL_SUITES

    # Environment layer BEFORE backend init (repro.launch.env owns the
    # ordering footgun): REPRO_* variables apply to every suite; host
    # devices are forced only when the shard suite actually runs — the
    # other suites' timings should see the unmodified device count.
    from repro.launch import env as _env
    _env.apply_from_environ()
    if "shard" in names and args.host_devices > 0:
        _env.apply(_env.EnvConfig(host_devices=args.host_devices))

    # Import AFTER the env layer ran: these modules import jax at module
    # scope, and the flags must precede backend initialization.
    from benchmarks.bench_kernels import (bench_centering_kernel,
                                          bench_gram_kernel)
    from benchmarks.bench_kpca import (bench_runtime_vs_central,
                                       bench_similarity_vs_neighbors,
                                       bench_similarity_vs_nodes,
                                       bench_similarity_vs_samples)
    from benchmarks.bench_faults import bench_faults
    from benchmarks.bench_obs import bench_obs
    from benchmarks.bench_roofline import bench_roofline_summary
    from benchmarks.bench_serve_async import bench_serve_async
    from benchmarks.bench_serve_kpca import (bench_serve_kpca,
                                             bench_serve_sharded)
    from repro.obs import metrics, trace

    if args.trace_out:
        trace.enable()

    suites = {
        "fig3": bench_similarity_vs_nodes,
        "fig4": bench_similarity_vs_samples,
        "fig5": bench_similarity_vs_neighbors,
        "rt": bench_runtime_vs_central,
        "kernels": lambda: bench_gram_kernel() + bench_centering_kernel(),
        "roofline": bench_roofline_summary,
        "serve": bench_serve_kpca,
        "shard": bench_serve_sharded,
        "async": bench_serve_async,
        "obs": bench_obs,
        "faults": bench_faults,
    }

    assert list(suites) == ALL_SUITES, "keep ALL_SUITES in sync"
    results = []
    print("name,us_per_call,derived")
    for name in names:
        fn = suites[name]
        rows = fn(m=64) if quick and name in QUICK_DIM_SUITES else fn()
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}")
            results.append({"name": row[0], "us_per_call": float(row[1]),
                            "derived": row[2]})
        sys.stdout.flush()

    json_path = args.json or ("bench-smoke.json" if args.smoke else None)
    payload = {"suites": names, "rows": results}
    derived = _derived_fields(results)
    if derived:
        payload["derived"] = derived
    for path in filter(None, {json_path, args.out}):
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {path}", file=sys.stderr)
    if args.trace_out:
        n = trace.export(args.trace_out)
        print(f"wrote {n} trace events -> {args.trace_out}", file=sys.stderr)
    if args.metrics_out:
        metrics.write_json(args.metrics_out)
        print(f"wrote metrics snapshot -> {args.metrics_out}",
              file=sys.stderr)


if __name__ == "__main__":
    main()
