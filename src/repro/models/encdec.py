"""Encoder-decoder transformer (seamless-m4t backbone: speech encoder stub
-> text decoder with cross-attention). The modality frontend is a STUB per
the assignment: ``batch["frames"]`` carries precomputed frame embeddings at
d_model."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, gqa_forward, init_gqa, sdpa
from .common import (ParamCollector, ScanBlock, StackedCollector,
                     constrain_act, dtype_of, rms_norm, slice_layer)
from .mlp import init_mlp, mlp_forward


def init_encdec(cfg: ArchConfig, key: jax.Array, mesh=None):
    col = ParamCollector(key, dtype_of(cfg.param_dtype))
    e = cfg.d_model
    col.param("embed", (cfg.vocab, e), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        col.param("lm_head", (e, cfg.vocab), ("embed", "vocab"), scale=0.02)
    col.param("final_norm", (e,), (None,), init="ones")
    col.param("enc_norm", (e,), (None,), init="ones")

    enc = StackedCollector(col, cfg.n_enc_layers, "enc")
    init_gqa(enc, cfg)
    init_mlp(enc, cfg)
    enc.param("ln_attn", (e,), (None,), init="ones")
    enc.param("ln_mlp", (e,), (None,), init="ones")

    dec = StackedCollector(col, cfg.n_layers, "dec")
    init_gqa(dec, cfg)                       # self-attention
    # cross-attention
    h, hk, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dec.param("xattn/wq", (e, h, d), ("embed", "heads", "head_dim"))
    dec.param("xattn/wk", (e, hk, d), ("embed", "kv_heads", "head_dim"))
    dec.param("xattn/wv", (e, hk, d), ("embed", "kv_heads", "head_dim"))
    dec.param("xattn/wo", (h, d, e), ("heads", "head_dim", "embed"))
    init_mlp(dec, cfg)
    dec.param("ln_attn", (e,), (None,), init="ones")
    dec.param("ln_xattn", (e,), (None,), init="ones")
    dec.param("ln_mlp", (e,), (None,), init="ones")
    return col.params, col.axes


def _encode(params, cfg: ArchConfig, frames, mesh=None):
    x = constrain_act(frames.astype(dtype_of(cfg.compute_dtype)), mesh)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def block(p, carry):
        x = carry
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        a, _ = gqa_forward(slice_layer(p, "attn"), cfg, h, positions,
                           causal=False)
        x = x + a
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        return constrain_act(x + mlp_forward(slice_layer(p, "mlp"), cfg, h),
                             mesh), None

    x, _ = ScanBlock.run(block, slice_layer(params, "enc"), x,
                         remat=cfg.remat, unroll=cfg.unroll_scans)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attn(p, cfg, x, xk, xv):
    """Cross-attention with precomputed encoder K/V (no mask, no rope)."""
    q = jnp.einsum("bse,ehd->bshd", x, p["xattn/wq"].astype(x.dtype))
    bias = jnp.zeros((x.shape[0], x.shape[1], xk.shape[1]), jnp.float32)
    out = sdpa(cfg, q, xk.astype(x.dtype), xv.astype(x.dtype), bias)
    return jnp.einsum("bshd,hde->bse", out, p["xattn/wo"].astype(x.dtype))


def _enc_kv(p, cfg, enc_out):
    xk = jnp.einsum("bse,ehd->bshd", enc_out, p["xattn/wk"].astype(enc_out.dtype))
    xv = jnp.einsum("bse,ehd->bshd", enc_out, p["xattn/wv"].astype(enc_out.dtype))
    return xk, xv


def _decoder(params, cfg: ArchConfig, tokens, enc_out, positions,
             self_cache=None, cache_len=None, mesh=None):
    x = constrain_act(
        params["embed"][tokens].astype(dtype_of(cfg.compute_dtype)), mesh)

    def block(p, carry, cache_slice=None):
        x = carry
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        a, new_cache = gqa_forward(
            slice_layer(p, "attn"), cfg, h, positions, causal=True,
            cache=None if cache_slice is None else KVCache(*cache_slice),
            cache_len=cache_len)
        x = x + a
        h = rms_norm(x, p["ln_xattn"], cfg.norm_eps)
        xk, xv = _enc_kv(p, cfg, enc_out)
        x = x + _cross_attn(p, cfg, h, xk, xv)
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        return constrain_act(x + mlp_forward(slice_layer(p, "mlp"), cfg, h),
                             mesh), new_cache

    stacked = slice_layer(params, "dec")
    if self_cache is None:
        def sblock(p, carry):
            y, _ = block(p, carry)
            return y, None
        x, _ = ScanBlock.run(sblock, stacked, x, remat=cfg.remat,
                             unroll=cfg.unroll_scans)
        new_cache = None
    else:
        def step(carry, xs):
            p, ck, cv = xs
            y, nc = block(p, carry, (ck, cv))
            return y, nc
        x, new_cache = jax.lax.scan(step, x,
                                    (stacked, self_cache[0], self_cache[1]),
                                    unroll=cfg.unroll_scans)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bse,ev->bsv", x, head.astype(x.dtype)), new_cache


def encdec_loss(params, cfg: ArchConfig, batch, mesh=None):
    enc_out = _encode(params, cfg, batch["frames"], mesh=mesh)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    logits, _ = _decoder(params, cfg, tokens, enc_out, positions, mesh=mesh)
    targets = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    return loss, {"loss": loss}


class EncDecCache(NamedTuple):
    self_k: jax.Array     # (L, B, T, Hkv, D)
    self_v: jax.Array
    enc_out: jax.Array    # (B, S_enc, E)


def encdec_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    l, hk, d = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return EncDecCache(
        jnp.zeros((l, batch, max_len, hk, d), dtype),
        jnp.zeros((l, batch, max_len, hk, d), dtype),
        jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype))


def encdec_prefill(params, cfg: ArchConfig, batch, max_len: int, mesh=None,
                   cache_dtype=jnp.bfloat16):
    """Encode frames + run the decoder prompt, building the self-attn cache."""
    enc_out = _encode(params, cfg, batch["frames"], mesh=mesh)
    cache = encdec_init_cache(cfg, batch["tokens"].shape[0], max_len,
                              cache_dtype)
    cache = cache._replace(enc_out=enc_out.astype(cache_dtype))
    return encdec_decode_step(params, cfg, cache, batch["tokens"],
                              jnp.zeros((), jnp.int32), mesh=mesh)


def encdec_decode_step(params, cfg: ArchConfig, cache, tokens, cache_len,
                       mesh=None):
    b, s = tokens.shape
    positions = jnp.broadcast_to(cache_len + jnp.arange(s)[None], (b, s))
    enc_out = cache.enc_out.astype(dtype_of(cfg.compute_dtype))
    logits, new_cache = _decoder(params, cfg, tokens, enc_out, positions,
                                 self_cache=(cache.self_k, cache.self_v),
                                 cache_len=cache_len, mesh=mesh)
    return logits[:, -1], EncDecCache(new_cache[0], new_cache[1],
                                      cache.enc_out)
