"""Model substrate: parameter collection with logical sharding axes, norms,
initializers, dtype policy.

Parameters live in FLAT dicts keyed by '/'-separated paths; a parallel dict
maps each path to its tuple of logical axis names. Stacked ("scanned") layer
parameters carry a leading "layers" axis. Everything is pure JAX — no flax.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]
Axes = Dict[str, Tuple[Optional[str], ...]]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


class ParamCollector:
    """Creates parameters, records logical axes, threads the PRNG key."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _next(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, path: str, shape: Tuple[int, ...],
              axes: Tuple[Optional[str], ...], init: str = "normal",
              scale: Optional[float] = None, dtype=None) -> jax.Array:
        assert len(shape) == len(axes), (path, shape, axes)
        assert path not in self.params, f"duplicate param {path}"
        dt = dtype or self.dtype
        if init == "zeros":
            v = jnp.zeros(shape, dt)
        elif init == "ones":
            v = jnp.ones(shape, dt)
        elif init == "normal":
            if scale is None:
                # conservative fan-in: product of all-but-last non-stack dims
                dims = shape[1:] if (axes and axes[0] in ("layers", "stack")) \
                    else shape
                fan_in = max(int(math.prod(dims[:-1])) or dims[-1], 1)
                scale = 1.0 / math.sqrt(fan_in)
            v = (jax.random.normal(self._next(), shape, jnp.float32)
                 * scale).astype(dt)
        else:
            raise ValueError(init)
        self.params[path] = v
        self.axes[path] = tuple(axes)
        return v

    def abstract(self) -> Dict[str, jax.ShapeDtypeStruct]:
        return {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in self.params.items()}


class StackedCollector:
    """Proxy collector that prepends a 'layers' stack dim to every param —
    used to initialize scanned layer stacks with per-layer randomness."""

    def __init__(self, parent: ParamCollector, n: int, prefix: str):
        self._p = parent
        self._n = n
        self._prefix = prefix
        self.dtype = parent.dtype

    def _next(self):
        return self._p._next()

    def param(self, path, shape, axes, init="normal", scale=None, dtype=None):
        return self._p.param(f"{self._prefix}/{path}", (self._n,) + tuple(shape),
                             ("layers",) + tuple(axes), init=init,
                             scale=scale, dtype=dtype)


def abstract_params(init_fn, key) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Axes]:
    """Trace init_fn(key) -> (param ShapeDtypeStructs, logical axes) without
    allocating any memory (axes are static metadata captured by closure)."""
    closed = {}

    def capture(k):
        p, a = init_fn(k)
        closed["axes"] = a
        return p

    shapes = jax.eval_shape(capture, key)
    return shapes, closed["axes"]


# ----------------------------------------------------------------------
# normalization / activations
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def batch_axes_of(mesh):
    if mesh is None:
        return None
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def constrain_act(x, mesh):
    """Pin activations to (batch@data[,pod], replicated...) — without this
    GSPMD may replicate the batch and pay per-matmul activation all-reduces
    (measured: 14 TB/device/step on llama3-405b; see EXPERIMENTS.md §Perf)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = batch_axes_of(mesh)
    if x.shape[0] % int(np.prod([mesh.shape[a] for a in ba])):
        return x
    spec = P(ba, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ----------------------------------------------------------------------
# stacked-layer utilities (scan over layers)
# ----------------------------------------------------------------------

def slice_layer(params: Params, prefix: str) -> Params:
    """Sub-dict of params under `prefix/` with the prefix stripped."""
    plen = len(prefix) + 1
    return {k[plen:]: v for k, v in params.items() if k.startswith(prefix + "/")}


def merge(prefix: str, sub: Params) -> Params:
    return {f"{prefix}/{k}": v for k, v in sub.items()}


@dataclasses.dataclass
class ScanBlock:
    """Helper to scan a block function over stacked layer params."""

    @staticmethod
    def run(block_fn, stacked: Params, carry, remat: str = "full",
            unroll=1):
        """carry -> scan over leading 'layers' dim of every stacked param."""
        fn = block_fn
        if remat == "full":
            fn = jax.checkpoint(block_fn,
                                policy=jax.checkpoint_policies.nothing_saveable)
        elif remat == "dots":
            fn = jax.checkpoint(
                block_fn,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

        def step(c, layer_params):
            c2, out = fn(layer_params, c)
            return c2, out

        return jax.lax.scan(step, carry, stacked, unroll=unroll)
