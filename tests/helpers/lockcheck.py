"""Runtime lock-order detector: the dynamic companion to repro-lint's
static ``lock-order`` rule (docs/STATIC_ANALYSIS.md).

The static rule only sees inverse ``with`` nesting inside one file; real
deadlocks in the serving stack span objects and threads — the flusher
holds the engine's dispatch lock while reading through ``ModelHandle``,
the publisher worker holds the refresh lock while publishing, the queue's
condition sleeps under its own lock. This module observes the ACTUAL
acquisition order at test time:

  * ``LockOrderGraph`` — a thread-safe "acquired-while-holding" edge
    graph with DFS cycle detection;
  * ``OrderedLock`` — a ``threading.Lock`` work-alike that records an
    edge ``held -> acquiring`` for every lock the acquiring thread
    already holds (it also satisfies the private hooks
    ``threading.Condition`` needs, so ``Condition(OrderedLock(...))``
    instruments a condition's lock transparently);
  * ``instrument_serving_locks`` — context manager that swaps the
    ``threading`` module seen by ``repro.serve.batching`` /
    ``kpca_engine`` / ``publisher`` for a shim whose ``Lock()`` /
    ``Condition()`` build instrumented primitives named after the source
    line that created them;
  * the ``lock_order_guard`` autouse fixture — active for tests marked
    ``@pytest.mark.lockcheck`` (module-wide via ``pytestmark`` in
    tests/test_async_engine.py, test_batching.py, test_publisher.py):
    every lock the serving layer creates during the test is instrumented,
    and the test FAILS at teardown if the recorded order graph contains a
    cycle — an AB/BA interleaving that deadlocks only under unlucky
    scheduling fails deterministically here.

A cycle in the graph is a potential deadlock even if the test happened to
pass: two threads that ever acquire the same two locks in opposite orders
can block each other forever under the right interleaving.
"""

from __future__ import annotations

import contextlib
import linecache
import re
import sys
import threading
from typing import Dict, List, Optional, Set, Tuple

import pytest

_ATTR_ASSIGN = re.compile(r"self\.(\w+)\s*=")


class LockOrderGraph:
    """Acquired-while-holding edges between named locks, per process.

    ``record(held, acquiring)`` is called by ``OrderedLock`` under its own
    internal lock; ``find_cycle`` runs a DFS over the accumulated edges
    and returns one cycle as a name path (closed: first == last), or None.
    """

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: Dict[str, Set[str]] = {}
        self._local = threading.local()

    # -- per-thread held stack ----------------------------------------------

    def _held(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def on_acquiring(self, name: str) -> None:
        held = self._held()
        if held:
            with self._mu:
                for h in held:
                    if h != name:
                        self._edges.setdefault(h, set()).add(name)

    def on_acquired(self, name: str) -> None:
        self._held().append(name)

    def on_released(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    # -- analysis -----------------------------------------------------------

    @property
    def edges(self) -> Dict[str, Set[str]]:
        with self._mu:
            return {k: set(v) for k, v in self._edges.items()}

    def find_cycle(self) -> Optional[List[str]]:
        edges = self.edges
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in
                 set(edges) | {v for vs in edges.values() for v in vs}}
        path: List[str] = []

        def dfs(n) -> Optional[List[str]]:
            color[n] = GRAY
            path.append(n)
            for m in sorted(edges.get(n, ())):
                if color[m] == GRAY:
                    return path[path.index(m):] + [m]
                if color[m] == WHITE:
                    cyc = dfs(m)
                    if cyc:
                        return cyc
            color[n] = BLACK
            path.pop()
            return None

        for n in sorted(color):
            if color[n] == WHITE:
                cyc = dfs(n)
                if cyc:
                    return cyc
        return None


class OrderedLock:
    """Drop-in ``threading.Lock`` that reports to a ``LockOrderGraph``.

    Also provides the private hooks ``threading.Condition`` probes for
    (``_is_owned`` etc. fall back correctly because this exposes plain
    ``acquire``/``release``), so ``Condition(OrderedLock(...))`` works.
    """

    def __init__(self, name: str, graph: LockOrderGraph,
                 inner: Optional[threading.Lock] = None):
        self.name = name
        self.graph = graph
        self._inner = inner if inner is not None else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self.graph.on_acquiring(self.name)
        got = self._inner.acquire(blocking, timeout)
        if got:
            self.graph.on_acquired(self.name)
        return got

    def release(self) -> None:
        self._inner.release()
        self.graph.on_released(self.name)

    def locked(self) -> bool:
        return self._inner.locked()

    def _is_owned(self) -> bool:
        """Hook for ``threading.Condition``: owned iff this thread holds
        the lock (tracked exactly by the per-thread held stack)."""
        return self.name in self.graph._held()

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"OrderedLock({self.name!r}, locked={self.locked()})"


def _creation_site_name(depth: int = 2) -> str:
    """Name a lock after the source line creating it: prefers the
    ``self.<attr>`` being assigned, falls back to file:line."""
    frame = sys._getframe(depth)
    fname, lineno = frame.f_code.co_filename, frame.f_lineno
    line = linecache.getline(fname, lineno)
    m = _ATTR_ASSIGN.search(line)
    mod = frame.f_globals.get("__name__", "?").rsplit(".", 1)[-1]
    if m:
        return f"{mod}.{m.group(1)}"
    return f"{mod}:{lineno}"


class _ThreadingShim:
    """Stand-in for the ``threading`` module inside the serve modules:
    ``Lock``/``Condition`` build instrumented primitives on ``graph``,
    everything else (Thread, Event, local, ...) passes through."""

    def __init__(self, graph: LockOrderGraph):
        self.graph = graph

    def Lock(self):
        return OrderedLock(_creation_site_name(), self.graph)

    def RLock(self):                          # pragma: no cover (unused)
        return OrderedLock(_creation_site_name(), self.graph,
                           inner=threading.RLock())

    def Condition(self, lock=None):
        if lock is None:
            lock = OrderedLock(_creation_site_name(), self.graph)
        return threading.Condition(lock)

    def __getattr__(self, name):
        return getattr(threading, name)


_SERVE_MODULE_NAMES = ("repro.serve.batching", "repro.serve.kpca_engine",
                       "repro.serve.publisher")


@contextlib.contextmanager
def instrument_serving_locks(graph: LockOrderGraph):
    """Swap the ``threading`` binding of the serving modules for the
    instrumenting shim; locks created by objects constructed inside the
    context report to ``graph``. Pre-existing objects keep their plain
    locks (construct engines/handles INSIDE the context)."""
    import importlib
    mods = [importlib.import_module(n) for n in _SERVE_MODULE_NAMES]
    shim = _ThreadingShim(graph)
    saved = [(m, m.threading) for m in mods]
    for m in mods:
        m.threading = shim
    try:
        yield graph
    finally:
        for m, orig in saved:
            m.threading = orig


@pytest.fixture(autouse=True)
def lock_order_guard(request):
    """Autouse (via this plugin) for tests marked ``lockcheck``: serve-
    layer locks created during the test are instrumented, and a recorded
    AB/BA acquisition cycle fails the test at teardown."""
    if request.node.get_closest_marker("lockcheck") is None:
        yield None
        return
    graph = LockOrderGraph()
    with instrument_serving_locks(graph):
        yield graph
    cycle = graph.find_cycle()
    assert cycle is None, (
        f"lock-order cycle recorded: {' -> '.join(cycle)} — two threads "
        f"acquire these locks in opposite orders (latent deadlock)")
