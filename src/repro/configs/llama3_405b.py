"""llama3-405b [dense] 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 [arXiv:2407.21783; unverified]."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", family="dense", n_layers=126, d_model=16384,
        n_heads=128, n_kv_heads=8, d_ff=53248, vocab=128256, head_dim=128,
        rope_theta=500000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b-smoke", family="dense", n_layers=3, d_model=96,
        n_heads=8, n_kv_heads=2, d_ff=192, vocab=512, head_dim=12,
        rope_theta=500000.0, remat="none")
