from .ops import admm_local_update_op
from .ref import admm_local_update_reference

__all__ = ["admm_local_update_op", "admm_local_update_reference"]
