"""SPMD correctness: shard_map + collective_permute DKPCA vs. the reference
simulator, on 8 forced host devices (subprocess — the main pytest process
keeps the default 1-device CPU config)."""

import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "check_dkpca_distributed.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(mode):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, HELPER, mode], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK" in out.stdout


@pytest.mark.parametrize("mode", ["exact", "pallas", "rescale"])
def test_distributed_matches_simulator(mode):
    _run(mode)
