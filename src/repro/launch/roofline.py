"""Roofline derivation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, in seconds per step:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_BW               (819 GB/s)
    collective = collective_bytes_per_device / LINK_BW       (50 GB/s/link)

cost_analysis() runs on the SPMD-partitioned per-device module, so its
flops/bytes are already per-device (verified in tests). collective bytes are
parsed from the partitioned HLO (sum of collective-op output bytes; the
published formula collective_bytes/(chips*link_bw) with global bytes reduces
to the same per-device expression).

Methodology caveats (CPU-backend dry-run):
- "bytes accessed" is an unfused upper bound (the CPU cost model counts
  operand traffic before fusion) — the memory term is therefore pessimistic;
  we report it as an upper bound and use deltas (before/after) for §Perf.
- The collective term uses raw payload bytes; ring factors (2(n-1)/n for
  all-reduce etc.) would scale it by <=2x and do not change which term
  dominates in any cell.

MODEL_FLOPS = 6*N_active*D for train steps (fwd+bwd), 2*N_active*D for
prefill/decode forward passes, D = tokens processed per step. The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) measures how much compiled compute is
"useful" (remat recompute, SSD chunk overhead, and dispatch waste show up
here)."""

from __future__ import annotations

import argparse
import json
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link


def model_flops(rec: Dict, shape_name: str, batch: int, seq: int) -> float:
    n_act = rec["n_active_params"]
    if shape_name.startswith("train"):
        return 6.0 * n_act * batch * seq
    if shape_name.startswith("prefill"):
        return 2.0 * n_act * batch * seq
    # decode: one token per sequence per step
    return 2.0 * n_act * batch


SHAPE_DIMS = {
    "train_4k": (256, 4096),
    "prefill_32k": (32, 32768),
    "decode_32k": (128, 1),      # tokens per step
    "long_500k": (1, 1),
}


def analyze(rec: Dict) -> Dict:
    shape = rec["shape"]
    if shape in SHAPE_DIMS:
        batch, seq = SHAPE_DIMS[shape]
    else:
        # dkpca-paper cell: n_active_params carries the ANALYTIC useful
        # flops per node (= per device) for one ADMM iteration
        batch, seq = None, None
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed_per_device"] / HBM_BW
    coll_bytes = sum(v["bytes"] for v in rec["collectives"].values())
    collective_s = coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    if batch is None:
        mf = rec["n_active_params"] * max(rec["n_devices"], 1)
    else:
        mf = model_flops(rec, shape, batch, seq)
    hlo_total = rec["flops_per_device"] * max(rec["n_devices"], 1)
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: ideal compute time of *useful* flops over the
    # dominant actual term — the score to hillclimb.
    ideal_s = mf / (PEAK_FLOPS * max(rec["n_devices"], 1))
    frac = ideal_s / max(terms[dominant], 1e-30)
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "collective_bytes_per_device": coll_bytes,
    }


def to_markdown(results: Dict[str, Dict], single_pod_only=True) -> str:
    rows = []
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful FLOPs | roofline frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for key, rec in sorted(results.items()):
        if not rec.get("ok"):
            continue
        if single_pod_only and rec["mesh"] != "16x16":
            continue
        a = analyze(rec)
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {a['compute_s']:.3f} | {a['memory_s']:.3f} "
            f"| {a['collective_s']:.3f} | **{a['dominant']}** "
            f"| {a['useful_flops_ratio']:.2f} "
            f"| {a['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--markdown", default="results/roofline.md")
    args = ap.parse_args()
    results = json.load(open(args.dryrun))
    out = {}
    for key, rec in results.items():
        if rec.get("ok"):
            out[key] = dict(rec, **analyze(rec))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    md = to_markdown(results, single_pod_only=True)
    with open(args.markdown, "w") as f:
        f.write(md + "\n")
    print(md)


if __name__ == "__main__":
    main()
