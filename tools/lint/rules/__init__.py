"""Rule modules register themselves on import (``@register``)."""

from . import benchrules, concurrency, jaxrules, obs, testing  # noqa: F401

__all__ = ["benchrules", "concurrency", "jaxrules", "obs", "testing"]
