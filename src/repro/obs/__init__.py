"""Observability layer: span tracing, metrics, communication accounting.

Three independent, dependency-free (stdlib-only, no jax) facilities:

  * ``repro.obs.trace`` — ring-buffer span tracer with Chrome-trace/
    Perfetto export; zero-cost no-op while disabled.
  * ``repro.obs.metrics`` — process-wide registry of counters/gauges/
    histograms; JSON snapshot + Prometheus text exposition.
  * ``repro.obs.comm`` — trace-time per-iteration communication
    accounting for the ADMM transports (``CommLedger``).

See docs/OBSERVABILITY.md for the span taxonomy, the metric catalog, and
how to open an exported trace in Perfetto.
"""

from . import metrics, trace
from .comm import CommLedger, CommProfile

__all__ = ["CommLedger", "CommProfile", "metrics", "trace"]
