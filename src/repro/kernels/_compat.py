"""jax version compatibility for the Pallas kernel packages.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
jax releases; resolve whichever exists so the kernels build on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
