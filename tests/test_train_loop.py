"""End-to-end training-loop behaviour on a tiny model (single device)."""

import numpy as np

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig, cosine_with_warmup
from repro.train import TrainConfig, train


def _cfg():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      head_dim=16, tie_embeddings=True, remat="none",
                      param_dtype="float32", compute_dtype="float32")


def test_loss_decreases():
    cfg = _cfg()
    model = build_model(cfg)
    data = TokenStream(vocab=cfg.vocab, batch=4, seq=32, seed=0)
    opt = AdamWConfig(lr=3e-3, schedule=cosine_with_warmup(5, 60))
    state, hist = train(model, opt, data, TrainConfig(steps=60, log_every=0))
    first = float(np.mean(hist["loss"][:5]))
    last = float(np.mean(hist["loss"][-5:]))
    # markov token stream is learnable: must beat the unigram plateau
    assert last < first - 0.5, (first, last)
    assert np.isfinite(hist["loss"]).all()


def test_history_and_monitoring_fields():
    cfg = _cfg()
    model = build_model(cfg)
    data = TokenStream(vocab=cfg.vocab, batch=2, seq=16, seed=1)
    _, hist = train(model, opt_cfg := AdamWConfig(lr=1e-3), data,
                    TrainConfig(steps=8, log_every=0))
    assert len(hist["loss"]) == 8
    assert len(hist["step_time"]) == 8
    assert "straggler_flags" in hist
