"""Tests for sharded multi-device kPCA serving: ShardedFittedKpca
(repro.core.oos), the shard_map + psum execution path (repro.serve.sharded),
per-shard landmark compression, and the engine routing.

tests/conftest.py exposes 4 host CPU devices, so shard counts 1/2/4 all run
on a REAL mesh (shard_map + psum), not just the single-device fallback.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, oos
from repro.core.kernels_math import gram
from repro.launch.mesh import make_serving_mesh
from repro.serve import KpcaEngine, KpcaServeConfig
from repro.serve.sharded import project_sharded

SPEC = KernelSpec(kind="rbf", gamma=0.25)
N, M, C = 90, 12, 3                       # N chosen indivisible by 4


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def fitted():
    x = jnp.asarray(_rand((N, M), seed=0))
    return oos.fit_central(x, SPEC, n_components=C, center=True)


@pytest.fixture(scope="module")
def queries():
    return jnp.asarray(_rand((17, M), seed=1))


class TestShardingParity:
    @pytest.mark.parametrize("n_shards", [1, 2, 4])
    def test_matches_unsharded_on_mesh(self, fitted, queries, n_shards):
        """Sharded psum scores == FittedKpca.transform to fp32 tolerance,
        on a real CPU device mesh."""
        assert jax.device_count() >= 4, "conftest should expose 4 devices"
        sharded, err = oos.shard_fitted(fitted, n_shards)
        assert np.all(np.asarray(err) == 0.0)     # sharding alone is exact
        mesh = make_serving_mesh(n_shards)
        assert mesh is not None and mesh.devices.size == n_shards
        got = np.asarray(project_sharded(sharded, queries, mesh=mesh))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("n_shards", [2, 4])
    def test_pallas_partials_match(self, fitted, queries, n_shards):
        sharded, _ = oos.shard_fitted(fitted, n_shards)
        got = np.asarray(project_sharded(sharded, queries, use_pallas=True,
                                         interpret=True))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_uneven_partition(self, fitted, queries):
        """N=90 over 4 shards: sizes (23, 23, 22, 22), padding rows must
        contribute nothing."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        assert sum(sharded.shard_sizes) == N
        assert sharded.shard_capacity == max(sharded.shard_sizes)
        assert len(set(sharded.shard_sizes)) > 1   # actually uneven
        # indicator column is 0 exactly on padding rows
        ind = np.asarray(sharded.coefs_ext[..., -1])
        for j, n in enumerate(sharded.shard_sizes):
            assert ind[j, :n].all() and not ind[j, n:].any()
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)

    def test_single_device_fallback_same_math(self, fitted, queries):
        """mesh=None with more shards than devices falls back to the local
        reduction; scores identical to the mesh path."""
        sharded, _ = oos.shard_fitted(fitted, 8)   # > 4 devices
        assert make_serving_mesh(8) is None
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


class TestGatherAndCheckpoint:
    def test_shard_gather_roundtrip(self, fitted, queries):
        sharded, _ = oos.shard_fitted(fitted, 3)
        back = oos.gather_fitted(sharded)
        np.testing.assert_array_equal(np.asarray(back.x_support),
                                      np.asarray(fitted.x_support))
        np.testing.assert_array_equal(np.asarray(back.coefs),
                                      np.asarray(fitted.coefs))
        np.testing.assert_array_equal(np.asarray(oos.project(back, queries)),
                                      np.asarray(oos.project(fitted, queries)))

    def test_checkpoint_roundtrip(self, fitted, queries, tmp_path):
        """save -> load -> gather recovers the exact serving behavior."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        oos.save_sharded(str(tmp_path / "ck"), sharded)
        back = oos.load_sharded(str(tmp_path / "ck"))
        assert back.spec == sharded.spec
        assert back.shard_sizes == sharded.shard_sizes
        assert back.n_support == sharded.n_support
        np.testing.assert_array_equal(np.asarray(back.coefs_ext),
                                      np.asarray(sharded.coefs_ext))
        np.testing.assert_array_equal(
            np.asarray(project_sharded(back, queries)),
            np.asarray(project_sharded(sharded, queries)))
        gathered = oos.gather_fitted(back)
        np.testing.assert_allclose(
            np.asarray(oos.project(gathered, queries)),
            np.asarray(oos.project(fitted, queries)), rtol=1e-6, atol=1e-6)

    def test_load_rejects_wrong_kind(self, fitted, tmp_path):
        oos.save_fitted(str(tmp_path / "ck"), fitted)
        with pytest.raises(ValueError):
            oos.load_sharded(str(tmp_path / "ck"))


class TestPerShardCompression:
    def test_bound_dominates_actual_error(self, fitted):
        """The aggregate triangle-inequality bound must upper-bound the true
        relative RKHS error of the summed compressed component."""
        sharded, bound = oos.shard_fitted(fitted, 2, landmarks_per_shard=16)
        a_eff = np.asarray(oos.effective_coefs(fitted))
        x, g = fitted.x_support, fitted.gamma
        cm = oos.gather_fitted(sharded)               # row_mean_coef == 0
        z, beta = cm.x_support, np.asarray(cm.coefs)
        kxx = np.asarray(gram(SPEC, x, gamma=g))
        kzz = np.asarray(gram(SPEC, z, gamma=g))
        kxz = np.asarray(gram(SPEC, x, z, gamma=g))
        w2 = np.sum(a_eff * (kxx @ a_eff), axis=0)
        wh2 = np.sum(beta * (kzz @ beta), axis=0)
        cross = np.sum(a_eff * (kxz @ beta), axis=0)
        actual = np.sqrt(np.clip(w2 + wh2 - 2 * cross, 0.0, None) / w2)
        assert (np.asarray(bound) >= actual - 1e-5).all(), (bound, actual)

    def test_bound_monotone_in_landmarks(self, fitted):
        """Per-shard nested landmark schedules => the aggregate bound is
        monotone non-increasing in the per-shard budget."""
        bounds = []
        for n_l in (8, 16, 32, 45):
            _, b = oos.shard_fitted(fitted, 2, landmarks_per_shard=n_l,
                                    seed=0)
            bounds.append(np.asarray(b))
        for lo, hi in zip(bounds[1:], bounds[:-1]):
            assert (lo <= hi + 1e-5).all(), (lo, hi)

    def test_full_budget_recovers_exact_scores(self, fitted, queries):
        """landmarks_per_shard >= every shard size => projection is onto the
        full span, so scores match the uncompressed model."""
        sharded, bound = oos.shard_fitted(fitted, 3, landmarks_per_shard=N)
        assert float(np.max(np.asarray(bound))) < 1e-2
        got = np.asarray(project_sharded(sharded, queries))
        want = np.asarray(oos.project(fitted, queries))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_compressed_serving_cost_shrinks(self, fitted):
        sharded, _ = oos.shard_fitted(fitted, 4, landmarks_per_shard=8)
        assert sharded.shard_capacity == 8
        assert sharded.n_support == 32
        assert np.all(np.asarray(sharded.row_mean_coef) == 0.0)


class TestEngineRouting:
    def test_engine_serves_sharded_model(self, fitted):
        """KpcaEngine results over a sharded model match the unsharded
        engine request-for-request."""
        sharded, _ = oos.shard_fitted(fitted, 4)
        reqs = [_rand((q, M), seed=10 + q) for q in (3, 11, 26)]
        ref_eng = KpcaEngine(fitted, KpcaServeConfig(max_batch=16,
                                                     min_bucket=8))
        sh_eng = KpcaEngine(sharded, KpcaServeConfig(max_batch=16,
                                                     min_bucket=8))
        want = ref_eng.project_many(reqs)
        got = sh_eng.project_many(reqs)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=3e-4, atol=3e-4)
        assert sh_eng.stats.n_requests == 3
        assert sh_eng.stats.n_queries == 3 + 11 + 26

    def test_engine_rejects_mesh_for_plain_model(self, fitted):
        mesh = make_serving_mesh(1)
        with pytest.raises(ValueError):
            KpcaEngine(fitted, mesh=mesh)


class TestValidation:
    def test_rejects_bad_shard_count(self, fitted):
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, 0)
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, N + 1)
        with pytest.raises(ValueError):
            oos.shard_fitted(fitted, 2, landmarks_per_shard=0)
