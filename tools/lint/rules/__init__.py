"""Rule modules register themselves on import (``@register``)."""

from . import concurrency, jaxrules, obs, testing  # noqa: F401

__all__ = ["concurrency", "jaxrules", "obs", "testing"]
