"""Pure-SSM language model (falcon-mamba: mamba1 stack, attention-free).

Decode keeps O(1) state per layer (conv ring + (d_inner, N) ssm state) —
the long_500k shape runs at constant memory regardless of context length.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import (ParamCollector, ScanBlock, StackedCollector,
                     constrain_act, dtype_of, rms_norm, slice_layer)
from .mamba import (Mamba1State, init_mamba1, mamba1_decode, mamba1_forward,
                    mamba1_init_state)


def ssm_prefill(params, cfg, batch, max_len: int, mesh=None,
                cache_dtype=None):
    """Parallel prefill: chunked forward over the whole prompt, emitting the
    per-layer recurrent states for decode continuation (production path —
    NOT the sequential per-token recurrence)."""
    import jax.numpy as _jnp
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))

    def block(p, carry):
        xx = carry
        h = rms_norm(xx, p["ln"], cfg.norm_eps)
        y, st = mamba1_forward(slice_layer(p, "mamba"), cfg, h,
                               return_state=True)
        return xx + y, (st.conv, st.ssm)

    stacked = slice_layer(params, "layers")
    x, (conv_n, ssm_n) = ScanBlock.run(block, stacked, x, remat="none",
                                       unroll=cfg.unroll_scans)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _jnp.einsum("bse,ev->bsv", x[:, -1:],
                         head.astype(x.dtype))[:, -1]
    return logits, (conv_n, ssm_n)


def init_ssm_lm(cfg: ArchConfig, key: jax.Array, mesh=None):
    col = ParamCollector(key, dtype_of(cfg.param_dtype))
    e = cfg.d_model
    col.param("embed", (cfg.vocab, e), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        col.param("lm_head", (e, cfg.vocab), ("embed", "vocab"), scale=0.02)
    col.param("final_norm", (e,), (None,), init="ones")
    sub = StackedCollector(col, cfg.n_layers, "layers")
    init_mamba1(sub, cfg, "mamba")
    sub.param("ln", (e,), (None,), init="ones")
    return col.params, col.axes


def _block(cfg: ArchConfig, mesh=None):
    def block(p, carry):
        x = carry
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y = mamba1_forward(slice_layer(p, "mamba"), cfg, h)
        return constrain_act(x + y, mesh), None
    return block


def ssm_lm_loss(params, cfg: ArchConfig, batch, mesh=None):
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    stacked = slice_layer(params, "layers")
    x = constrain_act(x, mesh)
    x, _ = ScanBlock.run(_block(cfg, mesh), stacked, x, remat=cfg.remat,
                         unroll=cfg.unroll_scans)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bse,ev->bsv", x, head.astype(x.dtype))
    targets = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    return loss, {"loss": loss}


def ssm_init_cache(cfg: ArchConfig, batch: int, max_len: int = 0,
                   dtype=jnp.bfloat16):
    st = mamba1_init_state(cfg, batch, dtype)
    l = cfg.n_layers
    return (jnp.zeros((l,) + st.conv.shape, st.conv.dtype),
            jnp.zeros((l,) + st.ssm.shape, st.ssm.dtype))


def ssm_decode_step(params, cfg: ArchConfig, cache, tokens, cache_len,
                    mesh=None):
    """tokens (B, S) — decode (S=1) or prefill (runs tokens sequentially
    chunk-free via the recurrent path only when S==1; for prefill we use the
    chunked forward on the prompt then a state-rebuild pass)."""
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))

    def step(carry, xs):
        p, conv_c, ssm_c = xs
        h = rms_norm(carry, p["ln"], cfg.norm_eps)
        y, st = mamba1_decode(slice_layer(p, "mamba"), cfg, h,
                              Mamba1State(conv_c, ssm_c))
        return carry + y, (st.conv, st.ssm)

    stacked = slice_layer(params, "layers")
    if x.shape[1] == 1:
        x_out, (conv_n, ssm_n) = jax.lax.scan(
            step, x, (stacked, cache[0], cache[1]),
            unroll=cfg.unroll_scans)
    else:
        # prefill: run each position through the recurrent step via scan over
        # time (states are the only carry — memory-safe for long prompts)
        def time_step(state, xt):
            conv_c, ssm_c = state
            xo, (cn, sn) = jax.lax.scan(step, xt[:, None],
                                        (stacked, conv_c, ssm_c))
            return (cn, sn), xo[:, 0]

        (conv_n, ssm_n), ys = jax.lax.scan(
            time_step, (cache[0], cache[1]), jnp.moveaxis(x, 1, 0))
        x_out = jnp.moveaxis(ys, 0, 1)[:, -1:]
    x_out = rms_norm(x_out, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bse,ev->bsv", x_out,
                        head.astype(x_out.dtype))[:, -1]
    return logits, (conv_n, ssm_n)
