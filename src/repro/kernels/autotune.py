"""Measured-search autotuner for the Pallas kernel tile sizes.

Every kernel wrapper in this package (``gram_op``, ``project_op``/
``project_partial_op``, ``center_op``) historically ran one hardcoded
tiling (128x128x512, centering 256). That is a fine default on TPU-sized
problems and provably NOT optimal everywhere else — tile choice is a
hardware/shape question, so it is answered by measurement:

  * a candidate grid per op, filtered by legality for the concrete padded
    problem (sublane multiples of 8, lane multiples of 128, no tile wider
    than the padded axis — anything larger is the same program after the
    wrappers' auto-shrink);
  * each candidate timed best-of-``k`` with ``jax.block_until_ready`` on
    the actual output (compile excluded by an untimed warmup call);
  * winners persisted to a JSON **tile table** keyed by
    ``(op, pow2-shape-bucket, dtype, backend)`` and loaded transparently
    by the wrappers — a tuned entry changes the dispatch of every later
    call with that key, callers change nothing;
  * no entry -> the historical defaults, so an empty/missing table is
    exactly the pre-autotune behavior.

Point ``REPRO_TILE_TABLE`` at a table file to load it process-wide (read
once, before the first kernel dispatch), or install one programmatically
with ``set_default_table``. ``python -m repro.kernels.autotune --out
tile_table.json`` searches the standard serving shapes; tuning a shape
whose key is already in the table is a cache hit and re-runs nothing
(``force=True`` overrides).

Observability: every trial bumps ``autotune_trials_total`` and runs under
an ``autotune.<op>`` trace span; cache hits bump ``autotune_cached_total``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import metrics, trace

# Historical fixed tilings — the fallback for every key the table misses.
DEFAULT_TILES: Dict[str, Dict[str, int]] = {
    "gram": {"block_n": 128, "block_k": 128, "block_m": 512},
    "project": {"block_q": 128, "block_l": 128, "block_m": 512},
    "project_partial": {"block_q": 128, "block_l": 128, "block_m": 512},
    "centering": {"block": 256},
}

TABLE_ENV_VAR = "REPRO_TILE_TABLE"
TABLE_VERSION = 1

_m_trials = metrics.counter(
    "autotune_trials_total", "Tile candidates timed by the autotuner")
_m_cached = metrics.counter(
    "autotune_cached_total", "Tune requests answered from the tile table")


def _pow2_bucket(n: int, floor: int = 8) -> int:
    """Smallest power of two >= n (and >= floor) — the shape-bucket axis
    of a tile-table key. Serving already quantizes batch to pow2 buckets,
    so in steady state the bucket IS the padded shape."""
    b = floor
    while b < n:
        b *= 2
    return b


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    """Bucket every dim of ``shape`` to a power of two."""
    return tuple(_pow2_bucket(int(d)) for d in shape)


def table_key(op: str, shape: Sequence[int], dtype: Any,
              backend: str) -> str:
    """Canonical JSON key: ``op|d1xd2x...|dtype|backend`` with the shape
    pow2-bucketed."""
    dims = "x".join(str(d) for d in shape_bucket(shape))
    return f"{op}|{dims}|{np.dtype(dtype).name}|{backend}"


@dataclasses.dataclass
class Trial:
    """One timed candidate: its block sizes and best-of-k seconds."""
    blocks: Dict[str, int]
    seconds: float


class TileTable:
    """In-memory tile table with JSON round-trip.

    ``entries`` maps ``table_key`` strings to block-size dicts (plus the
    winning ``us`` for provenance). Thread-safety: lookups are plain dict
    reads (safe under the GIL); tuning writes happen before serving
    traffic in any sane deployment, and a racy overwrite of identical
    data is harmless.
    """

    def __init__(self, entries: Optional[Dict[str, Dict[str, Any]]] = None):
        self.entries: Dict[str, Dict[str, Any]] = dict(entries or {})

    # -- persistence --------------------------------------------------------

    @classmethod
    def load(cls, path: str) -> "TileTable":
        with open(path) as f:
            payload = json.load(f)
        if payload.get("version") != TABLE_VERSION:
            raise ValueError(
                f"tile table {path}: version {payload.get('version')!r} "
                f"!= supported {TABLE_VERSION}")
        return cls(payload.get("entries", {}))

    def save(self, path: str) -> None:
        payload = {"version": TABLE_VERSION, "entries": self.entries}
        with open(path, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
            f.write("\n")

    # -- lookup/update ------------------------------------------------------

    def lookup(self, op: str, shape: Sequence[int], dtype: Any,
               backend: str) -> Optional[Dict[str, int]]:
        hit = self.entries.get(table_key(op, shape, dtype, backend))
        if hit is None:
            return None
        return {k: int(v) for k, v in hit.items() if k.startswith("block")}

    def put(self, op: str, shape: Sequence[int], dtype: Any, backend: str,
            blocks: Dict[str, int], seconds: float) -> str:
        key = table_key(op, shape, dtype, backend)
        self.entries[key] = dict(blocks, us=round(seconds * 1e6, 3))
        return key

    def __len__(self) -> int:
        return len(self.entries)


# Process-wide table, initialized lazily from $REPRO_TILE_TABLE so launch
# env configuration (launch/env.py) can point every process of a
# deployment at one tuned table without code changes.
_default_table: Optional[TileTable] = None


def default_table() -> TileTable:
    global _default_table
    if _default_table is None:
        path = os.environ.get(TABLE_ENV_VAR)
        if path and os.path.exists(path):
            _default_table = TileTable.load(path)
        else:
            _default_table = TileTable()
    return _default_table


def set_default_table(table: Optional[TileTable]) -> None:
    """Install (or with None: reset, re-reading $REPRO_TILE_TABLE on next
    use) the process-wide table."""
    global _default_table
    _default_table = table


def get_tiles(op: str, shape: Sequence[int], dtype: Any,
              table: Optional[TileTable] = None) -> Dict[str, int]:
    """Tile sizes for one dispatch: table hit for this (op, shape-bucket,
    dtype, backend), else the historical defaults. This is the hook the
    ``ops.py`` wrappers call when no explicit block sizes are passed."""
    import jax
    backend = jax.default_backend()
    t = table if table is not None else default_table()
    hit = t.lookup(op, shape, dtype, backend)
    if hit is not None:
        return dict(DEFAULT_TILES[op], **hit)
    return dict(DEFAULT_TILES[op])


# ---- candidate grids ------------------------------------------------------

def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def gram_candidates(n: int, k: int, m: int) -> List[Dict[str, int]]:
    """Legal (block_n, block_k, block_m) grid for an (n, m) x (k, m) gram.

    Legality: row tiles are multiples of 8 (sublane), feature tiles
    multiples of 128 (lane); tiles beyond the padded axis are dropped —
    the wrapper's auto-shrink maps them to the same program as the
    axis-sized tile, so timing them twice is pure waste.
    """
    np_, kp, mp = _round_up(n, 8), _round_up(k, 8), _round_up(m, 128)
    bns = [b for b in (8, 16, 32, 64, 128, 256) if b <= np_] or [np_]
    bks = [b for b in (8, 16, 32, 64, 128, 256) if b <= kp] or [kp]
    bms = [b for b in (128, 256, 512) if b <= mp] or [mp]
    return [{"block_n": bn, "block_k": bk, "block_m": bm}
            for bn in bns for bk in bks for bm in bms]


def project_candidates(b: int, l: int, m: int) -> List[Dict[str, int]]:
    """Legal (block_q, block_l, block_m) grid for a (b, m) query batch
    against an (l, m) support set (same legality rules as gram)."""
    bp, lp, mp = _round_up(b, 8), _round_up(l, 8), _round_up(m, 128)
    bqs = [x for x in (8, 16, 32, 64, 128, 256) if x <= bp] or [bp]
    bls = [x for x in (8, 16, 32, 64, 128, 256) if x <= lp] or [lp]
    bms = [x for x in (128, 256, 512) if x <= mp] or [mp]
    return [{"block_q": bq, "block_l": bl, "block_m": bm}
            for bq in bqs for bl in bls for bm in bms]


def centering_candidates(n: int, m: int) -> List[Dict[str, int]]:
    """Legal square-ish block grid for centering an (n, m) kernel matrix
    (one knob: the wrapper derives row/col tiles from it)."""
    np_ = _round_up(n, 8)
    return [{"block": b} for b in (64, 128, 256, 512)
            if b <= max(np_, 128)] or [{"block": np_}]


# ---- measurement ----------------------------------------------------------

def _time_best_of(fn, args, k: int) -> float:
    """Best-of-k wall seconds for ``fn(*args)``, blocked on the REAL
    output; one untimed warmup call eats the compile."""
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(k):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _search(op: str, shape: Sequence[int], dtype: Any,
            candidates: List[Dict[str, int]], build_fn, k: int,
            table: Optional[TileTable], force: bool
            ) -> Tuple[Dict[str, int], List[Trial]]:
    """Shared search loop: cache-check, time every candidate, commit the
    winner. ``build_fn(blocks)`` returns a (fn, args) pair ready to time."""
    import jax
    backend = jax.default_backend()
    t = table if table is not None else default_table()
    if not force:
        hit = t.lookup(op, shape, dtype, backend)
        if hit is not None:
            _m_cached.inc()
            return dict(DEFAULT_TILES[op], **hit), []
    trials: List[Trial] = []
    with trace.span(f"autotune.{op}", shape=list(shape),
                    n_candidates=len(candidates)):
        for blocks in candidates:
            fn, args = build_fn(blocks)
            seconds = _time_best_of(fn, args, k)
            trials.append(Trial(dict(blocks), seconds))
            _m_trials.inc()
            if trace.is_enabled():
                trace.instant(f"autotune.{op}.trial", **blocks,
                              us=round(seconds * 1e6, 2))
    best = min(trials, key=lambda tr: tr.seconds)
    t.put(op, shape, dtype, backend, best.blocks, best.seconds)
    return dict(DEFAULT_TILES[op], **best.blocks), trials


def tune_gram(spec, x, y=None, gamma=None, interpret=None, k: int = 3,
              table: Optional[TileTable] = None, force: bool = False,
              candidates: Optional[List[Dict[str, int]]] = None
              ) -> Tuple[Dict[str, int], List[Trial]]:
    """Search the gram tile grid for this concrete problem; returns
    (winning blocks, trials — empty on a table cache hit)."""
    import jax
    from .gram.ops import gram_op
    yy = x if y is None else y
    shape = (x.shape[0], yy.shape[0], x.shape[1])
    cands = candidates if candidates is not None else gram_candidates(*shape)

    def build(blocks):
        fn = jax.jit(lambda xa, ya: gram_op(
            spec, xa, ya, gamma=gamma, interpret=interpret, **blocks))
        return fn, (x, yy)

    return _search("gram", shape, x.dtype, cands, build, k, table, force)


def tune_project(spec, x_query, x_support, coefs, row_mean_coef=None,
                 bias=None, gamma=None, interpret=None, k: int = 3,
                 table: Optional[TileTable] = None, force: bool = False,
                 candidates: Optional[List[Dict[str, int]]] = None
                 ) -> Tuple[Dict[str, int], List[Trial]]:
    """Search the fused-projection tile grid (serving hot path)."""
    import jax
    from .project.ops import project_op
    shape = (x_query.shape[0], x_support.shape[0], x_query.shape[1])
    cands = candidates if candidates is not None \
        else project_candidates(*shape)

    def build(blocks):
        fn = jax.jit(lambda xq: project_op(
            spec, xq, x_support, coefs, row_mean_coef=row_mean_coef,
            bias=bias, gamma=gamma, interpret=interpret, **blocks))
        return fn, (x_query,)

    return _search("project", shape, x_query.dtype, cands, build, k,
                   table, force)


def tune_project_partial(spec, x_query, x_support, coefs_ext, gamma=None,
                         interpret=None, k: int = 3,
                         table: Optional[TileTable] = None,
                         force: bool = False,
                         candidates: Optional[List[Dict[str, int]]] = None
                         ) -> Tuple[Dict[str, int], List[Trial]]:
    """Search the per-shard partial-projection tile grid."""
    import jax
    from .project.ops import project_partial_op
    shape = (x_query.shape[0], x_support.shape[0], x_query.shape[1])
    cands = candidates if candidates is not None \
        else project_candidates(*shape)

    def build(blocks):
        fn = jax.jit(lambda xq: project_partial_op(
            spec, xq, x_support, coefs_ext, gamma=gamma,
            interpret=interpret, **blocks))
        return fn, (x_query,)

    return _search("project_partial", shape, x_query.dtype, cands, build,
                   k, table, force)


def tune_centering(k_matrix, k: int = 3,
                   table: Optional[TileTable] = None, force: bool = False,
                   candidates: Optional[List[Dict[str, int]]] = None
                   ) -> Tuple[Dict[str, int], List[Trial]]:
    """Search the centering block grid for an (n, m) kernel matrix."""
    import jax
    from .centering.ops import center_op
    shape = tuple(k_matrix.shape)
    cands = candidates if candidates is not None \
        else centering_candidates(*shape)

    def build(blocks):
        fn = jax.jit(lambda km: center_op(km, interpret=None, **blocks))
        return fn, (k_matrix,)

    return _search("centering", shape, k_matrix.dtype, cands, build, k,
                   table, force)


# ---- CLI ------------------------------------------------------------------

def _standard_serving_shapes(m: int, landmarks: int, max_batch: int):
    """The pow2 serving buckets the engines actually dispatch."""
    b = 8
    while b < max_batch:
        yield b
        b *= 2
    yield max_batch


ALL_OPS = ("gram", "project", "project_partial", "centering")


def main(argv=None) -> None:
    """``python -m repro.kernels.autotune --out tile_table.json``: tune
    gram/project/project_partial/centering over the standard serving
    shapes and persist the table. Rerunning against an existing table only
    fills gaps; ``--assert-cached`` turns the rerun into a CI check that
    every requested key really answers from the table (0 trials)."""
    import argparse
    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--out", default="tile_table.json")
    ap.add_argument("--m", type=int, default=64, help="feature dim")
    ap.add_argument("--landmarks", type=int, default=256,
                    help="support-set rows for project/gram")
    ap.add_argument("--max-batch", type=int, default=128,
                    help="widest serving bucket")
    ap.add_argument("--shards", type=int, default=4,
                    help="shard count sizing project_partial's per-shard "
                         "support slice (sharded serving dispatches the "
                         "partial op at landmarks/shards rows)")
    ap.add_argument("--ops", nargs="*", default=None, choices=ALL_OPS,
                    help="subset of ops to tune (default: all)")
    ap.add_argument("--k", type=int, default=3, help="timing repeats")
    ap.add_argument("--force", action="store_true",
                    help="re-search keys already in the table")
    ap.add_argument("--assert-cached", action="store_true",
                    help="fail unless every requested key is already a "
                         "table hit — the CI cache-hit assertion")
    args = ap.parse_args(argv)

    from ..core.kernels_math import KernelSpec
    spec = KernelSpec(kind="rbf", gamma=0.5)
    table = TileTable.load(args.out) if os.path.exists(args.out) \
        else TileTable()
    want = set(args.ops) if args.ops else set(ALL_OPS)
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(args.landmarks, args.m)).astype(np.float32)
    coefs = rng.normal(size=(args.landmarks, 4)).astype(np.float32)
    n_trials = 0

    if "gram" in want:
        blocks, trials = tune_gram(spec, xs, k=args.k, table=table,
                                   force=args.force)
        n_trials += len(trials)
        print(f"gram {xs.shape}: {blocks} ({len(trials)} trials)")
    # Per-shard slice for the sharded partial op: the serving path calls
    # project_partial_op with each shard's Lp = ceil(L/S) support rows.
    lp = max(8, -(-args.landmarks // max(args.shards, 1)))
    xs_shard = rng.normal(size=(lp, args.m)).astype(np.float32)
    coefs_ext = rng.normal(size=(lp, 5)).astype(np.float32)
    for b in _standard_serving_shapes(args.m, args.landmarks,
                                      args.max_batch):
        xq = rng.normal(size=(b, args.m)).astype(np.float32)
        if "project" in want:
            blocks, trials = tune_project(spec, xq, xs, coefs, k=args.k,
                                          table=table, force=args.force)
            n_trials += len(trials)
            print(f"project b={b}: {blocks} ({len(trials)} trials)")
        if "project_partial" in want:
            blocks, trials = tune_project_partial(
                spec, xq, xs_shard, coefs_ext, k=args.k, table=table,
                force=args.force)
            n_trials += len(trials)
            print(f"project_partial b={b} (Lp={lp}): {blocks} "
                  f"({len(trials)} trials)")
    if "centering" in want:
        km = rng.normal(size=(args.landmarks, args.landmarks)) \
            .astype(np.float32)
        blocks, trials = tune_centering(km, k=args.k, table=table,
                                        force=args.force)
        n_trials += len(trials)
        print(f"centering {km.shape}: {blocks} ({len(trials)} trials)")
    table.save(args.out)
    print(f"wrote {len(table)} entries -> {args.out}")
    if args.assert_cached and n_trials:
        raise SystemExit(
            f"--assert-cached: expected every key to hit the table, but "
            f"{n_trials} trials ran (stale or missing entries)")


__all__ = [
    "DEFAULT_TILES", "TABLE_ENV_VAR", "TileTable", "Trial",
    "centering_candidates", "default_table", "get_tiles",
    "gram_candidates", "project_candidates", "set_default_table",
    "shape_bucket", "table_key", "tune_centering", "tune_gram",
    "tune_project", "tune_project_partial",
]

if __name__ == "__main__":
    main()
