"""Unit + property tests for kernel math (paper §1, §3.1, §6.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (KernelSpec, center_gram, central_kpca, gram,
                        pairwise_sqdist, psd_jitter_eigh, resolve_gamma,
                        topk_eigh)

jax.config.update("jax_enable_x64", False)


def _rand(shape, seed=0, scale=1.0):
    return scale * np.random.default_rng(seed).normal(size=shape).astype(np.float32)


class TestPairwiseSqdist:
    def test_matches_naive(self):
        x, y = _rand((17, 5), 0), _rand((9, 5), 1)
        d = pairwise_sqdist(jnp.asarray(x), jnp.asarray(y))
        naive = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
        np.testing.assert_allclose(np.asarray(d), naive, rtol=1e-4, atol=1e-4)

    def test_nonnegative_zero_diag(self):
        x = _rand((32, 8), 2)
        d = np.asarray(pairwise_sqdist(jnp.asarray(x), jnp.asarray(x)))
        assert (d >= 0).all()
        np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)


class TestGram:
    @pytest.mark.parametrize("kind", ["rbf", "linear", "poly"])
    def test_normalized_diag_is_one(self, kind):
        # Paper §3.1 requires K(x, x) = 1.
        spec = KernelSpec(kind=kind, gamma=0.5, normalize=True)
        x = _rand((20, 6), 3)
        k = np.asarray(gram(spec, jnp.asarray(x)))
        np.testing.assert_allclose(np.diag(k), 1.0, atol=1e-4)

    @pytest.mark.parametrize("kind", ["rbf", "linear"])
    def test_symmetric_psd(self, kind):
        spec = KernelSpec(kind=kind, gamma=0.3)
        x = _rand((24, 4), 4)
        k = np.asarray(gram(spec, jnp.asarray(x)))
        np.testing.assert_allclose(k, k.T, atol=1e-5)
        ev = np.linalg.eigvalsh(k)
        assert ev.min() > -1e-4

    def test_rbf_values(self):
        spec = KernelSpec(kind="rbf", gamma=0.25)
        x = np.array([[0.0, 0.0], [1.0, 1.0]], np.float32)
        k = np.asarray(gram(spec, jnp.asarray(x)))
        np.testing.assert_allclose(k[0, 1], np.exp(-0.25 * 2.0), rtol=1e-5)

    def test_median_heuristic_positive(self):
        x = _rand((50, 10), 5)
        g = float(resolve_gamma(KernelSpec(kind="rbf"), jnp.asarray(x)))
        assert g > 0

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(3, 24), m=st.integers(1, 12), seed=st.integers(0, 99))
    def test_property_rbf_range_and_psd(self, n, m, seed):
        x = _rand((n, m), seed)
        k = np.asarray(gram(KernelSpec(kind="rbf", gamma=0.7), jnp.asarray(x)))
        assert (k <= 1.0 + 1e-5).all() and (k >= 0.0).all()
        assert np.linalg.eigvalsh(k).min() > -1e-4


class TestCentering:
    def test_row_col_means_zero(self):
        x = _rand((15, 7), 6)
        k = gram(KernelSpec(gamma=0.4), jnp.asarray(x))
        kc = np.asarray(center_gram(k))
        np.testing.assert_allclose(kc.mean(0), 0.0, atol=1e-5)
        np.testing.assert_allclose(kc.mean(1), 0.0, atol=1e-5)

    def test_idempotent(self):
        x = _rand((12, 5), 7)
        k = gram(KernelSpec(gamma=0.4), jnp.asarray(x))
        k1 = center_gram(k)
        k2 = center_gram(k1)
        np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=1e-5)

    def test_rectangular_block(self):
        x, y = _rand((10, 4), 8), _rand((6, 4), 9)
        k = gram(KernelSpec(gamma=0.4), jnp.asarray(x), jnp.asarray(y))
        kc = np.asarray(center_gram(k))
        assert kc.shape == (10, 6)
        np.testing.assert_allclose(kc.mean(), 0.0, atol=1e-5)


class TestEigh:
    def test_topk_matches_numpy(self):
        a = _rand((16, 16), 10)
        a = a @ a.T
        lam, vec = topk_eigh(jnp.asarray(a), 3)
        ref = np.linalg.eigvalsh(a)[::-1][:3]
        np.testing.assert_allclose(np.asarray(lam), ref, rtol=1e-3)
        for i in range(3):
            v = np.asarray(vec[:, i])
            np.testing.assert_allclose(a @ v, ref[i] * v, rtol=2e-2, atol=1e-3)

    def test_jitter_floors_spectrum(self):
        a = np.zeros((8, 8), np.float32)
        a[0, 0] = 4.0  # rank-1
        lam, _ = psd_jitter_eigh(jnp.asarray(a), rel_eps=1e-3)
        assert float(lam[0]) >= 1e-3 * 4.0 - 1e-6


class TestCentralKpca:
    def test_alpha_normalization(self):
        # Paper §1: ||alpha|| = 1/sqrt(lambda_1) so that ||w*|| = 1.
        x = jnp.asarray(_rand((30, 6), 11))
        alpha, lam, k = central_kpca(x, KernelSpec(gamma=0.3), 2)
        for i in range(2):
            n = float(jnp.linalg.norm(alpha[:, i]))
            np.testing.assert_allclose(n, 1.0 / np.sqrt(float(lam[i])), rtol=1e-4)
            # ||w||^2 = alpha^T K alpha = 1
            w2 = float(alpha[:, i] @ k @ alpha[:, i])
            np.testing.assert_allclose(w2, 1.0, rtol=1e-3)

    def test_first_component_dominates_variance(self):
        x = jnp.asarray(_rand((40, 5), 12))
        alpha, lam, k = central_kpca(x, KernelSpec(gamma=0.3), 3)
        # projections variance == eigenvalue ordering
        assert float(lam[0]) >= float(lam[1]) >= float(lam[2]) > 0
