"""Fault tolerance: node-failure re-knit convergence, train-loop
checkpoint/restart determinism, NaN-guard skip, straggler monitor."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (KernelSpec, build_setup, central_kpca, run_admm,
                        similarity)
from repro.core.topology import reknit, ring
from repro.data import node_dataset
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import StragglerMonitor, TrainConfig, train
from repro.train.loop import build_train_step

SPEC = KernelSpec(kind="rbf")


class TestNodeFailure:
    def test_reknit_converges_on_survivors(self):
        """Kill 2 of 12 nodes; survivors re-knit and still reach the
        (surviving-data) central solution — the decentralized algorithm has
        no fusion center to lose."""
        nodes, _ = node_dataset(12, 40, m=24, seed=4)
        graph = ring(12, hops=2)
        g2, survivors = reknit(graph, [3, 7])
        nodes2 = np.asarray(nodes)[survivors]
        pooled2 = nodes2.reshape(-1, nodes2.shape[-1])
        setup = build_setup(jnp.asarray(nodes2), g2, SPEC)
        ag, _, _ = central_kpca(jnp.asarray(pooled2), SPEC, 1,
                                gamma=setup.gamma)
        res = run_admm(setup, n_iters=40)
        sims = [float(similarity(res.alpha[j], jnp.asarray(nodes2[j]),
                                 ag[:, 0], jnp.asarray(pooled2), SPEC,
                                 gamma=setup.gamma))
                for j in range(len(survivors))]
        assert np.mean(sims) > 0.85, sims


def _tiny_cfg():
    return ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab=128,
                      head_dim=16, tie_embeddings=True, remat="none",
                      param_dtype="float32", compute_dtype="float32")


class TestCheckpointRestart:
    def test_resume_is_deterministic(self, tmp_path):
        """Train 6 steps straight vs. 3 steps + kill + resume 3 steps: the
        final params must be bitwise identical (data iterator state is part
        of the checkpoint)."""
        cfg = _tiny_cfg()
        opt = AdamWConfig(lr=1e-2)

        def run(steps, ckpt_dir, fresh):
            model = build_model(cfg)
            data = TokenStream(vocab=cfg.vocab, batch=2, seq=16, seed=1)
            tcfg = TrainConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=3,
                               log_every=0)
            state, _ = train(model, opt, data, tcfg)
            return state

        s_straight = run(6, str(tmp_path / "a"), True)
        # interrupted run: first 3 steps (checkpoint at 3), then resume to 6
        run(3, str(tmp_path / "b"), True)
        s_resumed = run(6, str(tmp_path / "b"), False)
        for k in s_straight["params"]:
            np.testing.assert_array_equal(
                np.asarray(s_straight["params"][k]),
                np.asarray(s_resumed["params"][k]), err_msg=k)

    def test_nan_guard_skips_bad_step(self):
        cfg = _tiny_cfg()
        model = build_model(cfg)
        _, step_fn = build_train_step(model, AdamWConfig(lr=1e-2))
        init_fn, _ = build_train_step(model, AdamWConfig(lr=1e-2))
        state, _ = init_fn(jax.random.PRNGKey(0))
        good = TokenStream(vocab=cfg.vocab, batch=2, seq=16, seed=0).next_batch()
        before = np.asarray(state["params"]["embed"])
        # poison the embedding gradient path via a NaN label trick: feed
        # out-of-range labels -> gather produces garbage but finite; instead
        # poison params to force a NaN loss
        bad_state = dict(state)
        bad_state["params"] = dict(state["params"])
        bad_state["params"]["final_norm"] = state["params"][
            "final_norm"] * jnp.nan
        new_state, metrics = step_fn(bad_state, good)
        assert bool(metrics["skipped"])
        # parameters unchanged for skipped step
        np.testing.assert_array_equal(
            np.asarray(new_state["params"]["embed"]),
            np.asarray(bad_state["params"]["embed"]))


class TestStraggler:
    def test_monitor_flags_slow_steps(self):
        m = StragglerMonitor(factor=3.0)
        for _ in range(10):
            m.record(0.1)
        assert m.record(0.5) is True
        assert m.flagged == 1
        assert m.record(0.11) is False
