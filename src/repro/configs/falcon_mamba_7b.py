"""falcon-mamba-7b [ssm] 64L d_model=4096 (attention-free) vocab=65024,
ssm_state=16 — mamba1 architecture [arXiv:2410.05355; unverified]."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=65024, head_dim=64,
        attn_kind="none", mamba_version=1, ssm_state=16, d_inner=8192,
        d_conv=4, dt_rank=256, tie_embeddings=True)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="falcon-mamba-7b-smoke", family="ssm", n_layers=3, d_model=64,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=512, head_dim=16,
        attn_kind="none", mamba_version=1, ssm_state=8, d_inner=128,
        d_conv=4, dt_rank=8, ssm_chunk=8, tie_embeddings=True, remat="none")
