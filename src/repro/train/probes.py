"""Decentralized activation spectroscopy — the paper's DKPCA as a training
probe (DESIGN.md §4).

Each data-parallel shard treats its pooled activation minibatch as the local
dataset X_j of a network node; the probe runs a few ADMM iterations of
decentralized kernel PCA over the ``data`` mesh axis (collective_permute
ring) and reports, per node, the kernel-PCA participation of its batch —
WITHOUT gathering activations (bandwidth O(|Omega| N) per node, privacy-
preserving). On a single device it falls back to the vectorized simulator.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..core import KernelSpec, RhoSchedule, build_setup, run_admm
from ..core.dkpca import dkpca_distributed
from ..core.topology import ring


def pooled_activations(params, tokens):
    """Cheap representation proxy: mean-pooled token embeddings (B, E).
    (For full residual-stream probes, tap model internals instead.)"""
    emb = params["embed"]
    return jnp.mean(emb[tokens].astype(jnp.float32), axis=1)


def activation_probe(params, batch, mesh=None, axis_names=("data",),
                     hops: int = 1, n_iters: int = 8,
                     samples_per_node: int = 32,
                     spec: Optional[KernelSpec] = None):
    """Returns dict of probe metrics (all computed decentralized)."""
    spec = spec or KernelSpec(kind="rbf")
    acts = pooled_activations(params, batch["tokens"])    # (B, E)
    b = acts.shape[0]
    if mesh is not None:
        j = int(np.prod([mesh.shape[a] for a in axis_names]))
    else:
        j = max(b // samples_per_node, 3)
    n = min(samples_per_node, b // j)
    if n < 4 or j < 3:
        return {"skipped": True}
    x_nodes = acts[: j * n].reshape(j, n, -1)

    if mesh is not None and j >= 2 * hops + 1:
        res = dkpca_distributed(x_nodes, mesh, axis_names, hops=hops,
                                spec=spec, n_iters=n_iters)
        alpha = res.alpha
        residual = float(res.primal_residual[-1])
    else:
        graph = ring(j, hops=min(hops, (j - 1) // 2) or 1)
        setup = build_setup(x_nodes, graph, spec)
        res = run_admm(setup, n_iters=n_iters, rho2=RhoSchedule())
        alpha = res.alpha
        residual = float(res.primal_residual[-1])
    # participation: per-node projection energy of the consensus component
    energy = jnp.linalg.norm(alpha, axis=1)
    return {
        "skipped": False,
        "consensus_residual": residual,
        "participation_mean": float(jnp.mean(energy)),
        "participation_cv": float(jnp.std(energy)
                                  / jnp.maximum(jnp.mean(energy), 1e-9)),
    }
