"""Pallas TPU kernel: blocked Gram (kernel) matrix computation.

The paper's setup phase computes K(X_p, X_q) for all neighbor pairs — a
matmul-shaped hotspot: ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y, with an exp
epilogue for RBF. On TPU we tile the (n, k) output into MXU-aligned VMEM
blocks, loop the contraction (feature) dimension as the innermost grid axis
accumulating into the output block, and fuse the distance/exp epilogue into
the final contraction step — one HBM write per output tile, no materialized
distance matrix.

Grid: (n/bn, k/bk, m/bm), dimension_semantics = (parallel, parallel,
arbitrary). Block shapes default to 128x128x512 (MXU lane/sublane aligned,
~0.5 MB per operand tile in fp32 — three tiles + output fit well within the
~16 MB VMEM budget with double buffering).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .._compat import CompilerParams


def _gram_kernel(sx_ref, sy_ref, gamma_ref, x_ref, y_ref, o_ref, *,
                 kind: str, degree: int, coef: float, scale: float,
                 normalize: bool, n_m_blocks: int):
    """One (bn, bk) output tile; accumulates x @ y^T over the m grid axis."""
    mb = pl.program_id(2)

    @pl.when(mb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)          # (bn, bm)
    y = y_ref[...].astype(jnp.float32)          # (bk, bm)
    o_ref[...] += jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (bn, bk)

    @pl.when(mb == n_m_blocks - 1)
    def _epilogue():
        acc = o_ref[...]
        if kind == "rbf":
            sx = sx_ref[...].astype(jnp.float32)    # (bn,)
            sy = sy_ref[...].astype(jnp.float32)    # (bk,)
            d2 = sx[:, None] + sy[None, :] - 2.0 * acc
            d2 = jnp.maximum(d2, 0.0)
            o_ref[...] = jnp.exp(-gamma_ref[0] * d2)
        else:
            k = acc * scale
            if kind == "poly":
                k = (k + coef) ** degree
            if normalize:
                # sx/sy hold the *self-kernel* values for linear/poly.
                sx = sx_ref[...].astype(jnp.float32)
                sy = sy_ref[...].astype(jnp.float32)
                denom = jnp.maximum(sx[:, None] * sy[None, :], 1e-12)
                k = k * jax.lax.rsqrt(denom)
            o_ref[...] = k


@functools.partial(
    jax.jit,
    static_argnames=("kind", "degree", "coef", "scale", "normalize",
                     "block_n", "block_k", "block_m", "interpret"))
def gram_tiles(x: jax.Array, y: jax.Array, sx: jax.Array, sy: jax.Array,
               gamma: jax.Array, *, kind: str = "rbf", degree: int = 3,
               coef: float = 1.0, scale: float = 1.0, normalize: bool = True,
               block_n: int = 128, block_k: int = 128, block_m: int = 512,
               interpret: bool = False) -> jax.Array:
    """Tiled Gram matrix. Shapes must be pre-padded to block multiples:
    x (n, m), y (k, m), sx (n,), sy (k,) -> (n, k) float32."""
    n, m = x.shape
    k = y.shape[0]
    assert n % block_n == 0 and k % block_k == 0 and m % block_m == 0, \
        (x.shape, y.shape, (block_n, block_k, block_m))
    n_m_blocks = m // block_m
    grid = (n // block_n, k // block_k, n_m_blocks)

    kernel = functools.partial(
        _gram_kernel, kind=kind, degree=degree, coef=coef, scale=scale,
        normalize=normalize, n_m_blocks=n_m_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i, j, b: (i,)),         # sx
            pl.BlockSpec((block_k,), lambda i, j, b: (j,)),         # sy
            pl.BlockSpec((1,), lambda i, j, b: (0,)),               # gamma
            pl.BlockSpec((block_n, block_m), lambda i, j, b: (i, b)),
            pl.BlockSpec((block_k, block_m), lambda i, j, b: (j, b)),
        ],
        out_specs=pl.BlockSpec((block_n, block_k), lambda i, j, b: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(sx, sy, gamma, x, y)
