"""DKPCA activation probe (the paper's technique as a training feature)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.train import activation_probe


def test_probe_single_device_fallback():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=48,
                     n_heads=2, n_kv_heads=1, d_ff=96, vocab=256,
                     head_dim=24, tie_embeddings=True, remat="none",
                     param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(96, 16), dtype=np.int32))}
    out = activation_probe(params, batch, mesh=None, samples_per_node=16,
                           n_iters=6)
    assert not out["skipped"]
    assert np.isfinite(out["consensus_residual"])
    assert out["participation_mean"] > 0
    assert out["participation_cv"] >= 0


def test_probe_skips_tiny_batches():
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                     n_heads=1, n_kv_heads=1, d_ff=32, vocab=64, head_dim=16,
                     tie_embeddings=True, remat="none",
                     param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((4, 8), jnp.int32)}
    out = activation_probe(params, batch, mesh=None)
    assert out["skipped"]
