from .ops import project_op
from .ref import project_reference

__all__ = ["project_op", "project_reference"]
