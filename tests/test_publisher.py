"""Tests for the streaming-serving seam: ``oos.refresh_coefficients``
(cached kernel-mean statistics), the versioned ``ModelHandle``, the
engine's read-through/version-isolation semantics, and the end-to-end
train -> refresh -> publish -> serve loop over the chunked driver."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, build_setup, oos, solver
from repro.core.topology import ring
from repro.data import node_dataset
from repro.serve import BackgroundPublisher, KpcaEngine, KpcaServeConfig, \
    ModelHandle, stream_chunks

SPEC = KernelSpec(kind="rbf", gamma=0.25)

# Instrument every serve-layer lock and fail on a recorded AB/BA
# acquisition cycle (tests/helpers/lockcheck.py).
pytestmark = pytest.mark.lockcheck


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def fitted():
    x = jnp.asarray(_rand((48, 10), seed=0))
    return x, oos.fit_central(x, SPEC, n_components=2, center=True)


class TestRefreshCoefficients:
    def test_matches_full_refit(self, fitted):
        """Refreshing with new alpha == rebuilding from scratch with
        from_dual (which re-forms the Gram), to fp32 resolution."""
        x, model = fitted
        alpha2 = jnp.asarray(_rand((48, 2), seed=1))
        got = oos.refresh_coefficients(model, alpha2)
        want = oos.from_dual(x, alpha2, SPEC, gamma=model.gamma, center=True)
        xq = jnp.asarray(_rand((9, 10), seed=2))
        np.testing.assert_allclose(np.asarray(oos.project(got, xq)),
                                   np.asarray(oos.project(want, xq)),
                                   rtol=1e-5, atol=1e-5)

    def test_node_major_alpha_pools_like_from_decentralized(self):
        nodes = jnp.asarray(_rand((6, 8, 10), seed=3))
        a1 = jnp.asarray(_rand((6, 8), seed=4))
        model = oos.from_decentralized(nodes, a1, SPEC, gamma=0.3,
                                       center=True)
        a2 = jnp.asarray(_rand((6, 8), seed=5))
        got = oos.refresh_coefficients(model, a2)
        want = oos.from_decentralized(nodes, a2, SPEC, gamma=0.3,
                                      center=True)
        xq = jnp.asarray(_rand((7, 10), seed=6))
        np.testing.assert_allclose(np.asarray(oos.project(got, xq)),
                                   np.asarray(oos.project(want, xq)),
                                   rtol=1e-5, atol=1e-5)

    def test_uncentered_model_refreshes_to_zero_centering(self):
        x = jnp.asarray(_rand((20, 6), seed=7))
        model = oos.fit_central(x, SPEC, 1, center=False)
        new = oos.refresh_coefficients(model, jnp.asarray(_rand((20,), 8)))
        assert not np.any(np.asarray(new.row_mean_coef))
        assert not np.any(np.asarray(new.bias))

    def test_rejects_mismatched_support(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            oos.refresh_coefficients(model, jnp.ones((7, 2)))

    def test_rejects_centered_model_without_cache(self, fitted):
        _, model = fitted
        stripped = dataclasses.replace(model, k_row_mean=None,
                                       k_grand_mean=None)
        with pytest.raises(ValueError):
            oos.refresh_coefficients(stripped, model.coefs)

    def test_cache_survives_save_load(self, fitted, tmp_path):
        x, model = fitted
        oos.save_fitted(str(tmp_path / "ck"), model)
        back = oos.load_fitted(str(tmp_path / "ck"))
        assert back.k_row_mean is not None
        alpha2 = jnp.asarray(_rand((48, 2), seed=9))
        np.testing.assert_allclose(
            np.asarray(oos.refresh_coefficients(back, alpha2).bias),
            np.asarray(oos.refresh_coefficients(model, alpha2).bias),
            rtol=1e-6, atol=1e-6)


class TestModelHandle:
    def test_publish_bumps_version_atomically(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        assert h.version == 0
        m2 = oos.refresh_coefficients(model, model.coefs * 2.0)
        assert h.publish(m2) == 1
        got, v = h.get()
        assert v == 1 and got is m2

    def test_rejects_kind_change(self, fitted):
        _, model = fitted
        sharded, _ = oos.shard_fitted(model, 2)
        h = ModelHandle(model)
        with pytest.raises(TypeError):
            h.publish(sharded)

    def test_sharded_handle_pins_shard_count(self, fitted):
        """The engine's mesh is compiled against the initial shard count,
        so a re-sharded publish must be rejected up front."""
        _, model = fitted
        two, _ = oos.shard_fitted(model, 2)
        four, _ = oos.shard_fitted(model, 4)
        h = ModelHandle(two)
        with pytest.raises(ValueError):
            h.publish(four)
        two_b, _ = oos.shard_fitted(
            oos.refresh_coefficients(model, model.coefs * 2.0), 2)
        assert h.publish(two_b) == 1       # same layout: fine

    def test_refresh_publishes_new_coefficients(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        alpha2 = jnp.asarray(_rand((48, 2), seed=10))
        assert h.refresh(alpha2) == 1
        np.testing.assert_allclose(np.asarray(h.current().coefs),
                                   np.asarray(alpha2), rtol=1e-6, atol=1e-6)


class TestShardedRefresh:
    """Per-shard coefficient refresh: each shard rebuilds from its own
    cached kernel-mean slice; the global centering terms are recomputed
    from the per-shard partial sums (no Gram contact)."""

    @pytest.fixture(scope="class")
    def sharded(self, fitted):
        _, model = fitted
        return oos.shard_fitted(model, 3)[0]   # uneven: 48 -> 16/16/16

    def test_refresh_matches_full_refit(self, fitted, sharded):
        x, model = fitted
        alpha2 = jnp.asarray(_rand((48, 2), seed=20))
        got = oos.refresh_coefficients(sharded, alpha2)
        want, _ = oos.shard_fitted(
            oos.from_dual(x, alpha2, SPEC, gamma=model.gamma, center=True),
            3)
        xq = jnp.asarray(_rand((7, 10), seed=21))
        from repro.serve.sharded import project_sharded
        np.testing.assert_allclose(
            np.asarray(project_sharded(got, xq)),
            np.asarray(project_sharded(want, xq)), rtol=1e-5, atol=1e-5)

    def test_uneven_shards_refresh(self, fitted):
        """Padding rows must stay inert through a refresh (45 -> 15/15/15
        would be even; force 45 -> 4 shards = 12/11/11/11 padded to 12)."""
        x, model = fitted
        sub = oos.from_dual(x[:45], model.coefs[:45], SPEC,
                            gamma=model.gamma, center=True)
        sh, _ = oos.shard_fitted(sub, 4)
        assert len(set(sh.shard_sizes)) > 1    # genuinely uneven
        alpha2 = jnp.asarray(_rand((45, 2), seed=22))
        got = oos.refresh_coefficients(sh, alpha2)
        want, _ = oos.shard_fitted(
            oos.from_dual(x[:45], alpha2, SPEC, gamma=model.gamma,
                          center=True), 4)
        xq = jnp.asarray(_rand((6, 10), seed=23))
        from repro.serve.sharded import project_sharded
        np.testing.assert_allclose(
            np.asarray(project_sharded(got, xq)),
            np.asarray(project_sharded(want, xq)), rtol=1e-5, atol=1e-5)

    def test_single_shard_swap_composes_to_full_refresh(self, sharded):
        alpha2 = jnp.asarray(_rand((48, 2), seed=24))
        cur, off = sharded, 0
        for j, n in enumerate(sharded.shard_sizes):
            cur = oos.refresh_shard_coefficients(cur, j,
                                                 alpha2[off:off + n])
            off += n
        want = oos.refresh_coefficients(sharded, alpha2)
        xq = jnp.asarray(_rand((5, 10), seed=25))
        from repro.serve.sharded import project_sharded
        np.testing.assert_allclose(
            np.asarray(project_sharded(cur, xq)),
            np.asarray(project_sharded(want, xq)), rtol=1e-6, atol=1e-6)

    def test_single_shard_swap_leaves_others_alone(self, sharded):
        a0 = jnp.asarray(_rand((sharded.shard_sizes[1], 2), seed=26))
        new = oos.refresh_shard_coefficients(sharded, 1, a0)
        np.testing.assert_array_equal(
            np.asarray(new.coefs_ext[0]), np.asarray(sharded.coefs_ext[0]))
        np.testing.assert_array_equal(
            np.asarray(new.coefs_ext[2]), np.asarray(sharded.coefs_ext[2]))
        # the input model is unchanged (frozen artifact)
        assert new is not sharded

    def test_refresh_shard_validates(self, sharded):
        with pytest.raises(ValueError):
            oos.refresh_shard_coefficients(sharded, 7, jnp.ones((16, 2)))
        with pytest.raises(ValueError):
            oos.refresh_shard_coefficients(sharded, 0, jnp.ones((5, 2)))

    def test_compressed_sharded_rejects_refresh(self, fitted):
        _, model = fitted
        sh, _ = oos.shard_fitted(model, 2, landmarks_per_shard=8)
        assert sh.k_row_mean is None           # compression drops the cache
        with pytest.raises(ValueError):
            oos.refresh_coefficients(sh, jnp.ones((sh.n_support, 2)))

    def test_cache_survives_shard_checkpoint_and_gather(self, sharded,
                                                        tmp_path):
        oos.save_sharded(str(tmp_path / "ck"), sharded)
        back = oos.load_sharded(str(tmp_path / "ck"))
        assert back.k_row_mean is not None
        alpha2 = jnp.asarray(_rand((48, 2), seed=27))
        np.testing.assert_allclose(
            np.asarray(oos.refresh_coefficients(back, alpha2).bias),
            np.asarray(oos.refresh_coefficients(sharded, alpha2).bias),
            rtol=1e-6, atol=1e-6)
        gathered = oos.gather_fitted(sharded)
        assert gathered.k_row_mean is not None  # gather keeps refreshability
        np.testing.assert_allclose(
            np.asarray(oos.refresh_coefficients(gathered, alpha2).bias),
            np.asarray(oos.refresh_coefficients(sharded, alpha2).bias),
            rtol=1e-5, atol=1e-5)

    def test_concurrent_shard_refreshes_both_land(self, sharded):
        """refresh_shard is a read-rebuild-publish cycle; two threads
        swapping DIFFERENT shards must serialize, so neither update is
        silently overwritten by the other's stale base."""
        import threading
        h = ModelHandle(sharded)
        finals = {}

        def hammer(shard, seed):
            a = None
            for i in range(20):
                a = jnp.asarray(_rand((sharded.shard_sizes[shard], 2),
                                      seed=seed + i))
                h.refresh_shard(shard, a)
            finals[shard] = a

        threads = [threading.Thread(target=hammer, args=(s, 100 * s))
                   for s in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60.0)
        assert h.version == 40
        cur = h.current()
        for s in (0, 1):                       # each shard's LAST write won
            np.testing.assert_array_equal(
                np.asarray(cur.coefs_ext[s, :sharded.shard_sizes[s], :2]),
                np.asarray(finals[s]))

    def test_handle_refresh_and_refresh_shard(self, sharded):
        h = ModelHandle(sharded)
        alpha2 = jnp.asarray(_rand((48, 2), seed=28))
        assert h.refresh(alpha2) == 1          # sharded refresh now works
        a_shard = jnp.asarray(_rand((sharded.shard_sizes[0], 2), seed=29))
        assert h.refresh_shard(0, a_shard) == 2
        np.testing.assert_allclose(
            np.asarray(h.current().coefs_ext[0, :sharded.shard_sizes[0],
                                             :2]),
            np.asarray(a_shard), rtol=1e-6, atol=1e-6)


class TestEngineVersionIsolation:
    def test_inflight_flush_finishes_on_old_version(self, fitted):
        """A publish landing MID-FLUSH (between slabs) must not leak into
        that flush: all its slabs score on the snapshot taken at flush
        start; the next flush sees the new version."""
        _, model = fitted
        h = ModelHandle(model)
        eng = KpcaEngine(h, KpcaServeConfig(max_batch=8, min_bucket=8))
        m2 = oos.refresh_coefficients(model, model.coefs * 2.0)

        x = _rand((20, 10), seed=11)           # 3 slabs at max_batch=8
        fut = eng.submit(x)
        run_slab = eng._run_slab
        fired = dict(n=0)

        def publish_after_first_slab(mdl, version, slab):
            out = run_slab(mdl, version, slab)
            if fired["n"] == 0:
                h.publish(m2)                  # lands between slab 0 and 1
            fired["n"] += 1
            return out

        eng._run_slab = publish_after_first_slab
        eng.flush()
        eng._run_slab = run_slab
        assert fired["n"] == 3
        np.testing.assert_allclose(
            fut.result(), np.asarray(oos.project(model, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.stats.per_request[-1].model_version == 0

        fut2 = eng.submit(x)                   # next batch: new version
        eng.flush()
        np.testing.assert_allclose(
            fut2.result(), np.asarray(oos.project(m2, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.stats.per_request[-1].model_version == 1

    def test_plain_model_still_works(self, fitted):
        _, model = fitted
        eng = KpcaEngine(model, KpcaServeConfig(max_batch=8, min_bucket=8))
        x = _rand((5, 10), seed=12)
        out = eng.project_many([x])
        np.testing.assert_allclose(
            out[0], np.asarray(oos.project(model, jnp.asarray(x))),
            rtol=1e-5, atol=1e-5)
        assert eng.model is model


class TestStreamingEndToEnd:
    def test_driver_publishes_and_engine_serves_live(self):
        """The acceptance loop: chunked ADMM driver -> refresh_coefficients
        -> ModelHandle.publish -> KpcaEngine, with the engine serving
        between chunks and the final served scores matching an offline fit
        of the final alpha."""
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=12, m=8, seed=0)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)

        # seed model from the warm-start alpha (iteration 0)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        handle = ModelHandle(oos.from_decentralized(
            nodes, a0, spec, gamma=setup.gamma, center=True))
        eng = KpcaEngine(handle, KpcaServeConfig(max_batch=8, min_bucket=8))
        xq = _rand((5, 8), seed=13)

        versions = []
        driver = solver.run_chunked(setup, n_iters=12, chunk=3, alpha0=a0)
        for chunk in driver:
            handle.refresh(chunk.state.alpha)
            eng.submit(xq)
            eng.flush()
            versions.append(eng.stats.per_request[-1].model_version)
        assert versions == [1, 2, 3, 4]        # one publish per chunk

        final_alpha = chunk.state.alpha
        want = oos.project(
            oos.from_decentralized(nodes, final_alpha, spec,
                                   gamma=setup.gamma, center=True),
            jnp.asarray(xq))
        got = eng.project_many([xq])[0]
        np.testing.assert_allclose(got, np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_stream_chunks_validates_every(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            stream_chunks(iter([]), ModelHandle(model), every=0)

    def test_stream_chunks_glue(self):
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=10, m=8, seed=1)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        handle = ModelHandle(oos.from_decentralized(
            nodes, a0, spec, gamma=setup.gamma, center=True))
        last = stream_chunks(
            solver.run_chunked(setup, n_iters=10, chunk=4, alpha0=a0),
            handle, every=2)
        # 3 chunks (4+4+2): publishes after chunk 2 and at the tail chunk
        assert handle.version == 2
        np.testing.assert_allclose(
            np.asarray(handle.current().coefs).reshape(6, 10) * 6,
            np.asarray(last.state.alpha), rtol=1e-6, atol=1e-6)

    def test_stream_chunks_rejects_every_and_policy(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            stream_chunks(iter([]), ModelHandle(model), every=2,
                          policy="residual")


class TestRefreshPolicies:
    """Pluggable refresh cadence on the driver's chunk stream."""

    @staticmethod
    def _chunk(residual):
        return solver.ChunkResult(
            state=None, alpha_hist=None, lagrangian=None,
            primal_residual=np.asarray([residual], np.float32),
            rho_hist=None)

    def test_every_k(self):
        pol = solver.EveryK(3)
        fired = [pol.should_refresh(self._chunk(1.0)) for _ in range(7)]
        assert fired == [False, False, True, False, False, True, False]
        with pytest.raises(ValueError):
            solver.EveryK(0)

    def test_residual_improvement_fires_on_drops_only(self):
        pol = solver.ResidualImprovement(rel_drop=0.2)
        seq = [10.0,    # first chunk: no baseline -> fire
               9.5,     # -5% < 20% -> censored
               7.9,     # -21% vs 10.0 -> fire, baseline 7.9
               7.0,     # -11% -> censored
               6.0]     # -24% vs 7.9 -> fire
        fired = [pol.should_refresh(self._chunk(r)) for r in seq]
        assert fired == [True, False, True, False, True]

    def test_resolver_accepts_all_forms(self):
        assert isinstance(solver.resolve_refresh_policy(None), solver.EveryK)
        assert isinstance(solver.resolve_refresh_policy(4), solver.EveryK)
        assert isinstance(solver.resolve_refresh_policy("residual"),
                          solver.ResidualImprovement)
        fn = solver.resolve_refresh_policy(
            lambda ch: float(ch.primal_residual[-1]) < 1.0)
        assert fn.should_refresh(self._chunk(0.5)) is True
        assert fn.should_refresh(self._chunk(2.0)) is False
        with pytest.raises(ValueError):
            solver.resolve_refresh_policy("bogus")
        with pytest.raises(TypeError):
            solver.resolve_refresh_policy(1.5)

    def test_residual_policy_censors_real_driver(self):
        """Against a real converging run the residual trigger must publish
        strictly fewer versions than every-chunk, while the final model
        still matches the final alpha."""
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=10, m=8, seed=2)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        base = oos.from_decentralized(nodes, a0, spec, gamma=setup.gamma,
                                      center=True)
        h_all, h_res = ModelHandle(base), ModelHandle(base)
        last = stream_chunks(
            solver.run_chunked(setup, n_iters=16, chunk=2, alpha0=a0),
            h_all)
        stream_chunks(
            solver.run_chunked(setup, n_iters=16, chunk=2, alpha0=a0),
            h_res, policy=solver.ResidualImprovement(rel_drop=0.3))
        assert 0 < h_res.version < h_all.version
        np.testing.assert_allclose(          # tail publish: same final model
            np.asarray(h_res.current().coefs),
            np.asarray(h_all.current().coefs), rtol=1e-6, atol=1e-6)


class TestBackgroundPublisher:
    def test_refresh_and_drain(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        with BackgroundPublisher(h) as pub:
            alpha2 = jnp.asarray(_rand((48, 2), seed=30))
            pub.refresh(alpha2)
            pub.drain(timeout=30.0)
            assert h.version == 1
            np.testing.assert_allclose(np.asarray(h.current().coefs),
                                       np.asarray(alpha2),
                                       rtol=1e-6, atol=1e-6)
        assert pub.n_published == 1

    def test_latest_wins_coalescing(self, fitted):
        """A burst of refreshes for the same target publishes at most a
        few times — intermediate snapshots are dropped unpublished, and
        the LAST one always lands."""
        _, model = fitted
        h = ModelHandle(model)
        alphas = [jnp.asarray(_rand((48, 2), seed=31 + i))
                  for i in range(12)]
        with BackgroundPublisher(h) as pub:
            for a in alphas:
                pub.refresh(a)
            pub.drain(timeout=30.0)
        assert pub.n_published + pub.n_coalesced == 12
        assert h.version == pub.n_published
        np.testing.assert_allclose(np.asarray(h.current().coefs),
                                   np.asarray(alphas[-1]),
                                   rtol=1e-6, atol=1e-6)

    def test_worker_error_reraised_at_drain(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        pub = BackgroundPublisher(h)
        pub.refresh(jnp.ones((7, 2)))          # wrong support size
        with pytest.raises(ValueError):
            pub.drain(timeout=30.0)
        alpha2 = jnp.asarray(_rand((48, 2), seed=43))
        pub.refresh(alpha2)                    # worker survived the error
        pub.drain(timeout=30.0)
        assert h.version == 1
        pub.close()
        with pytest.raises(RuntimeError):      # closed: no new jobs
            pub.refresh(alpha2)

    def test_close_flushes_pending_jobs(self, fitted):
        _, model = fitted
        h = ModelHandle(model)
        pub = BackgroundPublisher(h)
        pub.refresh(jnp.asarray(_rand((48, 2), seed=44)))
        pub.close()                            # drains before stopping
        assert h.version == 1
        pub.close()                            # idempotent

    def test_stream_chunks_through_background_publisher(self):
        """The driver loop hands snapshots to the publisher thread and
        keeps iterating; stream_chunks drains before returning, so the
        handle ends at the final coefficients."""
        spec = KernelSpec(kind="rbf", gamma=None)
        nodes, _ = node_dataset(n_nodes=6, n_per_node=10, m=8, seed=3)
        setup = build_setup(jnp.asarray(nodes), ring(6, hops=1), spec)
        from repro.core.admm import initial_alpha
        a0 = initial_alpha(setup, "local")
        handle = ModelHandle(oos.from_decentralized(
            nodes, a0, spec, gamma=setup.gamma, center=True))
        with BackgroundPublisher(handle) as pub:
            last = stream_chunks(
                solver.run_chunked(setup, n_iters=12, chunk=3, alpha0=a0),
                handle, publisher=pub)
            assert handle.version >= 1         # drained before returning
        np.testing.assert_allclose(
            np.asarray(handle.current().coefs).reshape(6, 10) * 6,
            np.asarray(last.state.alpha), rtol=1e-6, atol=1e-6)
