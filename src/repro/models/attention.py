"""Attention: GQA (full / sliding-window), optional qk-norm, MLA
(DeepSeek-V2 multi-head latent attention with absorbed decode), einsum and
chunked (flash-style scan) implementations, KV-cache decode paths.

Shapes: activations (B, S, E); q (B, S, H, D); kv (B, S, Hkv, D) with
H = G * Hkv. Masks are built from absolute positions so the same code serves
train, prefill and decode.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import ParamCollector, rms_norm
from .rope import apply_rope

NEG_INF = -1e9


# ----------------------------------------------------------------------
# parameter init
# ----------------------------------------------------------------------

def init_gqa(col: ParamCollector, cfg: ArchConfig, prefix: str = "attn"):
    e, h, hk, d = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    col.param(f"{prefix}/wq", (e, h, d), ("embed", "heads", "head_dim"))
    col.param(f"{prefix}/wk", (e, hk, d), ("embed", "kv_heads", "head_dim"))
    col.param(f"{prefix}/wv", (e, hk, d), ("embed", "kv_heads", "head_dim"))
    col.param(f"{prefix}/wo", (h, d, e), ("heads", "head_dim", "embed"))
    if cfg.qk_norm:
        col.param(f"{prefix}/q_norm", (d,), ("head_dim",), init="ones")
        col.param(f"{prefix}/k_norm", (d,), ("head_dim",), init="ones")


def init_mla(col: ParamCollector, cfg: ArchConfig, prefix: str = "attn"):
    e, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dc, dq = cfg.kv_lora_rank, cfg.q_lora_rank
    col.param(f"{prefix}/w_dkv", (e, dc), ("embed", "kv_lora"))
    col.param(f"{prefix}/w_kr", (e, dr), ("embed", "rope"))
    if dq:
        col.param(f"{prefix}/w_dq", (e, dq), ("embed", "q_lora"))
        col.param(f"{prefix}/w_uq", (dq, h, dn + dr),
                  ("q_lora", "heads", "head_dim"))
    else:
        col.param(f"{prefix}/w_q", (e, h, dn + dr),
                  ("embed", "heads", "head_dim"))
    col.param(f"{prefix}/w_uk", (dc, h, dn), ("kv_lora", "heads", "head_dim"))
    col.param(f"{prefix}/w_uv", (dc, h, dv), ("kv_lora", "heads", "head_dim"))
    col.param(f"{prefix}/wo", (h, dv, e), ("heads", "head_dim", "embed"))


# ----------------------------------------------------------------------
# masking
# ----------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int = 0,
               k_len_valid: Optional[jax.Array] = None):
    """(..., Sq, Sk) additive bias from absolute positions. Negative key
    positions (empty ring-buffer slots) are always masked."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    m = jnp.where(k_pos[..., None, :] < 0, NEG_INF, 0.0)
    if causal:
        m = jnp.where(diff < 0, NEG_INF, m)
    if window > 0:
        m = jnp.where(diff >= window, NEG_INF, m)
    if k_len_valid is not None:
        m = jnp.where(k_pos[..., None, :] >= k_len_valid, NEG_INF, m)
    return jnp.broadcast_to(m, jnp.broadcast_shapes(m.shape, diff.shape))


# ----------------------------------------------------------------------
# core attention math (einsum / chunked)
# ----------------------------------------------------------------------

def _sdpa_einsum(q, k, v, bias, scale):
    """q (B,Sq,Hk,G,D); k,v (B,Sk,Hk,D); bias (B?,Sq,Sk) -> (B,Sq,Hk,G,D)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    s = s + bias[:, None, None]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def _sdpa_chunked(q, k, v, bias, scale, q_chunk: int, kv_chunk: int,
                  unroll=1):
    """Flash-style two-level scan with online softmax (memory-bounded).
    Differentiable by plain autodiff; intended for long-sequence prefill and
    as the memory-term optimization for training (see EXPERIMENTS.md §Perf).
    """
    b, sq, hk, g, d = q.shape
    sk = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    while sq % qc:
        qc //= 2
    while sk % kc:
        kc //= 2
    nq, nk = sq // qc, sk // kc

    qr = q.reshape(b, nq, qc, hk, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, hk, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, hk, d).transpose(1, 0, 2, 3, 4)
    br = bias.reshape(b, nq, qc, nk, kc).transpose(1, 3, 0, 2, 4)  # nq,nk,b,qc,kc

    def q_step(_, qi):
        qb, bb = qi           # (b,qc,hk,g,d), (nk,b,qc,kc)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb, bk = ki   # (b,kc,hk,d) x2, (b,qc,kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32)
            s = s * scale + bk[:, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qb.dtype), vb)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hk, g, qc, d), qb.dtype)
        m0 = jnp.full((b, hk, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kr, vr, bb),
                                      unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)      # (b,qc,hk,g,d)

    _, outs = jax.lax.scan(q_step, None, (qr, br),
                           unroll=unroll)          # (nq,b,qc,hk,g,d)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hk, g, d)


def _sdpa_chunked_banded(q, k, v, bias, scale, q_chunk, kv_chunk,
                         window: int, unroll=1):
    """§Perf: SWA-banded flash attention. For sliding-window attention only
    chunk pairs with q_pos - k_pos in [0, window) contribute; instead of
    masking (which still pays the matmuls), iterate a FIXED band of
    ceil(window/kc)+1 kv chunks per q chunk, gathered by dynamic index.
    Compute drops from O(S^2) to O(S * window)."""
    b, sq, hk, g, d = q.shape
    sk = k.shape[1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    while sq % qc:
        qc //= 2
    while sk % kc:
        kc //= 2
    nq, nk = sq // qc, sk // kc
    nband = min(nk, window // kc + (qc + kc - 1) // kc + 1)

    qr = q.reshape(b, nq, qc, hk, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, hk, d)
    vr = v.reshape(b, nk, kc, hk, d)
    br = bias.reshape(b, nq, qc, nk, kc)

    def q_step(_, qi):
        qb, iq = qi

        def kv_step(carry, bi):
            acc, m, l = carry
            # newest-first band; out-of-range slots masked (clip would
            # double-count chunk 0 near the sequence start)
            ki_raw = (iq * qc + qc - 1) // kc - bi
            valid = ki_raw >= 0
            ki = jnp.clip(ki_raw, 0, nk - 1)
            kb = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
            bk = jax.lax.dynamic_index_in_dim(
                jax.lax.dynamic_index_in_dim(br, iq, 1, keepdims=False),
                ki, 2, keepdims=False)                     # (b, qc, kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32)
            s = s * scale + bk[:, None, None]
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(qb.dtype), vb)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hk, g, qc, d), qb.dtype)
        m0 = jnp.full((b, hk, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, qc), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nband), unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)), unroll=unroll)
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hk, g, d)


def sdpa(cfg: ArchConfig, q, k, v, bias):
    """Grouped-query attention dispatch. q (B,S,H,D), k/v (B,T,Hkv,D)."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, d)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    if cfg.attention_impl == "chunked" and s > 1:
        if cfg.swa_banded and cfg.attn_kind == "swa" and s == k.shape[1]:
            out = _sdpa_chunked_banded(qg, k, v, bias, scale,
                                       cfg.attn_q_chunk, cfg.attn_kv_chunk,
                                       cfg.window, unroll=cfg.unroll_scans)
        else:
            out = _sdpa_chunked(qg, k, v, bias, scale,
                                cfg.attn_q_chunk, cfg.attn_kv_chunk,
                                unroll=cfg.unroll_scans)
    else:
        out = _sdpa_einsum(qg, k, v, bias, scale)
    return out.reshape(b, s, h, d)


# ----------------------------------------------------------------------
# GQA module (train / prefill / decode)
# ----------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array           # (B, T, Hkv, D)
    v: jax.Array


def _seq_shard(t, mesh, shard: bool):
    """Constrain (B, S, H, D) activations to (batch@data, S@model, ., .) —
    sequence-parallel attention (context parallelism for training)."""
    if mesh is None:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    from .common import batch_axes_of
    ba = batch_axes_of(mesh)
    seq_ax = "model" if (shard and t.shape[1] % mesh.shape["model"] == 0) \
        else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(ba, seq_ax, None, None)))


def gqa_forward(p, cfg: ArchConfig, x, positions, causal=True,
                cache: Optional[KVCache] = None,
                cache_len: Optional[jax.Array] = None, mesh=None):
    """x (B,S,E). With cache: decode/append mode — writes new kv at
    positions, attends over the cache. Returns (out, new_cache)."""
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_seq_shard and cache is None:
        q = _seq_shard(q, mesh, True)
        k = _seq_shard(k, mesh, False)   # keys/values replicated over model
        v = _seq_shard(v, mesh, False)

    window = cfg.window if cfg.attn_kind == "swa" else 0
    if cache is None:
        bias = _mask_bias(positions, positions, causal, window)
        out = sdpa(cfg, q, k, v, bias)
        new_cache = None
    else:
        b, s = x.shape[:2]
        t = cache.k.shape[1]
        cl = _scalar(cache_len)
        if s >= t:
            # Prefill longer than the cache (SWA ring buffer): attend within
            # the current sequence, then store the last t tokens at slots
            # pos % t (ring convention shared with the decode path).
            bias = _mask_bias(positions, positions, causal, window)
            out = sdpa(cfg, q, k, v, bias)
            shift = (cl + s - t) % t if t > 0 else 0
            ck = jnp.roll(k[:, -t:].astype(cache.k.dtype), shift, axis=1)
            cv = jnp.roll(v[:, -t:].astype(cache.v.dtype), shift, axis=1)
        else:
            # ring write: token with absolute position p lives at slot p % t
            idx = cl % t
            ck = _ring_update(cache.k, k.astype(cache.k.dtype), idx)
            cv = _ring_update(cache.v, v.astype(cache.v.dtype), idx)
            # absolute position of each slot given newest entry at idx+s-1
            newest = cl + s - 1
            slot = jnp.arange(t)
            k_pos = newest - ((idx + s - 1 - slot) % t)
            k_pos = jnp.broadcast_to(k_pos[None], (b, t))
            bias = _mask_bias(positions, k_pos, causal, window)
            out = sdpa(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype), bias)
        new_cache = KVCache(ck, cv)
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(x.dtype))
    return out, new_cache


def _ring_update(cache, new, idx):
    """Write `new` (B, S, ...) at ring slots [idx, idx+S) mod T."""
    t = cache.shape[1]
    s = new.shape[1]
    if s == 1:
        return jax.lax.dynamic_update_slice_in_dim(cache, new, idx, axis=1)
    # general (prefill into larger cache): positions idx..idx+s-1 fit without
    # wrap when idx + s <= t (standard prefill at cache_len=0); otherwise
    # wrap via double-write of the roll.
    return jax.lax.dynamic_update_slice_in_dim(cache, new, idx, axis=1)


def _scalar(v):
    return v if jnp.ndim(v) == 0 else v[0]


# ----------------------------------------------------------------------
# MLA module (DeepSeek-V2): train full-rank path + absorbed decode path
# ----------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array        # (B, T, dc)   compressed kv latents
    k_rope: jax.Array      # (B, T, dr)   shared rotary key part


def _mla_q(p, cfg, x, positions):
    if cfg.q_lora_rank:
        cq = jnp.einsum("bse,er->bsr", x, p["w_dq"].astype(x.dtype))
        q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bse,ehd->bshd", x, p["w_q"].astype(x.dtype))
    q_nope = q[..., :cfg.qk_nope_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(p, cfg: ArchConfig, x, positions, causal=True):
    """Training/prefill path: decompress K/V and run standard attention."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv = jnp.einsum("bse,ec->bsc", x, p["w_dkv"].astype(x.dtype))
    k_rope = apply_rope(jnp.einsum("bse,ed->bsd", x,
                                   p["w_kr"].astype(x.dtype)),
                        positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsc,chd->bshd", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsc,chd->bshd", c_kv, p["w_uv"].astype(x.dtype))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:3] + (cfg.qk_rope_dim,))],
        axis=-1)
    bias = _mask_bias(positions, positions, causal)
    # full multi-head (n_kv == n_heads) attention with (dn+dr) keys, dv values
    d_full = cfg.qk_nope_dim + cfg.qk_rope_dim
    scale = 1.0 / jnp.sqrt(d_full).astype(jnp.float32)
    b_, s_, h, _ = q.shape
    if cfg.attention_impl == "chunked" and s_ > 1:
        # pad v to key dim not needed: chunked impl is dim-agnostic per k/v
        out = _sdpa_chunked(q.reshape(b_, s_, h, 1, d_full), k, v, bias,
                            scale, cfg.attn_q_chunk, cfg.attn_kv_chunk,
                            unroll=cfg.unroll_scans)
        out = out.reshape(b_, s_, h, cfg.v_head_dim)
    else:
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        sc = sc + bias[:, None]
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", pr, v)
    return jnp.einsum("bshd,hde->bse", out, p["wo"].astype(x.dtype))


def mla_decode(p, cfg: ArchConfig, x, positions, cache: MLACache,
               cache_len: jax.Array):
    """Absorbed decode: attention runs entirely in the dc-dim latent space —
    the cache stores only (c_kv, k_rope): (dc + dr) per token instead of
    2*H*D (the paper-reported 93% KV-cache reduction for DSv2)."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    # absorb W_UK into q: q_lat[bshc] = q_nope . W_UK
    q_lat = jnp.einsum("bshd,chd->bshc", q_nope, p["w_uk"].astype(x.dtype))

    c_new = jnp.einsum("bse,ec->bsc", x, p["w_dkv"].astype(x.dtype))
    kr_new = apply_rope(jnp.einsum("bse,ed->bsd", x,
                                   p["w_kr"].astype(x.dtype)),
                        positions, cfg.rope_theta)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), _scalar(cache_len), axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), _scalar(cache_len),
        axis=1)

    t = c_kv.shape[1]
    d_full = cfg.qk_nope_dim + cfg.qk_rope_dim
    scale = 1.0 / jnp.sqrt(d_full).astype(jnp.float32)
    sc = (jnp.einsum("bshc,btc->bhst", q_lat, c_kv.astype(x.dtype))
          + jnp.einsum("bshd,btd->bhst", q_rope, k_rope.astype(x.dtype)))
    sc = sc.astype(jnp.float32) * scale
    idx = positions[:, 0] if positions.ndim == 2 else positions
    k_pos = jnp.arange(t)[None].repeat(b, 0)
    bias = _mask_bias(positions, k_pos, True,
                      k_len_valid=(idx + s)[:, None, None])
    pr = jax.nn.softmax(sc + bias[:, None], axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhst,btc->bshc", pr, c_kv.astype(x.dtype))
    out = jnp.einsum("bshc,chd->bshd", out_lat, p["w_uv"].astype(x.dtype))
    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(x.dtype))
    return out, MLACache(c_kv, k_rope)
