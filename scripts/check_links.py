#!/usr/bin/env python
"""Markdown link checker (stdlib only; CI step + pre-merge hygiene).

Scans every tracked ``*.md`` at the repo root, under ``docs/``, and under
``.github/`` for inline links/images ``[text](target)`` and verifies that
each RELATIVE target resolves to an existing file or directory (external
``http(s)://`` / ``mailto:`` links and pure ``#anchor`` self-references are
skipped; a ``path#anchor`` target is checked for the file part only).

    python scripts/check_links.py [root]

Exit code 1 with one line per broken link when anything is missing.
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — no nesting, stop at first closing paren; tolerate an
# optional "title" suffix after the path.
_LINK = re.compile(r"\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "ftp://")


def iter_md_files(root: pathlib.Path):
    yield from sorted(root.glob("*.md"))
    for sub in ("docs", ".github"):
        d = root / sub
        if d.is_dir():
            yield from sorted(d.rglob("*.md"))


def strip_code(text: str) -> str:
    """Blank out fenced code blocks and inline code spans — link syntax
    inside code samples is not a reference that can rot. Newlines inside
    fences are preserved so reported line numbers stay correct."""
    text = re.sub(r"```.*?```",
                  lambda m: "\n" * m.group(0).count("\n"), text, flags=re.S)
    return re.sub(r"`[^`\n]*`", "", text)


def check_file(path: pathlib.Path, root: pathlib.Path):
    broken = []
    for lineno, line in enumerate(strip_code(path.read_text()).splitlines(),
                                  start=1):
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            # NB: lstrip — `root / "/abs"` would discard root entirely.
            resolved = (root / rel.lstrip("/")) if rel.startswith("/") \
                else (path.parent / rel)
            if not resolved.exists():
                broken.append(
                    f"{path.relative_to(root)}:{lineno}: broken link "
                    f"-> {target}")
    return broken


def main() -> int:
    root = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 \
        else pathlib.Path(__file__).resolve().parent.parent
    broken, n_files = [], 0
    for md in iter_md_files(root):
        n_files += 1
        broken.extend(check_file(md, root))
    if broken:
        print("\n".join(broken))
        print(f"\n{len(broken)} broken link(s) across {n_files} files")
        return 1
    print(f"link-check OK: {n_files} markdown files, no broken relative "
          f"links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
