"""internvl2-76b [vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — InternViT + InternLM2 backbone [arXiv:2404.16821;
unverified]. Vision frontend is a STUB: input_specs() provides precomputed
patch embeddings (frontend_seq positions at d_model)."""

from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, head_dim=128,
        frontend="vision_stub", frontend_seq=1024, rope_theta=500000.0)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-76b-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
        frontend="vision_stub", frontend_seq=8, rope_theta=500000.0,
        remat="none")
