"""Tests for the serving artifact (repro.core.oos) and the fused Pallas
projection kernel (repro.kernels.project)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KernelSpec, central_kpca, kpca_project, oos
from repro.core.kernels_math import gram
from repro.kernels import (project_op, project_partial_op,
                           project_partial_reference, project_reference)

SPEC = KernelSpec(kind="rbf", gamma=0.25)


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.fixture(scope="module")
def fitted():
    x = jnp.asarray(_rand((64, 16), seed=0))
    model = oos.fit_central(x, SPEC, n_components=3, center=True)
    return x, model


class TestFittedKpca:
    def test_training_points_reproduce_centered_scores(self, fitted):
        """score(x_i) must equal (K_c alpha)_i — the defining property of
        the centered out-of-sample formula."""
        x, model = fitted
        alpha, _, k_c = central_kpca(x, SPEC, 3, center=True,
                                     gamma=model.gamma)
        want = np.asarray(k_c @ alpha)
        got = np.asarray(oos.project(model, x))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_uncentered_matches_raw_projection(self):
        x = jnp.asarray(_rand((40, 8), seed=1))
        xq = jnp.asarray(_rand((11, 8), seed=2))
        model = oos.fit_central(x, SPEC, 2, center=False)
        from repro.core.kernels_math import gram
        want = np.asarray(gram(SPEC, xq, x, gamma=model.gamma) @ model.coefs)
        got = np.asarray(oos.project(model, xq))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_kpca_project_is_centered_now(self, fitted):
        """The old raw path silently disagreed with a centered fit; the
        routed-through-oos version must match the centered eigen-scores,
        and the deprecated ``center=`` kwarg is gone (deprecation cycle
        finished — build ``oos.from_dual(center=False)`` for a raw fit)."""
        x, model = fitted
        alpha, _, k_c = central_kpca(x, SPEC, 3, center=True,
                                     gamma=model.gamma)
        got = np.asarray(kpca_project(x, x, alpha, SPEC, gamma=model.gamma))
        np.testing.assert_allclose(got, np.asarray(k_c @ alpha),
                                   rtol=1e-5, atol=1e-5)
        with pytest.raises(TypeError):
            kpca_project(x, x, alpha, SPEC, gamma=model.gamma, center=False)

    def test_from_decentralized_pools_nodes(self):
        """Packaging semantics: (J, N) node solutions (single or top-k
        list) pool to the averaged dual vector on the pooled support set.
        (Consensus *quality* is the fitting pipeline's concern — see
        tests/test_admm_convergence.py.)"""
        nodes = jnp.asarray(_rand((6, 20, 10), seed=3))
        a1 = jnp.asarray(_rand((6, 20), seed=4))
        a2 = jnp.asarray(_rand((6, 20), seed=5))
        model = oos.from_decentralized(nodes, [a1, a2], SPEC, gamma=0.3,
                                       center=True)
        assert model.n_support == 120 and model.n_components == 2
        pooled_alpha = jnp.stack([a1.reshape(-1), a2.reshape(-1)],
                                 axis=1) / 6
        want = oos.from_dual(nodes.reshape(-1, 10), pooled_alpha, SPEC,
                             gamma=0.3, center=True)
        xq = jnp.asarray(_rand((7, 10), seed=6))
        np.testing.assert_array_equal(np.asarray(oos.project(model, xq)),
                                      np.asarray(oos.project(want, xq)))

    def test_save_load_roundtrip(self, fitted, tmp_path):
        x, model = fitted
        oos.save_fitted(str(tmp_path / "ck"), model)
        back = oos.load_fitted(str(tmp_path / "ck"))
        assert back.spec == model.spec
        xq = jnp.asarray(_rand((9, 16), seed=4))
        np.testing.assert_array_equal(np.asarray(oos.project(model, xq)),
                                      np.asarray(oos.project(back, xq)))


class TestCompression:
    def test_error_monotone_in_landmarks(self, fitted):
        """Nested landmark sets => RKHS reconstruction error is monotone
        non-increasing in L, and exact recovery at full L."""
        x, model = fitted
        errs = []
        for n_l in (8, 16, 32, 48, 64):
            _, err = oos.compress(model, n_l, seed=0)
            errs.append(np.asarray(err))
        for lo, hi in zip(errs[1:], errs[:-1]):
            assert (lo <= hi + 1e-5).all(), (lo, hi)
        assert (errs[-1] < 1e-2).all(), errs[-1]

    def test_compressed_projection_approaches_exact(self, fitted):
        x, model = fitted
        xq = jnp.asarray(_rand((12, 16), seed=5))
        want = np.asarray(oos.project(model, xq))
        cm, _ = oos.compress(model, model.n_support, seed=0)
        got = np.asarray(oos.project(cm, xq))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_rejects_bad_landmark_count(self, fitted):
        _, model = fitted
        with pytest.raises(ValueError):
            oos.compress(model, 0)
        with pytest.raises(ValueError):
            oos.compress(model, model.n_support + 1)


class TestProjectPallasKernel:
    SHAPES = [(8, 8, 4, 1), (17, 23, 9, 3), (1, 64, 16, 2),
              (130, 100, 300, 2), (5, 300, 37, 1), (64, 256, 784, 4)]

    @pytest.mark.parametrize("bq,ls,m,c", SHAPES)
    @pytest.mark.parametrize("kind", ["rbf", "linear", "poly"])
    def test_allclose_to_reference(self, bq, ls, m, c, kind):
        spec = KernelSpec(kind=kind, gamma=0.3, degree=2, scale=0.1)
        rng = np.random.default_rng(bq * 1000 + ls)
        xq = jnp.asarray(rng.normal(size=(bq, m)).astype(np.float32))
        xs = jnp.asarray(rng.normal(size=(ls, m)).astype(np.float32))
        a = jnp.asarray(rng.normal(size=(ls, c)).astype(np.float32))
        rc = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
        got = np.asarray(project_op(spec, xq, xs, a, row_mean_coef=rc,
                                    bias=b, interpret=True))
        want = np.asarray(project_reference(spec, xq, xs, a,
                                            row_mean_coef=rc, bias=b))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_defaults_are_raw_projection(self):
        spec = KernelSpec(kind="rbf", gamma=0.5)
        xq = jnp.asarray(_rand((10, 12), seed=6))
        xs = jnp.asarray(_rand((30, 12), seed=7))
        a = jnp.asarray(_rand((30, 2), seed=8))
        got = np.asarray(project_op(spec, xq, xs, a, interpret=True))
        want = np.asarray(project_reference(spec, xq, xs, a))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_custom_blocks_multi_tile(self):
        """Force >1 tile on every grid axis."""
        spec = KernelSpec(kind="rbf", gamma=0.2)
        xq = jnp.asarray(_rand((70, 260), seed=9))
        xs = jnp.asarray(_rand((90, 260), seed=10))
        a = jnp.asarray(_rand((90, 1), seed=11))
        rc = jnp.asarray(_rand((1,), seed=12))
        b = jnp.asarray(_rand((1,), seed=13))
        got = np.asarray(project_op(spec, xq, xs, a, row_mean_coef=rc,
                                    bias=b, block_q=32, block_l=32,
                                    block_m=128, interpret=True))
        want = np.asarray(project_reference(spec, xq, xs, a,
                                            row_mean_coef=rc, bias=b))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("kind", ["linear", "poly"])
    def test_partial_op_non_rbf_matches_oracle(self, kind):
        """Sharded serving's raw-partials entry point through the fused
        kernel, for the normalized (§3.1) linear/poly kernels — including
        zero-indicator padding rows, which must contribute nothing."""
        spec = KernelSpec(kind=kind, degree=3, coef=0.5, scale=0.2)
        assert spec.normalize                  # paper §3.1 normalization
        rng = np.random.default_rng(41)
        xq = jnp.asarray(rng.normal(size=(13, 9)).astype(np.float32))
        xs = jnp.asarray(rng.normal(size=(21, 9)).astype(np.float32))
        ae = rng.normal(size=(21, 3)).astype(np.float32)
        ae[:, -1] = 1.0
        ae[17:] = 0.0                          # shard-padding rows
        ae = jnp.asarray(ae)
        got = np.asarray(project_partial_op(spec, xq, xs, ae,
                                            interpret=True))
        want = np.asarray(project_partial_reference(spec, xq, xs, ae))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        # last column really is the raw row-sum over the valid rows
        np.testing.assert_allclose(
            got[:, -1],
            np.asarray(jnp.sum(gram(spec, xq, xs[:17]), axis=1)),
            rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("kind", ["linear", "poly"])
    def test_non_rbf_centered_fit_serves_through_pallas(self, kind):
        """A CENTERED fit of a normalized non-RBF kernel must score
        identically through the fused Pallas path and the jnp oracle."""
        spec = KernelSpec(kind=kind, degree=2, scale=0.5)
        x = jnp.asarray(_rand((40, 8), seed=42))
        model = oos.fit_central(x, spec, n_components=2, center=True)
        xq = jnp.asarray(_rand((11, 8), seed=43))
        got = np.asarray(oos.project(model, xq, use_pallas=True,
                                     interpret=True))
        want = np.asarray(oos.project(model, xq))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_model_pallas_path_matches_jnp_path(self, fitted):
        x, model = fitted
        xq = jnp.asarray(_rand((21, 16), seed=14))
        got = np.asarray(oos.project(model, xq, use_pallas=True,
                                     interpret=True))
        want = np.asarray(oos.project(model, xq))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bf16_queries(self):
        spec = KernelSpec(kind="rbf", gamma=0.5)
        xq = jnp.asarray(_rand((16, 32), seed=15)).astype(jnp.bfloat16)
        xs = jnp.asarray(_rand((48, 32), seed=16))
        a = jnp.asarray(_rand((48, 2), seed=17))
        got = np.asarray(project_op(spec, xq, xs, a, interpret=True))
        want = np.asarray(project_reference(
            spec, xq.astype(jnp.float32), xs, a))
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
