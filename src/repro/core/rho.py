"""ADMM penalty-parameter policies.

Assumption 2 of the paper gives a closed-form lower bound on rho that
guarantees monotone decrease of the augmented Lagrangian (Theorem 2):

    rho >= ( sqrt(lam1^4 + 8 |Omega_j| lam1 * sum_n lam_n^3) + lam1^2 )
           / ( |Omega_j| * lam1 )

per node j, where lam_n are the eigenvalues of K_j. We take the max over
nodes. The paper's experiments instead use a hand-tuned warm-up schedule
(rho(1)=100 fixed; rho(2): 10 -> 50 -> 100); both are provided.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


def assumption2_rho(eigvals: jnp.ndarray, degree: jnp.ndarray) -> jnp.ndarray:
    """Per-node Theorem-2 rho bound.

    eigvals: (..., N) eigenvalues of (centered) K_j, any order.
    degree:  (...,) |Omega_j|.
    """
    lam = jnp.asarray(eigvals)
    lam1 = jnp.max(lam, axis=-1)
    s3 = jnp.sum(jnp.maximum(lam, 0.0) ** 3, axis=-1)
    d = jnp.asarray(degree, lam.dtype)
    return (jnp.sqrt(lam1 ** 4 + 8.0 * d * lam1 * s3) + lam1 ** 2) / (d * lam1)


@dataclasses.dataclass(frozen=True)
class RhoSchedule:
    """Paper §6.1 warm-up: start small, increase to rho_final at given steps.

    values[i] applies from iteration boundaries[i] onward;
    boundaries[0] must be 0.
    """

    boundaries: tuple = (0, 10, 20)
    values: tuple = (10.0, 50.0, 100.0)

    def __post_init__(self):
        assert len(self.boundaries) == len(self.values) and self.boundaries[0] == 0

    def at(self, t) -> jnp.ndarray:
        b = jnp.asarray(self.boundaries)
        v = jnp.asarray(self.values, jnp.float32)
        idx = jnp.sum(jnp.asarray(t) >= b) - 1
        return v[idx]

    @staticmethod
    def constant(rho: float) -> "RhoSchedule":
        return RhoSchedule(boundaries=(0,), values=(float(rho),))


def auto_rho(eigvals_per_node: np.ndarray, degrees: np.ndarray,
             safety: float = 1.05) -> float:
    """Global constant rho satisfying Assumption 2 on every node."""
    r = assumption2_rho(jnp.asarray(eigvals_per_node), jnp.asarray(degrees))
    return float(jnp.max(r) * safety)
