"""Decoder-only language model assembly (dense / MoE / MLA / VLM-backbone).

Layers are stacked on a leading axis and applied with jax.lax.scan (compile
time stays O(1) in depth; remat policy per config). The same block code
serves train, prefill (build KV cache + logits) and decode (one token,
cache update) — decode uses the MLA absorbed path where applicable.

VLM/audio-stub models consume a prefix of precomputed frontend embeddings
(``batch["frontend"]``, already at d_model) followed by text tokens; loss is
masked to text positions.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (KVCache, MLACache, gqa_forward, init_gqa, init_mla,
                        mla_decode, mla_forward)
from .common import (ParamCollector, ScanBlock, StackedCollector,
                     constrain_act, dtype_of, rms_norm, slice_layer)
from .mlp import init_mlp, mlp_forward
from .moe import init_moe, moe_forward, moe_forward_ref


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------

def init_decoder_lm(cfg: ArchConfig, key: jax.Array, mesh=None):
    col = ParamCollector(key, dtype_of(cfg.param_dtype))
    e = cfg.d_model
    col.param("embed", (cfg.vocab, e), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        col.param("lm_head", (e, cfg.vocab), ("embed", "vocab"), scale=0.02)
    col.param("final_norm", (e,), (None,), init="ones")

    def layer_block(col2: ParamCollector, moe: bool):
        if cfg.attn_kind == "mla":
            init_mla(col2, cfg)
        else:
            init_gqa(col2, cfg)
        col2.param("ln_attn", (e,), (None,), init="ones")
        col2.param("ln_mlp", (e,), (None,), init="ones")
        if moe:
            init_moe(col2, cfg)
        else:
            init_mlp(col2, cfg, d_ff=(cfg.d_ff_dense or cfg.d_ff))

    n_scan = cfg.n_layers - cfg.first_k_dense
    # leading dense layers (deepseek-v2 pattern), unscanned
    for i in range(cfg.first_k_dense):
        sub = ParamCollector(col._next(), col.dtype)
        layer_block(sub, moe=False)
        for k, v in sub.params.items():
            col.params[f"dense{i}/{k}"] = v
            col.axes[f"dense{i}/{k}"] = sub.axes[k]
    # stacked (scanned) layers — per-layer randomness via the stack dim
    layer_block(StackedCollector(col, n_scan, "layers"), moe=cfg.is_moe)
    return col.params, col.axes


# ----------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------

def _block_train(cfg: ArchConfig, mesh):
    def block(p, carry):
        x, positions, aux = carry
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        ap = slice_layer(p, "attn")
        if cfg.attn_kind == "mla":
            a = mla_forward(ap, cfg, h, positions)
        else:
            a, _ = gqa_forward(ap, cfg, h, positions, mesh=mesh)
        x = x + a
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        if "moe/router" in p:
            mp = slice_layer(p, "moe")
            if mesh is not None:
                m, aux_l = moe_forward(mp, cfg, h, mesh)
            else:
                m, aux_l = moe_forward_ref(mp, cfg, h)
            aux = aux + aux_l
        else:
            m = mlp_forward(slice_layer(p, "mlp"), cfg, h)
        return (constrain_act(x + m, mesh), positions, aux), None
    return block


def _block_decode(cfg: ArchConfig, mesh):
    def block(p, carry, cache_slice, cache_len):
        x, positions = carry
        h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
        ap = slice_layer(p, "attn")
        if cfg.attn_kind == "mla":
            a, new_cache = mla_decode(ap, cfg, h, positions,
                                      MLACache(*cache_slice), cache_len)
        else:
            a, new_cache = gqa_forward(ap, cfg, h, positions, causal=True,
                                       cache=KVCache(*cache_slice),
                                       cache_len=cache_len)
        x = x + a
        h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
        if "moe/router" in p:
            mp = slice_layer(p, "moe")
            if mesh is not None:
                m, _ = moe_forward(mp, cfg, h, mesh)
            else:
                m, _ = moe_forward_ref(mp, cfg, h)
        else:
            m = mlp_forward(slice_layer(p, "mlp"), cfg, h)
        return (constrain_act(x + m, mesh), positions), tuple(new_cache)
    return block


# ----------------------------------------------------------------------
# forward passes
# ----------------------------------------------------------------------

def _embed_inputs(params, cfg: ArchConfig, batch: Dict[str, Any]):
    tokens = batch["tokens"]
    emb = params["embed"]
    x = emb[tokens].astype(dtype_of(cfg.compute_dtype))
    if cfg.frontend != "none" and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return x, positions


def _run_layers(params, cfg: ArchConfig, x, positions, mesh):
    x = constrain_act(x, mesh)
    aux = jnp.zeros((), jnp.float32)
    block = _block_train(cfg, mesh)
    for i in range(cfg.first_k_dense):
        p_i = slice_layer(params, f"dense{i}")
        fn = jax.checkpoint(block) if cfg.remat != "none" else block
        (x, positions, aux), _ = fn(p_i, (x, positions, aux))
    stacked = slice_layer(params, "layers")
    (x, positions, aux), _ = ScanBlock.run(
        block, stacked, (x, positions, aux), remat=cfg.remat,
        unroll=cfg.unroll_scans)
    return x, aux


def _logits(params, cfg: ArchConfig, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bse,ev->bsv", x, head.astype(x.dtype))


def lm_loss(params, cfg: ArchConfig, batch, mesh=None):
    """Next-token CE, masked to text positions. Returns (loss, metrics)."""
    x, positions = _embed_inputs(params, cfg, batch)
    x, aux = _run_layers(params, cfg, x, positions, mesh)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    f = cfg.frontend_seq if (cfg.frontend != "none"
                             and "frontend" in batch) else 0
    x = x[:, f:]                                   # text region only
    logits = _logits(params, cfg, x)
    targets = batch["labels"]
    mask = batch.get("loss_mask")
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = nll.size
    loss = jnp.sum(nll) / denom
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16):
    """Stacked (scanned-layer) KV cache. MLA caches latents (dc + dr)."""
    l = cfg.n_layers
    if cfg.attn_kind == "mla":
        return (jnp.zeros((l, batch, max_len, cfg.kv_lora_rank), dtype),
                jnp.zeros((l, batch, max_len, cfg.qk_rope_dim), dtype))
    hk, d = cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_kind == "swa":
        max_len = min(max_len, cfg.window)
    return (jnp.zeros((l, batch, max_len, hk, d), dtype),
            jnp.zeros((l, batch, max_len, hk, d), dtype))


def lm_decode_step(params, cfg: ArchConfig, cache, tokens, cache_len,
                   mesh=None):
    """tokens (B, 1) -> (logits (B, V), new cache). cache_len: scalar."""
    emb = params["embed"]
    x = emb[tokens].astype(dtype_of(cfg.compute_dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(cache_len + jnp.arange(s)[None], (b, s))
    block = _block_decode(cfg, mesh)

    n_dense = cfg.first_k_dense
    new_dense_caches = []
    x_pos = (x, positions)
    for i in range(n_dense):
        p_i = slice_layer(params, f"dense{i}")
        sl = tuple(c[i] for c in cache)
        x_pos, nc = block(p_i, x_pos, sl, cache_len)
        new_dense_caches.append(nc)

    stacked = slice_layer(params, "layers")

    def step(carry, xs):
        layer_params, cache_slice = xs
        carry, new_slice = block(layer_params, carry, cache_slice, cache_len)
        return carry, new_slice

    scan_cache = tuple(c[n_dense:] for c in cache)
    (x, _), new_scan = jax.lax.scan(step, x_pos, (stacked, scan_cache),
                                    unroll=cfg.unroll_scans)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, -1]

    new_cache = []
    for ci in range(len(cache)):
        parts = ([jnp.stack([new_dense_caches[i][ci] for i in range(n_dense)])]
                 if n_dense else [])
        parts.append(new_scan[ci])
        new_cache.append(jnp.concatenate(parts, axis=0) if n_dense
                         else new_scan[ci])
    return logits, tuple(new_cache)


def lm_prefill(params, cfg: ArchConfig, batch, max_len: int, mesh=None,
               cache_dtype=jnp.bfloat16):
    """Process a full prompt: returns (last-position logits, filled cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_kv_cache(cfg, b, max_len, cache_dtype)
    logits, cache = lm_decode_step(params, cfg, cache, tokens,
                                   jnp.zeros((), jnp.int32), mesh=mesh)
    return logits, cache
