"""Per-iteration communication accounting for the ADMM transports.

The paper's headline iteration cost is O(|Omega_j| N) numbers moved per
node per iteration (§4.2); COKE-style censoring policies and the
communication-bounded fits of Balcan et al. make decisions from exactly
this quantity. ``CommLedger`` measures it from the transports themselves
instead of re-deriving it on paper:

  * ``repro.core.solver.DenseComm`` / ``RingComm`` accept a ledger and
    report every ``exchange`` (bytes + message count) and collective
    (psum/pmax payload bytes) into it;
  * ``repro.core.solver.admm_step`` brackets its body with
    ``begin_iteration``/``end_iteration``, so everything recorded in
    between is exactly ONE iteration's traffic.

Counting happens at **trace time**: jax traces the step body once per
compilation (``lax.scan`` traces its body once regardless of length), so
the Python-side hooks fire once per iteration *shape*, not once per
executed iteration — zero per-step runtime overhead, and the recorded
profile is the per-iteration cost by construction. The driver then tells
the ledger how many iterations actually ran (``add_iterations``) to get
cumulative totals. Traffic recorded outside an iteration bracket (the
setup phase's raw-data exchange and centering sweep in
``repro.core.dkpca``) accumulates into the one-off ``setup`` profile.

Scope semantics differ by transport and are part of the contract:
``DenseComm`` simulates the whole network in one process, so its profile
counts **network-wide** bytes (every directed edge); ``RingComm`` runs as
one node per device under shard_map, so its profile counts **one node's**
egress — multiply by J for the network total. Both count payload bytes
only (no framing / protocol overhead).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class CommProfile:
    """Accumulated traffic for one accounting window (an iteration, or
    the setup phase)."""

    bytes: int = 0             # point-to-point payload bytes
    messages: int = 0          # point-to-point sends (ppermute / edge)
    collectives: int = 0       # psum/pmax/pmean invocations
    collective_bytes: int = 0  # their payload bytes

    def add_exchange(self, nbytes: int, n_messages: int = 1) -> None:
        self.bytes += int(nbytes)
        self.messages += int(n_messages)

    def add_collective(self, nbytes: int) -> None:
        self.collectives += 1
        self.collective_bytes += int(nbytes)

    def scaled(self, n: int) -> "CommProfile":
        return CommProfile(self.bytes * n, self.messages * n,
                           self.collectives * n, self.collective_bytes * n)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class CommLedger:
    """Trace-time communication recorder shared by one solver run.

    Attach via ``repro.core.solver.run_chunked(ledger=...)`` (dense
    reference path) or ``repro.core.dkpca.dkpca_distributed(ledger=...)``
    (SPMD ring path); read ``per_iter`` / ``setup`` / ``totals()`` after.
    Not thread-safe: tracing and the driver loop run on one thread (the
    same contract as the driver itself, ``run_chunked`` docstring).
    """

    def __init__(self):
        self.per_iter = CommProfile()   # last traced iteration's profile
        self.setup = CommProfile()      # one-off (outside any iteration)
        self.iterations = 0             # iterations actually executed
        self._active: Optional[CommProfile] = None

    # -- hooks called by the transports (at trace time) ---------------------

    def begin_iteration(self) -> None:
        self._active = CommProfile()

    def end_iteration(self) -> None:
        if self._active is not None:
            self.per_iter = self._active
            self._active = None

    def record_exchange(self, nbytes: int, n_messages: int = 1) -> None:
        tgt = self._active if self._active is not None else self.setup
        tgt.add_exchange(nbytes, n_messages)

    def record_collective(self, nbytes: int) -> None:
        tgt = self._active if self._active is not None else self.setup
        tgt.add_collective(nbytes)

    # -- host-side bookkeeping ----------------------------------------------

    def add_iterations(self, n: int) -> None:
        self.iterations += int(n)

    def totals(self) -> CommProfile:
        """Cumulative traffic: setup + per-iteration profile times the
        executed iteration count (the per-iteration profile is constant
        across iterations — fixed shapes, fixed topology)."""
        it = self.per_iter.scaled(self.iterations)
        return CommProfile(
            self.setup.bytes + it.bytes,
            self.setup.messages + it.messages,
            self.setup.collectives + it.collectives,
            self.setup.collective_bytes + it.collective_bytes)

    def snapshot(self) -> dict:
        return {"per_iter": self.per_iter.as_dict(),
                "setup": self.setup.as_dict(),
                "iterations": self.iterations,
                "totals": self.totals().as_dict()}


__all__ = ["CommLedger", "CommProfile"]
