"""Distributed (SPMD) decentralized kernel PCA — paper Alg. 1 on a device
mesh.

Mapping (DESIGN.md §3): network node j == device j on the flattened mesh
axes; the paper's k-nearest-neighbor ring becomes ``jax.lax.ppermute``
shifts, i.e. nearest-neighbor hops on the TPU ICI torus. One program runs on
every node (bulk-synchronous SPMD, exactly the ADMM's communication
structure):

  setup:  r ppermute hops each direction exchange raw X_j (paper's setup
          phase); Gram blocks are computed locally (Pallas kernel on TPU);
          global-centering row-mean statistics use one ring sweep
          (J ppermute steps) + one pmean — the "consensus averaging round".
  iterate (lax.scan):
          2 message rounds per iteration, each 2r ppermutes of N-vectors:
          (alpha_l, K_l^-1 B_l column)  ->  Z-update (eq. 10-11)
          (phi(X_l)^T z_j projections)  ->  alpha/eta updates (eq. 12-13)

The iteration BODY is the shared ``repro.core.solver.admm_step`` — the same
code the reference simulator runs, here over the ``RingComm`` (ppermute)
transport instead of dense indexing.

Per-node per-iteration communication is O(|Omega_j| N) numbers — matching
the paper's §4.2 cost analysis — and is independent of the network size J.

Resumable runs: ``dkpca_distributed(alpha0=..., b0=..., t0=...)`` continues
from a mid-run iterate (the returned ``DistDkpcaResult.b`` plus ``alpha``
is the full restart state; ``t0`` offsets the rho schedule), which is the
SPMD equivalent of the reference path's ``repro.core.solver.run_chunked``
chunk boundaries. Fault tolerance: the ring is re-knit around failed nodes
by re-launching with the survivor mesh (see ``repro.core.topology.reknit``
and tests/test_fault_tolerance.py); state checkpoints via
``repro.checkpoint`` (``repro.core.solver.save_state``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .admm import initial_alpha, local_solution_alpha  # noqa: F401
from .kernels_math import KernelSpec, gram, psd_jitter_eigh, resolve_gamma
from .rho import RhoSchedule
from .solver import AdmmState, RingComm, SolverOps, admm_step
from ..distributed.compat import pvary, shard_map
from ..obs.comm import CommLedger
from .topology import ring_shifts


@dataclasses.dataclass
class DistDkpcaResult:
    alpha: jax.Array           # (J, N)
    alpha_hist: jax.Array      # (T, J, N)
    primal_residual: jax.Array  # (T,)
    znorm2_hist: jax.Array     # (T, J)
    b: Optional[jax.Array] = None  # (J, N, S) final duals (restart state)


def _ring_recv(v, axes, offset: int, j: int):
    """result[m] = v[(m + offset) % J] over the flattened mesh axes."""
    perm = [((m + offset) % j, m) for m in range(j)]
    return jax.lax.ppermute(v, axes, perm)


def dkpca_distributed(
    x_nodes,
    mesh: Mesh,
    axis_names: Sequence[str] = ("data", "model"),
    hops: int = 2,
    spec: KernelSpec = KernelSpec(),
    center: str = "global",
    include_self: bool = True,
    rho1: float = 100.0,
    rho2: Optional[RhoSchedule] = None,
    n_iters: int = 30,
    seed: int = 0,
    init: str = "local",
    alpha0: Optional[jax.Array] = None,
    b0: Optional[jax.Array] = None,
    t0: int = 0,
    project: str = "ball",
    gamma: Optional[float] = None,
    use_pallas: bool = False,
    message_dtype=None,
    unroll_iters: bool = False,
    ledger: Optional[CommLedger] = None,
    link_mask=None,
) -> DistDkpcaResult:
    """Run decentralized kPCA with one network node per device.

    x_nodes: (J, N, M) with J == prod(mesh axis sizes for axis_names).
    init (used when alpha0 is None): "local" (default, same semantics as
    ``repro.core.admm.initial_alpha``) starts each node at its own local
    kPCA solution — computed INSIDE the node program from the
    eigendecomposition the setup phase already does, so it costs no extra
    communication and warm-starts z at the pooled local components (the
    measured m=24 transient fix, docs/ADMM_CONVERGENCE.md); "paper" is the
    paper's unnormalized Gaussian.
    b0/t0: resume a run from iteration ``t0`` with duals ``b0`` (J, N, S)
    — pass the previous call's ``result.b``/``result.alpha``; the rho2
    schedule is evaluated at the global iteration indices [t0, t0+n_iters).
    ledger: a ``repro.obs.CommLedger`` accounting PER-NODE wire traffic —
    setup-phase exchanges land in ``ledger.setup``, the iterate phase in
    ``ledger.per_iter`` (recorded at trace time; see repro.obs.comm).
    link_mask: optional (n_iters, J, S) {0,1} per-iteration slot mask
    censoring lost/delayed links (repro.faults.FaultPlan.link_mask) —
    same COKE-style semantics as the dense driver: the received columns
    are zeroed at the transport AND the censored slots leave the
    consensus weights (admm_step(slot_mask=...)), so the SPMD trajectory
    matches the dense path under the same mask (parity-tested in
    tests/test_fault_injection.py). Node DROPOUT is not handled here:
    the mesh is fixed for the life of one call, so recovery is a
    re-launch on the survivor mesh with the shrunk state
    (repro.faults.shrink_state) passed via alpha0/b0/t0.
    """
    axis_names = tuple(axis_names)
    j_nodes = int(np.prod([mesh.shape[a] for a in axis_names]))
    x_nodes = jnp.asarray(x_nodes, jnp.float32)
    jj, n, m = x_nodes.shape
    assert jj == j_nodes, (jj, j_nodes)
    assert center in ("global", "none")
    if rho2 is None:
        rho2 = RhoSchedule()
    if gamma is None:
        g = resolve_gamma(spec, x_nodes.reshape(jj * n, m))
    else:
        g = jnp.asarray(gamma, jnp.float32)
    local_init = False
    if alpha0 is None:
        if init == "local":
            # placeholder shard_map operand; overwritten per-node by the
            # local kPCA solution once K_j's eigendecomposition exists.
            local_init = True
            alpha0 = jnp.zeros((jj, n), jnp.float32)
        elif init == "paper":
            alpha0 = jax.random.normal(jax.random.PRNGKey(seed), (jj, n),
                                       jnp.float32)
        else:
            raise ValueError(f"unknown init {init!r}")
    rho2_arr = jnp.asarray([rho2.at(t) for t in range(t0, t0 + n_iters)],
                           jnp.float32)
    rho_self = float(rho1) if include_self else 0.0

    offsets = ring_shifts(hops)                 # [-r..-1, 1..r]
    s_slots = len(offsets) + 1                  # slot 0 = self
    # rev_static[d]: for in-slot d (offset o), the sender's out-slot index
    # pointing back at us = slot of offset -o (in the same 0=self layout).
    slot_of = {0: 0}
    slot_of.update({o: i + 1 for i, o in enumerate(offsets)})
    rev_static = [slot_of[-o] for o in offsets]

    if b0 is None:
        b0 = jnp.zeros((jj, n, s_slots), jnp.float32)
    else:
        b0 = jnp.asarray(b0, jnp.float32)
        assert b0.shape == (jj, n, s_slots), (b0.shape, (jj, n, s_slots))

    fn = partial(_node_fn, axes=axis_names, j_nodes=j_nodes,
                 offsets=tuple(offsets), rev_static=tuple(rev_static),
                 s_slots=s_slots, spec=spec, center=center,
                 rho_self=rho_self, include_self=include_self,
                 project=project, n_iters=n_iters, t0=t0,
                 local_init=local_init, use_pallas=use_pallas,
                 message_dtype=message_dtype, unroll_iters=unroll_iters,
                 ledger=ledger)
    in_specs = [P(axis_names, None, None), P(axis_names, None),
                P(axis_names, None, None), P(), P()]
    args = [x_nodes, alpha0, b0, g, rho2_arr]
    if link_mask is not None:
        # extra sharded operand ONLY when faults are injected: the
        # fault-free program stays byte-identical to the pre-fault trace.
        lm = jnp.asarray(link_mask, jnp.float32)
        assert lm.shape == (n_iters, jj, s_slots), \
            (lm.shape, (n_iters, jj, s_slots))
        in_specs.append(P(None, axis_names, None))
        args.append(lm)
    shmap = shard_map(
        fn, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(axis_names, None), P(axis_names, None, None),
                   P(None, axis_names, None), P(None), P(None, axis_names)),
        # Pallas calls inside the body produce ShapeDtypeStructs without vma
        # annotations; disable the varying-mesh-axes checker for this map.
        check_vma=False,
    )
    with mesh:
        alpha, b_f, hist, res, zn = jax.jit(shmap)(*args)
    if ledger is not None:
        ledger.add_iterations(n_iters)
    return DistDkpcaResult(alpha=alpha, alpha_hist=hist, primal_residual=res,
                           znorm2_hist=zn, b=b_f)


def _node_fn(x_blk, a_blk, b_blk, g, rho2_arr, *extra, axes, j_nodes,
             offsets, rev_static, s_slots, spec, center, rho_self,
             include_self, project, n_iters, t0, local_init=False,
             use_pallas=False, message_dtype=None, unroll_iters=False,
             ledger=None):
    """Per-node SPMD program. x_blk: (1, N, M); a_blk: (1, N);
    b_blk: (1, N, S); extra: optionally one (n_iters, 1, S) per-node
    fault link mask (this node's censored slots per iteration).

    message_dtype (e.g. jnp.bfloat16): §Perf knob — cast per-iteration
    ppermute payloads (alpha, K^-1 B columns, z-projections) to a narrower
    dtype before the wire, halving ICI bytes; accumulation stays fp32."""
    x = x_blk[0]
    alpha = a_blk[0]
    b0 = b_blk[0]
    n = x.shape[0]
    lm = extra[0][:, 0] if extra else None               # (n_iters, S)

    def gram_fn(xa, xb):
        if use_pallas:
            from ..kernels.gram import gram_op
            return gram_op(spec, xa, xb, gamma=g)
        return gram(spec, xa, xb, gamma=g)

    # ---- setup: exchange raw data with r-hop neighbors (paper Alg. 1) ----
    xs = [x] + [_ring_recv(x, axes, o, j_nodes) for o in offsets]
    xs = jnp.stack(xs)                                     # (S, N, M)
    itemsize = jnp.dtype(x.dtype).itemsize
    if ledger is not None:
        ledger.record_exchange(len(offsets) * x.size * itemsize, len(offsets))

    # ---- global centering statistics: one ring sweep + pmean -------------
    if center == "global":
        if ledger is not None:
            # The sweep's scan body traces once but represents j_nodes
            # single-hop rotations of x, plus one scalar pmean and the
            # m_slots neighbor shifts — recorded explicitly here since
            # _ring_recv has no per-call hook inside the scan.
            ledger.record_exchange(j_nodes * x.size * itemsize, j_nodes)
            ledger.record_collective(jnp.dtype(jnp.float32).itemsize)
            ledger.record_exchange(len(offsets) * n * itemsize, len(offsets))
        def sweep(carry, _):
            rot, macc, mubar = carry
            kb = gram_fn(x, rot)                           # (N, N)
            macc = macc + jnp.sum(kb, axis=1)
            mubar = mubar + jnp.sum(kb)
            rot = _ring_recv(rot, axes, 1, j_nodes)
            return (rot, macc, mubar), None

        zero_n = pvary(jnp.zeros((n,), jnp.float32), axes)
        zero_s = pvary(jnp.zeros((), jnp.float32), axes)
        (_, macc, mubar), _ = jax.lax.scan(
            sweep, (x, zero_n, zero_s), None, length=j_nodes)
        m_own = macc / (j_nodes * n)                       # m(x) for own rows
        mu_bar = jax.lax.pmean(mubar / (j_nodes * n * n), axes)
        m_slots = [m_own] + [_ring_recv(m_own, axes, o, j_nodes)
                             for o in offsets]
        m_slots = jnp.stack(m_slots)                       # (S, N)
    else:
        m_slots = jnp.zeros((s_slots, n), jnp.float32)
        mu_bar = jnp.zeros((), jnp.float32)

    # ---- Gram blocks over slot data (Pallas hotspot on TPU) --------------
    xflat = xs.reshape(s_slots * n, -1)
    kfull = gram_fn(xflat, xflat)
    if center == "global":
        mf = m_slots.reshape(s_slots * n)
        kfull = kfull - mf[:, None] - mf[None, :] + mu_bar
    kcross = kfull.reshape(s_slots, n, s_slots, n).transpose(0, 2, 1, 3)

    k_loc = kcross[0, 0]
    lam, vec = psd_jitter_eigh(k_loc)
    if local_init:
        # initial_alpha(setup, "local") semantics: each node's own top
        # kernel principal component, v1 / sqrt(lam1), so ||w_j|| = 1.
        alpha = local_solution_alpha(lam, vec)

    n_nbr = len(offsets)
    maskf = jnp.concatenate(
        [jnp.full((1,), 1.0 if include_self else 0.0, jnp.float32),
         jnp.ones((n_nbr,), jnp.float32)])
    ops = SolverOps(kcross=kcross, k=k_loc, lam=lam, vec=vec, mask=maskf)
    comm = RingComm(axes, j_nodes, offsets, rev_static,
                    message_dtype=message_dtype, ledger=ledger)

    def iteration(carry, t):
        st = carry
        rho_slots = jnp.concatenate(
            [jnp.full((1,), rho_self), jnp.full((n_nbr,), rho2_arr[t])])
        if lm is None:
            new, res = admm_step(ops, comm, st, rho_slots, project)
        else:
            from ..faults.comm import FaultyComm  # lazy: leaf, no cycle
            sm = lm[t]
            new, res = admm_step(ops, FaultyComm(comm, sm), st, rho_slots,
                                 project, slot_mask=sm)
        return new, (new.alpha, res, new.znorm2)

    state0 = AdmmState(
        alpha=alpha, b=b0, g=pvary(jnp.zeros((n, s_slots), jnp.float32),
                                   axes),
        znorm2=pvary(jnp.zeros((), jnp.float32), axes),
        t=jnp.asarray(t0, jnp.int32),
        rho=pvary(jnp.zeros((s_slots,), jnp.float32), axes))
    final, (ahist, rhist, znhist) = jax.lax.scan(
        iteration, state0, jnp.arange(n_iters), unroll=unroll_iters)
    return (final.alpha[None], final.b[None], ahist[:, None, :], rhist,
            znhist[:, None])
