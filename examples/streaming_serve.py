"""Streaming alpha, fully async: serve projections FROM A STILL-RUNNING
ADMM fit with non-blocking publishes and a residual-driven refresh cadence.

    PYTHONPATH=src python examples/streaming_serve.py

Three threads cooperate, none blocking the others:
  * the DRIVER thread (here: the main loop) iterates the chunked solver
    (repro.core.solver.run_chunked) and hands each live coefficient
    snapshot to the publisher in O(1) — but only when the residual-
    improvement policy says the update is worth publishing (the serving
    analogue of COKE's communication censoring);
  * the PUBLISHER thread (repro.serve.BackgroundPublisher) rebuilds a
    servable FittedKpca from the cached kernel-mean statistics (no Gram
    re-formation) and atomically swaps it into the ModelHandle, coalescing
    latest-wins if the driver outpaces it;
  * the FLUSHER thread inside the engine drains submitted queries on a
    size-or-deadline trigger; futures resolve as slabs complete, each
    against one consistent model version."""

import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, build_setup, oos, solver
from repro.core.admm import initial_alpha
from repro.core.topology import ring
from repro.data import node_dataset
from repro.serve import (BackgroundPublisher, KpcaEngine, KpcaServeConfig,
                         ModelHandle)


def main():
    nodes, pooled = node_dataset(n_nodes=8, n_per_node=40, m=24, seed=0)
    spec = KernelSpec(kind="rbf")
    setup = build_setup(jnp.asarray(nodes), ring(8, hops=2), spec)

    # seed the handle from the warm-start alpha, start serving immediately
    a0 = initial_alpha(setup, "local")
    handle = ModelHandle(oos.from_decentralized(
        nodes, a0, spec, gamma=setup.gamma, center=True))
    engine = KpcaEngine(handle, KpcaServeConfig(
        max_batch=32, min_bucket=8, flush_max_wait_s=0.002))

    xq = np.random.default_rng(1).normal(size=(16, 24)).astype(np.float32)
    gold = oos.project(oos.fit_central(jnp.asarray(pooled), spec, 1,
                                       gamma=setup.gamma), jnp.asarray(xq))
    gold = np.asarray(gold)[:, 0]

    policy = solver.ResidualImprovement(rel_drop=0.15)
    print("chunk  iter  version  primal-res  published?  "
          "corr(served, central-fit)")
    with BackgroundPublisher(handle) as pub, engine:
        chunk = None
        fired = False
        for i, chunk in enumerate(
                solver.run_chunked(setup, n_iters=24, chunk=4, tol=1e-3)):
            fired = policy.should_refresh(chunk)
            if fired:
                pub.refresh(chunk.state.alpha)   # O(1): never blocks the fit
            fut = engine.submit(xq)              # async: future, not scores
            scores = fut.result(timeout=30.0)[:, 0]
            corr = float(np.corrcoef(scores, gold)[0, 1])
            print(f"{i + 1:5d}  {int(chunk.state.t):4d}  "
                  f"{handle.version:7d}  "
                  f"{float(chunk.primal_residual[-1]):10.2e}  "
                  f"{'yes' if fired else 'censored':>10}  {abs(corr):.4f}")
        if chunk is not None and not fired:      # censored tail: the served
            pub.refresh(chunk.state.alpha)       # model must not lag the fit
        pub.drain()                              # final snapshot published

    stats = engine.stats
    print(f"served {stats.n_queries} queries across {stats.n_requests} "
          f"requests while fitting; published {pub.n_published} versions "
          f"({pub.n_coalesced} coalesced); final version {handle.version}")


if __name__ == "__main__":
    main()
