"""Hybrid SSM + shared-attention LM (zamba2: mamba2 backbone, ONE shared
transformer block applied every ``attn_every`` mamba blocks).

Simplification vs. the released zamba2 (noted in DESIGN.md): the shared
block consumes the residual stream directly (no concat-with-embedding
re-projection); LoRA adapters on the shared block are omitted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import KVCache, gqa_forward, init_gqa
from .common import (ParamCollector, ScanBlock, StackedCollector,
                     constrain_act, dtype_of, rms_norm, slice_layer)
from .mamba import (Mamba2State, init_mamba2, mamba2_decode, mamba2_forward,
                    mamba2_init_state)
from .mlp import init_mlp, mlp_forward


def _group_plan(cfg: ArchConfig):
    g = cfg.n_layers // cfg.attn_every          # full groups (shared attn after each)
    tail = cfg.n_layers - g * cfg.attn_every
    return g, tail


def init_hybrid_lm(cfg: ArchConfig, key: jax.Array, mesh=None):
    col = ParamCollector(key, dtype_of(cfg.param_dtype))
    e = cfg.d_model
    col.param("embed", (cfg.vocab, e), ("vocab", "embed"), scale=0.02)
    if not cfg.tie_embeddings:
        col.param("lm_head", (e, cfg.vocab), ("embed", "vocab"), scale=0.02)
    col.param("final_norm", (e,), (None,), init="ones")
    sub = StackedCollector(col, cfg.n_layers, "layers")
    init_mamba2(sub, cfg, "mamba")
    sub.param("ln", (e,), (None,), init="ones")
    # ONE shared attention+mlp block (reused at every application)
    shared = ParamCollector(col._next(), col.dtype)
    init_gqa(shared, cfg)
    init_mlp(shared, cfg)
    shared.param("ln_attn", (e,), (None,), init="ones")
    shared.param("ln_mlp", (e,), (None,), init="ones")
    for k, v in shared.params.items():
        col.params[f"shared/{k}"] = v
        col.axes[f"shared/{k}"] = shared.axes[k]
    return col.params, col.axes


def _mamba_block(cfg: ArchConfig, mesh=None):
    def block(p, carry):
        x = carry
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        y = mamba2_forward(slice_layer(p, "mamba"), cfg, h)
        return constrain_act(x + y, mesh), None
    return block


def _shared_attn(params, cfg: ArchConfig, x, positions, cache=None,
                 cache_len=None):
    p = slice_layer(params, "shared")
    h = rms_norm(x, p["ln_attn"], cfg.norm_eps)
    a, new_cache = gqa_forward(slice_layer(p, "attn"), cfg, h, positions,
                               causal=True, cache=cache, cache_len=cache_len)
    x = x + a
    h = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    return x + mlp_forward(slice_layer(p, "mlp"), cfg, h), new_cache


def _tree_slice(stacked, lo, hi):
    return {k: v[lo:hi] for k, v in stacked.items()}


def hybrid_lm_loss(params, cfg: ArchConfig, batch, mesh=None):
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    stacked = slice_layer(params, "layers")
    x = constrain_act(x, mesh)
    g, tail = _group_plan(cfg)
    block = _mamba_block(cfg, mesh)
    for gi in range(g):
        lo = gi * cfg.attn_every
        x, _ = ScanBlock.run(block, _tree_slice(stacked, lo,
                                                lo + cfg.attn_every),
                             x, remat=cfg.remat, unroll=cfg.unroll_scans)
        x, _ = _shared_attn(params, cfg, x, positions)
    if tail:
        x, _ = ScanBlock.run(block, _tree_slice(stacked, g * cfg.attn_every,
                                                cfg.n_layers),
                             x, remat=cfg.remat, unroll=cfg.unroll_scans)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bse,ev->bsv", x, head.astype(x.dtype))
    targets = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - gold)
    return loss, {"loss": loss}


def hybrid_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    st = mamba2_init_state(cfg, batch, dtype)
    l = cfg.n_layers
    g, _ = _group_plan(cfg)
    hk, d = cfg.n_kv_heads, cfg.head_dim
    return (jnp.zeros((l,) + st.conv.shape, st.conv.dtype),
            jnp.zeros((l,) + st.ssm.shape, st.ssm.dtype),
            jnp.zeros((g, batch, max_len, hk, d), dtype),    # shared attn K
            jnp.zeros((g, batch, max_len, hk, d), dtype))    # shared attn V


def _one_token(params, cfg: ArchConfig, x, positions, conv_c, ssm_c, ck, cv,
               cache_len):
    """Single-token pass through the full hybrid stack. x (B, 1, E)."""
    stacked = slice_layer(params, "layers")
    g, tail = _group_plan(cfg)

    def mstep(carry, xs):
        p, cc, sc = xs
        h = rms_norm(carry, p["ln"], cfg.norm_eps)
        y, st = mamba2_decode(slice_layer(p, "mamba"), cfg, h,
                              Mamba2State(cc, sc))
        return carry + y, (st.conv, st.ssm)

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for gi in range(g):
        lo = gi * cfg.attn_every
        hi = lo + cfg.attn_every
        x, (cn, sn) = jax.lax.scan(
            mstep, x, (_tree_slice(stacked, lo, hi), conv_c[lo:hi],
                       ssm_c[lo:hi]), unroll=cfg.unroll_scans)
        new_conv.append(cn)
        new_ssm.append(sn)
        x, kvc = _shared_attn(params, cfg, x, positions,
                              cache=KVCache(ck[gi], cv[gi]),
                              cache_len=cache_len)
        new_k.append(kvc.k)
        new_v.append(kvc.v)
    if tail:
        x, (cn, sn) = jax.lax.scan(
            mstep, x, (_tree_slice(stacked, g * cfg.attn_every, cfg.n_layers),
                       conv_c[g * cfg.attn_every:],
                       ssm_c[g * cfg.attn_every:]), unroll=cfg.unroll_scans)
        new_conv.append(cn)
        new_ssm.append(sn)
    return x, (jnp.concatenate(new_conv), jnp.concatenate(new_ssm),
               jnp.stack(new_k), jnp.stack(new_v))


def hybrid_prefill(params, cfg: ArchConfig, batch, max_len: int, mesh=None,
                   cache_dtype=jnp.bfloat16):
    """Parallel prefill: chunked mamba2 forward (with state extraction) +
    shared-attention KV cache build for the whole prompt."""
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    stacked = slice_layer(params, "layers")
    g, tail = _group_plan(cfg)
    hk, d = cfg.n_kv_heads, cfg.head_dim
    t_cache = max_len

    def pblock(p, carry):
        xx = carry
        h = rms_norm(xx, p["ln"], cfg.norm_eps)
        y, st = mamba2_forward(slice_layer(p, "mamba"), cfg, h,
                               return_state=True)
        return xx + y, (st.conv, st.ssm)

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for gi in range(g):
        lo = gi * cfg.attn_every
        x, (cn, sn) = ScanBlock.run(
            pblock, _tree_slice(stacked, lo, lo + cfg.attn_every), x,
            remat="none", unroll=cfg.unroll_scans)
        new_conv.append(cn)
        new_ssm.append(sn)
        kv0 = KVCache(jnp.zeros((b, t_cache, hk, d), cache_dtype),
                      jnp.zeros((b, t_cache, hk, d), cache_dtype))
        x, kvc = _shared_attn(params, cfg, x, positions, cache=kv0,
                              cache_len=jnp.zeros((), jnp.int32))
        new_k.append(kvc.k)
        new_v.append(kvc.v)
    if tail:
        x, (cn, sn) = ScanBlock.run(
            pblock, _tree_slice(stacked, g * cfg.attn_every, cfg.n_layers),
            x, remat="none", unroll=cfg.unroll_scans)
        new_conv.append(cn)
        new_ssm.append(sn)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bse,ev->bsv", x[:, -1:], head.astype(x.dtype))[:, -1]
    return logits, (jnp.concatenate(new_conv), jnp.concatenate(new_ssm),
                    jnp.stack(new_k), jnp.stack(new_v))


def hybrid_decode_step(params, cfg: ArchConfig, cache, tokens, cache_len,
                       mesh=None):
    """tokens (B, S): S=1 decode, S>1 prefill (time-scanned token steps —
    the mamba recurrence is inherently sequential at inference)."""
    x = params["embed"][tokens].astype(dtype_of(cfg.compute_dtype))
    b, s = tokens.shape

    if s == 1:
        positions = jnp.broadcast_to(cache_len + jnp.arange(1)[None], (b, 1))
        x, new_cache = _one_token(params, cfg, x, positions, *cache,
                                  cache_len)
    else:
        def time_step(carry, t):
            cache_t = carry
            xt = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)
            pos = jnp.broadcast_to((cache_len + t)[None, None], (b, 1))
            y, new_cache = _one_token(params, cfg, xt, pos, *cache_t,
                                      cache_len + t)
            return new_cache, y[:, 0]

        new_cache, ys = jax.lax.scan(time_step, cache, jnp.arange(s))
        x = ys[-1][:, None]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bse,ev->bsv", x, head.astype(x.dtype))[:, -1]
    return logits, new_cache
