"""Production mesh definition (per assignment spec).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).

``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer jax; on older releases every mesh axis is implicitly
Auto, so omitting the kwarg is equivalent.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType

    def _axis_kw(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # older jax: all axes are Auto by default
    def _axis_kw(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / examples / elastic restore)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))


def mesh_shardings(mesh):
    """(row_sharded, replicated) ``NamedSharding`` pair over a 1-D mesh.

    The two placements sharded serving needs: ``row_sharded`` splits a
    leading axis one slice per device (model-parallel support slices, or
    data-parallel query rows); ``replicated`` pins a full copy on every
    device. Centralized here so the serving layer never constructs
    partition specs ad hoc — and so the pair is built ONCE per mesh, not
    per dispatch.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    (axis_name,) = mesh.axis_names
    return (NamedSharding(mesh, PartitionSpec(axis_name)),
            NamedSharding(mesh, PartitionSpec()))


def replicate_on_mesh(tree, mesh):
    """``device_put`` every leaf of ``tree`` replicated onto ``mesh``.

    The data-parallel serving layout: the full model on every device,
    query rows partitioned. One explicit placement that callers cache
    beats jit re-broadcasting an uncommitted model on every dispatch —
    the per-call transfer is exactly the overhead the sharded fast path
    exists to remove (docs/PERFORMANCE.md).
    """
    import jax.tree_util

    _, replicated = mesh_shardings(mesh)
    return jax.tree_util.tree_map(
        lambda leaf: jax.device_put(leaf, replicated), tree)


def make_serving_mesh(n_shards: int, axis_name: str = "shard"):
    """1-D mesh over the first ``n_shards`` devices for sharded kPCA serving.

    Unlike ``make_mesh`` this tolerates a machine with MORE devices than
    shards (it takes a prefix) and signals "not enough devices" by returning
    None instead of raising, so callers (``repro.serve.sharded``) can fall
    back to the single-device reduction with identical math. On CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
    jax call to expose N host devices.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    if len(devices) < n_shards:
        return None
    return Mesh(np.asarray(devices[:n_shards]), (axis_name,))
