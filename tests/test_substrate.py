"""Substrate tests: optimizer, schedules, data stream determinism,
checkpoint atomicity/restore/elastic, gradient compression, serve engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint, save_checkpoint_async)
from repro.data.tokens import TokenStream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_local, compression_ratio,
                         cosine_with_warmup)


class TestAdamW:
    def test_converges_quadratic(self):
        params = {"w": jnp.array([5.0, -3.0], jnp.float32)}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0)
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
        state = adamw_init(params)
        _, _, metrics = adamw_update(cfg, params,
                                     {"w": jnp.full(3, 100.0)}, state)
        assert float(metrics["grad_norm"]) > 100

    def test_schedule(self):
        s = cosine_with_warmup(10, 100)
        assert float(s(jnp.asarray(0))) == 0.0
        assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
        assert float(s(jnp.asarray(100))) <= 0.11


class TestTokenStream:
    def test_deterministic_and_resumable(self):
        a = TokenStream(vocab=100, batch=2, seq=16, seed=3)
        batches = [a.next_batch() for _ in range(5)]
        b = TokenStream(vocab=100, batch=2, seq=16, seed=3)
        for _ in range(2):
            b.next_batch()
        st = b.state()
        c = TokenStream(vocab=100, batch=2, seq=16, seed=3)
        c.restore(st)
        for i in range(2, 5):
            nb = c.next_batch()
            np.testing.assert_array_equal(np.asarray(nb["tokens"]),
                                          np.asarray(batches[i]["tokens"]))

    def test_labels_shifted(self):
        s = TokenStream(vocab=50, batch=1, seq=8, seed=0)
        b = s.next_batch()
        np.testing.assert_array_equal(np.asarray(b["labels"][0, :-1]),
                                      np.asarray(b["tokens"][0, 1:]))

    def test_learnable_structure(self):
        """Markov stream: bigram entropy must be far below log(V)."""
        s = TokenStream(vocab=1000, batch=8, seq=256, seed=1)
        toks = np.asarray(s.next_batch()["tokens"]).ravel()
        assert len(np.unique(toks)) < 300  # vocab usage is concentrated


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a/b": jnp.arange(6.0).reshape(2, 3),
                "c": jnp.asarray(3, jnp.int32)}
        save_checkpoint(str(tmp_path), 7, tree, metadata={"x": 1})
        out, meta, step = restore_checkpoint(str(tmp_path))
        assert step == 7 and meta == {"x": 1}
        np.testing.assert_array_equal(np.asarray(out["a/b"]),
                                      np.asarray(tree["a/b"]))

    def test_keep_last(self, tmp_path):
        for s in range(5):
            save_checkpoint(str(tmp_path), s, {"x": jnp.zeros(1)},
                            keep_last=2)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [3, 4]

    def test_atomic_no_torn_checkpoint(self, tmp_path):
        """A .tmp dir left by a killed writer must be invisible to restore."""
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(2)})
        os.makedirs(tmp_path / "step_00000002.tmp")  # simulated torn write
        assert latest_step(str(tmp_path)) == 1
        out, _, step = restore_checkpoint(str(tmp_path))
        assert step == 1

    def test_async(self, tmp_path):
        t = save_checkpoint_async(str(tmp_path), 3, {"x": jnp.ones(4)})
        t.join(timeout=30)
        assert latest_step(str(tmp_path)) == 3

    def test_elastic_reshard(self, tmp_path):
        """Checkpoint written unsharded restores onto a different mesh."""
        import subprocess, sys, textwrap
        code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_mesh
            from repro.checkpoint import save_checkpoint, restore_checkpoint
            d = r"{tmp_path}"
            tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
            save_checkpoint(d, 1, tree)
            for shape in [(4, 2), (8, 1), (2, 4)]:
                mesh = make_mesh(shape, ("data", "model"))
                sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
                out, _, _ = restore_checkpoint(d, shardings=sh)
                assert out["w"].sharding.mesh.shape["data"] == shape[0]
                np.testing.assert_array_equal(np.asarray(out["w"]),
                                              np.arange(64.0).reshape(8, 8))
            print("ELASTIC-OK")
        """)
        env = dict(os.environ,
                   PYTHONPATH=os.path.abspath(
                       os.path.join(os.path.dirname(__file__), "..", "src")))
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
        assert "ELASTIC-OK" in r.stdout, r.stderr


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        """With error feedback, the accumulated compressed updates converge
        to the accumulated true gradient (PowerSGD property)."""
        rng = np.random.default_rng(0)
        g_true = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
        err = jnp.zeros((32, 16))
        p_prev = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
        acc = jnp.zeros_like(g_true)
        for _ in range(30):
            p, q, err = compress_local(g_true, err, p_prev)
            acc = acc + p @ q.T
            p_prev = p
        # mean compressed update ~ true gradient
        rel = float(jnp.linalg.norm(acc / 30 - g_true)
                    / jnp.linalg.norm(g_true))
        assert rel < 0.15, rel

    def test_rank_captures_lowrank_exactly(self):
        rng = np.random.default_rng(1)
        u = rng.normal(size=(24, 2)).astype(np.float32)
        v = rng.normal(size=(12, 2)).astype(np.float32)
        g = jnp.asarray(u @ v.T)
        err = jnp.zeros_like(g)
        p_prev = jnp.asarray(rng.normal(size=(24, 4)).astype(np.float32))
        for _ in range(3):
            p, q, err = compress_local(g, err, p_prev)
            p_prev = p
        assert float(jnp.linalg.norm(err) / jnp.linalg.norm(g)) < 1e-3

    def test_ratio(self):
        params = {"w": jnp.zeros((128, 128)), "b": jnp.zeros(128)}
        r = compression_ratio(params, rank=4)
        assert r < 0.1


class TestServeEngine:
    def test_generate_batched(self):
        from repro.configs import get_config
        from repro.models import build_model
        from repro.serve import DecodeEngine, ServeConfig

        cfg = get_config("llama3.2-3b", smoke=True)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        eng = DecodeEngine(model, params, 2,
                           ServeConfig(max_len=32, max_new_tokens=5))
        prompts = [[1, 2, 3], [4, 5], [6], [7, 8, 9, 10], [11]]
        outs = eng.generate(prompts)
        assert len(outs) == 5
        assert all(len(o) == 5 for o in outs)
        assert all(0 <= t < cfg.vocab for o in outs for t in o)

    def test_greedy_deterministic(self):
        from repro.configs import get_config
        from repro.models import build_model
        from repro.serve import DecodeEngine, ServeConfig

        cfg = get_config("qwen3-32b", smoke=True)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(1))
        eng = DecodeEngine(model, params, 2,
                           ServeConfig(max_len=24, max_new_tokens=4))
        a = eng.generate([[1, 2], [3]])
        b = eng.generate([[1, 2], [3]])
        assert a == b
