"""Multi-device sharded kPCA projection serving (shard_map + psum).

The out-of-sample score is a sum over support points (paper §1), so it
shards embarrassingly: each device holds one slice of a
``ShardedFittedKpca`` — a contiguous block of support rows and the matching
dual-coefficient rows — and computes the raw partial

    P_j = K(X_query, X_j) @ coefs_ext_j          # (B, C+1)

with the existing fused Pallas projection kernel
(``repro.kernels.project.project_partial_op``; the extra column is the raw
kernel row-sum via the indicator column). Partials are ``psum``-reduced over
the shard mesh axis, and the GLOBAL centering terms (row-mean weight, bias),
which depend on the full support set, are applied exactly once after the
reduction (``repro.core.oos.finalize_partial_scores``). Per-query traffic is
therefore one (B, C+1) all-reduce regardless of support-set size — the same
communication shape COKE/Balcan-style distributed kPCA exploits.

Execution:
  * with a mesh (``launch.mesh.make_serving_mesh`` or caller-supplied), the
    partial computation runs under ``shard_map`` with the model's shard axis
    partitioned over the mesh and queries replicated;
  * with no mesh (fewer devices than shards), a vmap-over-shards fallback
    computes the identical math on one device, so tests and laptops run the
    same code path modulo placement.

Live updates: a sharded model refreshes per shard
(``repro.core.oos.refresh_shard_coefficients`` — per-shard cached
kernel-mean stats, global centering rebuilt post-hoc) and is republished as
ONE atomic ``ModelHandle`` swap, so this module never sees a model whose
shards disagree about the version; the scoring path stays version-free.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.kernels_math import gram
from ..core.oos import ShardedFittedKpca, finalize_partial_scores
from ..distributed.compat import shard_map
from ..launch.mesh import make_serving_mesh


def _shard_partial(spec, xq, xs, coefs_ext, gamma, use_pallas, interpret):
    """One shard's raw (B, C+1) partial: K(xq, xs) @ coefs_ext."""
    if use_pallas:
        from ..kernels.project import project_partial_op
        return project_partial_op(spec, xq, xs, coefs_ext, gamma=gamma,
                                  interpret=interpret)
    return gram(spec, xq, xs, gamma=gamma) @ coefs_ext


def project_sharded(model: ShardedFittedKpca, x_query: jax.Array, *,
                    mesh=None, axis_name: str = "shard",
                    use_pallas: bool = False,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Sharded centered out-of-sample scores: (B, M) -> (B, C).

    Args:
      model: sharded artifact (see ``repro.core.oos.shard_fitted``).
      x_query: (B, M) query batch, replicated to every shard.
      mesh: 1-D ``jax.sharding.Mesh`` whose single axis has size
        ``model.n_shards``. None = build one over the first n_shards local
        devices, falling back to the single-device reduction when the
        machine has fewer devices than shards.
      axis_name: mesh axis to reduce over (when building the default mesh).
      use_pallas: per-shard partials via the fused Pallas kernel instead of
        the dense jnp path.
      interpret: forwarded to the Pallas wrapper.

    Returns:
      (B, C) float32 scores, equal to ``oos.project(gather_fitted(model))``
      to fp32 tolerance (tests/test_sharded_serving.py).
    """
    x_query = jnp.asarray(x_query)
    if mesh is None:
        mesh = make_serving_mesh(model.n_shards, axis_name)
    if mesh is None:                      # not enough devices: same math,
        partials = _partials_local(model, x_query, use_pallas, interpret)
    else:                                 # one device per shard + psum
        partials = _partials_shard_map(model, x_query, mesh, use_pallas,
                                       interpret)
    return finalize_partial_scores(partials, model.row_mean_coef,
                                   model.bias, model.n_support)


def _partials_shard_map(model: ShardedFittedKpca, x_query: jax.Array, mesh,
                        use_pallas: bool,
                        interpret: Optional[bool]) -> jax.Array:
    """psum-reduced (B, C+1) partials over the mesh's shard axis."""
    (axis_name,) = mesh.axis_names
    spec = model.spec

    def fn(xs, ae, xq, g):
        # xs (1, Lp, M), ae (1, Lp, C+1): this device's shard slice.
        part = _shard_partial(spec, xq, xs[0], ae[0], g, use_pallas,
                              interpret)
        return jax.lax.psum(part, axis_name)

    f = shard_map(fn, mesh=mesh,
                  in_specs=(P(axis_name), P(axis_name), P(None, None), P()),
                  out_specs=P(None, None), check_vma=False)
    return f(model.x_support, model.coefs_ext, x_query, model.gamma)


def _partials_local(model: ShardedFittedKpca, x_query: jax.Array,
                    use_pallas: bool,
                    interpret: Optional[bool]) -> jax.Array:
    """Single-device reduction: loop shards, sum partials (== psum)."""
    spec = model.spec
    total = jnp.zeros((x_query.shape[0], model.n_components + 1),
                      jnp.float32)
    for j in range(model.n_shards):
        total = total + _shard_partial(
            spec, x_query, model.x_support[j], model.coefs_ext[j],
            model.gamma, use_pallas, interpret)
    return total


__all__ = ["project_sharded"]
