"""Tests for the shared request batching/queueing layer
(``repro.serve.batching``): bounded queue + admission control, drain
triggers, pow2 buckets, and the two slab packers."""

import threading
import time

import numpy as np
import pytest

from repro.serve.batching import (QueueFullError, RequestQueue, ShedError,
                                  bucket_for, iter_slabs, left_pad_pack,
                                  pow2_buckets)


def _rand(shape, seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


# Instrument every serve-layer lock and fail on a recorded AB/BA
# acquisition cycle (tests/helpers/lockcheck.py).
pytestmark = pytest.mark.lockcheck


class TestBuckets:
    def test_power_of_two_ladder(self):
        assert pow2_buckets(8, 64) == [8, 16, 32, 64]

    def test_non_pow2_max_is_widest(self):
        assert pow2_buckets(8, 48) == [8, 16, 32, 48]

    def test_validation(self):
        with pytest.raises(ValueError):
            pow2_buckets(8, 4)
        with pytest.raises(ValueError):
            pow2_buckets(0, 4)

    def test_bucket_for(self):
        buckets = [8, 16, 32]
        assert bucket_for(buckets, 1) == 8
        assert bucket_for(buckets, 8) == 8
        assert bucket_for(buckets, 9) == 16
        assert bucket_for(buckets, 99) == 32   # overflow -> widest


class TestRequestQueue:
    def test_fifo_put_drain(self):
        q = RequestQueue()
        futs = [q.put(f"p{i}", n=i + 1)[0] for i in range(3)]
        assert q.depth == 6 and len(q) == 3
        entries = q.drain()
        assert [e.payload for e in entries] == ["p0", "p1", "p2"]
        assert [e.future is f for e, f in zip(entries, futs)] == [True] * 3
        assert q.depth == 0 and q.drain() == []

    def test_take_and_restore_preserve_order(self):
        q = RequestQueue()
        for i in range(5):
            q.put(i, n=1)
        head = q.take(2)
        assert [e.payload for e in head] == [0, 1] and q.depth == 3
        q.restore(head)                        # failed batch goes back FIRST
        assert [e.payload for e in q.drain()] == [0, 1, 2, 3, 4]

    def test_reject_policy(self):
        q = RequestQueue(max_queries=10, policy="reject")
        q.put("a", n=6)
        with pytest.raises(QueueFullError):
            q.put("b", n=5)
        assert q.n_rejected == 1
        q.put("c", n=4)                        # exactly at capacity: fine
        assert q.depth == 10 and q.depth_peak == 10

    def test_shed_policy_drops_oldest(self):
        q = RequestQueue(max_queries=10, policy="shed")
        old, _ = q.put("old", n=6)
        mid, _ = q.put("mid", n=4)
        fut, shed = q.put("new", n=5)          # sheds "old" only
        assert [f is old for f in shed] == [True]
        assert q.n_shed == 1
        with pytest.raises(ShedError):
            old.result(timeout=0)
        assert [e.payload for e in q.drain()] == ["mid", "new"]
        assert not (mid.done() or fut.done())

    def test_oversize_request_always_rejected(self):
        q = RequestQueue(max_queries=10, policy="shed")
        q.put("a", n=2)
        with pytest.raises(QueueFullError):
            q.put("huge", n=11)
        assert q.n_rejected == 1 and q.n_shed == 0
        assert len(q) == 1                     # nothing was shed for it

    def test_validation(self):
        with pytest.raises(ValueError):
            RequestQueue(policy="fifo")
        with pytest.raises(ValueError):
            RequestQueue(max_queries=0)

    def test_wait_for_work_size_trigger(self):
        q = RequestQueue()
        stop = threading.Event()
        hits = []

        def waiter():
            hits.append(q.wait_for_work(4, max_wait_s=30.0, stop=stop))

        t = threading.Thread(target=waiter)
        t.start()
        try:
            q.put("a", n=2)
            t.join(timeout=0.05)
            assert t.is_alive()                # 2 < 4 rows: still waiting
            q.put("b", n=2)                    # size trigger fires
            t.join(timeout=5.0)
            assert not t.is_alive() and hits == [True]
        finally:
            stop.set()
            q.kick()
            t.join(timeout=5.0)

    def test_wait_for_work_deadline_trigger(self):
        q = RequestQueue()
        stop = threading.Event()
        q.put("a", n=1)
        t0 = time.monotonic()
        assert q.wait_for_work(100, max_wait_s=0.05, stop=stop) is True
        assert time.monotonic() - t0 < 5.0

    def test_wait_for_work_stop(self):
        q = RequestQueue()
        stop = threading.Event()
        out = []

        def waiter():
            out.append(q.wait_for_work(4, max_wait_s=30.0, stop=stop))

        t = threading.Thread(target=waiter)
        t.start()
        try:
            t.join(timeout=0.05)
            assert t.is_alive()                # nothing queued: still waiting
            stop.set()
            q.kick()
            t.join(timeout=5.0)
            assert not t.is_alive() and out == [False]  # nothing queued
        finally:
            stop.set()
            q.kick()
            t.join(timeout=5.0)


class TestSlabPacking:
    def test_iter_slabs_spans_and_owners(self):
        q = RequestQueue()
        sizes = [3, 10, 1]
        for i, s in enumerate(sizes):
            q.put(_rand((s, 4), seed=i), n=s)
        entries = q.drain()
        slabs = list(iter_slabs(entries, max_batch=8, buckets=[4, 8]))
        # 14 rows -> slabs of 8 and 6 (bucketed to 8)
        assert [(s.shape, take) for s, take, _ in slabs] == \
            [((8, 4), 8), ((8, 4), 6)]
        owners = np.concatenate([o for _, _, o in slabs])
        rids = [e.rid for e in entries]
        assert owners.tolist() == [rids[0]] * 3 + [rids[1]] * 10 + [rids[2]]
        # rows survive packing exactly; padding rows are zero
        stream = np.concatenate([e.payload for e in entries])
        np.testing.assert_array_equal(
            np.concatenate([s[:t] for s, t, _ in slabs]), stream)
        assert not np.any(slabs[-1][0][6:])

    def test_iter_slabs_empty(self):
        assert list(iter_slabs([], 8, [8])) == []
        q = RequestQueue()
        q.put(np.zeros((0, 3), np.float32), n=0)
        assert list(iter_slabs(q.drain(), 8, [8])) == []

    def test_left_pad_pack(self):
        toks, plen = left_pad_pack([[1, 2, 3], [7]], slots=4)
        assert plen == 3 and toks.shape == (4, 3)
        assert toks[0].tolist() == [1, 2, 3]
        assert toks[1].tolist() == [0, 0, 7]   # right-aligned
        assert not toks[2:].any()              # idle slots all-pad
        with pytest.raises(ValueError):
            left_pad_pack([], slots=2)
        with pytest.raises(ValueError):
            left_pad_pack([[1], [2], [3]], slots=2)
