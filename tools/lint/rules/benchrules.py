"""Benchmark honesty rules.

JAX dispatch is asynchronous: a jitted call returns a future-like array
immediately, and the compute lands whenever somebody blocks on it
(``jax.block_until_ready``, ``.item()``, a ``np.asarray`` device->host
get). A benchmark that reads the clock after an UNBLOCKED device call
times the dispatch, not the work — the classic way a kernel "gets 1000x
faster" in a commit message. The ``untimed-device-call`` rule flags
exactly that shape inside ``benchmarks/``: a ``time.perf_counter()``
start, a device-dispatching call in the timed region, and no reachable
materialization before the matching clock read.

Device-dispatching calls are recognized by local convention, not type
inference: names bound from ``jax.jit(...)`` in the same file, kernel
wrapper names ending in ``_op`` (``gram_op``, ``project_op``, ...), and
names imported from a ``kernels`` module. Materializers are
``block_until_ready`` (function or method), ``.item()``, and
``np.asarray``/``np.array``/``float()`` on the region's values. The rule
stays quiet outside ``benchmarks/`` — library code is allowed to keep
device values in flight; only a timed region that claims to measure them
must pin them down.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set

from ..engine import FileContext, Finding, Rule, register

_CLOCKS = {"perf_counter", "monotonic", "time", "process_time"}
_BLOCKERS = {"block_until_ready", "item", "asarray", "array", "float",
             "result"}
_STMT_LISTS = ("body", "orelse", "finalbody")


def _is_clock_call(node: ast.AST) -> bool:
    """``time.perf_counter()`` / ``time.monotonic()`` / bare
    ``perf_counter()`` — any zero-arg read of a wall clock."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute):
        return f.attr in _CLOCKS
    return isinstance(f, ast.Name) and f.id in _CLOCKS


def _clock_start_name(stmt: ast.stmt) -> Optional[str]:
    """``t0 = time.perf_counter()`` -> ``"t0"``."""
    if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
            and isinstance(stmt.targets[0], ast.Name) \
            and _is_clock_call(stmt.value):
        return stmt.targets[0].id
    return None


def _reads_clock_against(stmt: ast.stmt, name: str) -> bool:
    """Does ``stmt`` contain ``<clock>() - name`` (the region's end)?"""
    for node in ast.walk(stmt):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub) \
                and _is_clock_call(node.left) \
                and isinstance(node.right, ast.Name) \
                and node.right.id == name:
            return True
    return False


def _jit_bound_names(tree: ast.Module) -> Set[str]:
    """Names assigned from ``jax.jit(...)`` / ``jit(...)`` anywhere in
    the file — calling one of these dispatches device work."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and
                isinstance(node.value, ast.Call)):
            continue
        f = node.value.func
        jit = (isinstance(f, ast.Attribute) and f.attr == "jit") or \
              (isinstance(f, ast.Name) and f.id == "jit")
        if jit:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _kernel_import_names(tree: ast.Module) -> Set[str]:
    """Names imported from a ``...kernels...`` module (the Pallas wrapper
    package) — each is a device-dispatching op."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and "kernels" in node.module:
            out.update(a.asname or a.name for a in node.names)
    return out


def _called_name(call: ast.Call) -> Optional[str]:
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


@register
class UntimedDeviceCallRule(Rule):
    name = "untimed-device-call"
    summary = ("benchmarks/ only: a timed region dispatches a jitted/"
               "Pallas op but never blocks on it before the clock read — "
               "the row times dispatch, not the work")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        parts = ctx.path.replace("\\", "/").split("/")
        in_bench = "benchmarks" in parts or \
            parts[-1].startswith("bench_")
        if not in_bench:
            return
        device_names = _jit_bound_names(ctx.tree) | \
            _kernel_import_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            for field in _STMT_LISTS:
                stmts = getattr(node, field, None)
                if isinstance(stmts, list):
                    yield from self._check_body(ctx, stmts, device_names)

    def _check_body(self, ctx: FileContext, stmts: List[ast.stmt],
                    device_names: Set[str]) -> Iterator[Finding]:
        for i, stmt in enumerate(stmts):
            t_name = _clock_start_name(stmt)
            if t_name is None:
                continue
            region: List[ast.stmt] = []
            for later in stmts[i + 1:]:
                region.append(later)
                if _reads_clock_against(later, t_name):
                    break
            else:
                continue                  # never read back: not a timing
            yield from self._check_region(ctx, region, device_names)

    def _check_region(self, ctx: FileContext, region: List[ast.stmt],
                      device_names: Set[str]) -> Iterator[Finding]:
        device_calls: List[ast.Call] = []
        blocked = False
        for stmt in region:
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                name = _called_name(node)
                if name in _BLOCKERS:
                    blocked = True
                elif name in device_names:
                    device_calls.append(node)
        if blocked:
            return
        for call in device_calls:
            yield self.finding(
                ctx, call,
                f"device call '{_called_name(call)}' inside a timed "
                "region is never materialized before the clock read — "
                "JAX dispatch is async, so the region times the enqueue "
                "only; wrap it in jax.block_until_ready(...) (or read "
                "the result with .item()/np.asarray) before stopping "
                "the clock")
