# The paper's primary contribution: decentralized kernel PCA with projection
# consensus constraints (He, Yang, Shi, Huang — cs.DC 2022), plus its central
# and local baselines.
from .admm import (DkpcaResult, DkpcaSetup, admm_iteration,
                   augmented_lagrangian, build_setup, run_admm, theorem2_rho)
from .central import central_kpca, kpca_project
from .kernels_math import (KernelSpec, center_gram, center_gram_global, gram,
                           pairwise_sqdist, psd_jitter_eigh, resolve_gamma,
                           topk_eigh)
from .local import local_kpca, neighborhood_kpca
from .metrics import similarity, subspace_alignment
from .oos import FittedKpca, ShardedFittedKpca
from .rho import RhoSchedule, assumption2_rho, auto_rho
from .solver import AdmmState, ChunkResult, run_chunked
from . import oos, solver, topology

__all__ = [
    "AdmmState", "ChunkResult", "DkpcaResult", "DkpcaSetup", "FittedKpca",
    "KernelSpec", "RhoSchedule", "ShardedFittedKpca",
    "admm_iteration", "assumption2_rho", "augmented_lagrangian", "auto_rho",
    "build_setup", "center_gram", "center_gram_global", "central_kpca",
    "gram", "kpca_project", "local_kpca", "metrics", "neighborhood_kpca",
    "oos", "pairwise_sqdist", "psd_jitter_eigh", "resolve_gamma", "run_admm",
    "run_chunked", "similarity", "solver", "subspace_alignment",
    "theorem2_rho", "topk_eigh", "topology",
]
