"""Assigned input shapes and abstract input specs.

    train_4k      seq_len=4096    global_batch=256   (training)
    prefill_32k   seq_len=32768   global_batch=32    (inference-prefill)
    decode_32k    seq_len=32768   global_batch=128   (one-token decode,
                                                      KV cache of seq_len)
    long_500k     seq_len=524288  global_batch=1     (long-context decode)

``decode_*``/``long_*`` lower ``serve_step``, not ``train_step``.
``long_500k`` is restricted to sub-quadratic archs (SSM / hybrid / SWA) —
see DESIGN.md §Arch-applicability. ``input_specs`` returns
ShapeDtypeStructs only (no allocation)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is this (arch, shape) cell runnable? (Skips mandated by the spec.)"""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention at 524288-token context "
                       "has no sub-quadratic path (skip mandated by the "
                       "assignment; see DESIGN.md §Arch-applicability)")
    return True, ""


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec,
                      seq: Optional[int] = None) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract train/prefill batch. seq_len counts the decoder/backbone
    sequence; VLM prefixes frontend_seq patch embeddings within it.
    ``seq`` overrides the token length (cost-fit variants) while keeping
    frame-stub lengths pinned to the full shape."""
    b, s = shape.batch, seq or shape.seq
    sd = jax.ShapeDtypeStruct
    if cfg.family == "audio":
        return {
            "frames": sd((b, min(cfg.enc_seq, shape.seq), cfg.d_model),
                         jnp.bfloat16),
            "tokens": sd((b, s), jnp.int32),
            "labels": sd((b, s), jnp.int32),
        }
    if cfg.family == "vlm" and cfg.frontend_seq:
        f = cfg.frontend_seq
        return {
            "frontend": sd((b, f, cfg.d_model), jnp.bfloat16),
            "tokens": sd((b, s - f), jnp.int32),
            "labels": sd((b, s - f), jnp.int32),
        }
    return {"tokens": sd((b, s), jnp.int32), "labels": sd((b, s), jnp.int32)}


def decode_specs(model, cfg: ArchConfig, shape: ShapeSpec):
    """Abstract (cache, tokens, cache_len) for serve_step."""
    b, s = shape.batch, shape.seq
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cache_len = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, cache_len


def concrete_train_batch(cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
    """Small concrete batch for smoke tests / examples."""
    import numpy as np
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, size=(batch, seq), dtype=np.int32)
    out = {"tokens": jnp.asarray(toks),
           "labels": jnp.asarray(np.roll(toks, -1, axis=1))}
    if cfg.family == "vlm" and cfg.frontend_seq:
        out["frontend"] = jnp.asarray(
            rng.normal(0, 0.02, size=(batch, cfg.frontend_seq, cfg.d_model))
            .astype(np.float32))
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.normal(0, 0.02, size=(batch, min(cfg.enc_seq, 4 * seq),
                                      cfg.d_model)).astype(np.float32))
    return out
