"""Fault-tolerance cost benchmark: what does surviving a fault cost?

Two recovery paths, timed end to end (docs/FAULT_TOLERANCE.md):

  1. ADMM dropout recovery — the re-knit + state-shrink + setup-rebuild
     pause when nodes leave mid-run, and the throughput cost of running
     the solver with an active link mask vs the untouched fault-free
     path (the mask becomes a traced operand only when faults exist;
     fault-free stays the baseline jaxpr).
  2. Serving shard-loss re-balance — latency of ``oos.drop_shard`` +
     the atomic publish, and the end-to-end request latency of a batch
     that hits the loss, retries, and serves from the survivor model.

Rows follow the harness convention (name, us_per_call, derived).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, oos
from repro.core.topology import ring
from repro.data import kpca_dataset, node_dataset
from repro.faults import (FaultPlan, FaultTolerantRun, NodeDropout,
                          ShardLoss, ShardLossInjector, ShardRebalancer)
from repro.serve import KpcaEngine, KpcaServeConfig, ModelHandle

SPEC = KernelSpec(kind="rbf")


def _drive(run: FaultTolerantRun) -> None:
    for _ in run.chunks():
        pass


def _admm_dropout_rows(m: int = 24):
    nodes, _ = node_dataset(12, 40, m=m, seed=4)
    graph = ring(12, hops=2)
    kw = dict(n_iters=30, chunk=10)

    run = FaultTolerantRun(nodes, graph, SPEC, FaultPlan(), **kw)
    t0 = time.perf_counter()
    _drive(run)
    clean_s = time.perf_counter() - t0

    plan = FaultPlan(dropouts=(NodeDropout(t=15, node=3),
                               NodeDropout(t=15, node=7)))
    run = FaultTolerantRun(nodes, graph, SPEC, plan, **kw)
    t0 = time.perf_counter()
    _drive(run)
    faulty_s = time.perf_counter() - t0
    # the faulty run does the same 30 iterations (on 12 then 10 nodes)
    # plus one recovery: the delta is reknit + shrink + setup rebuild +
    # the survivor-shape retrace
    t_recover_us = (faulty_s - clean_s) * 1e6
    rows = [
        ("faults/admm_clean_30it", clean_s * 1e6 / 30, "per-iter;12nodes"),
        ("faults/admm_dropout_30it", faulty_s * 1e6 / 30,
         f"per-iter;drop2@15;reknits={run.n_reknits}"),
        ("faults/dropout_recovery_overhead", max(t_recover_us, 0.0),
         "total-extra;reknit+shrink+rebuild+retrace"),
    ]
    return rows


def _serving_rebalance_rows():
    x = jnp.asarray(kpca_dataset(96, m=12, seed=0))
    model = oos.fit_central(x, SPEC, n_components=2, center=True)
    sharded, _ = oos.shard_fitted(model, 4)

    # bare drop_shard + publish: the atomic re-balance itself
    handle = ModelHandle(sharded)
    reb = ShardRebalancer()
    from repro.faults.errors import ShardLostError
    t0 = time.perf_counter()
    reb(ShardLostError(2), handle)
    rebalance_us = (time.perf_counter() - t0) * 1e6

    # end-to-end: a request that hits the loss, retries, serves survivor
    handle2 = ModelHandle(sharded)
    eng = KpcaEngine(
        handle2,
        KpcaServeConfig(max_batch=16, min_bucket=8, max_retries=2,
                        retry_backoff_s=0.001),
        inject_fault=ShardLossInjector(
            FaultPlan(shard_losses=(ShardLoss(at_dispatch=0, shard=1),))),
        on_fault=ShardRebalancer())
    xq = np.random.default_rng(0).normal(size=(8, 12)).astype(np.float32)
    eng.project_many([xq])                  # dispatch 0: fault -> rebalance
    t0 = time.perf_counter()
    eng.project_many([xq])
    healed_us = (time.perf_counter() - t0) * 1e6

    eng2 = KpcaEngine(ModelHandle(sharded),
                      KpcaServeConfig(max_batch=16, min_bucket=8))
    eng2.project_many([xq])
    t0 = time.perf_counter()
    eng2.project_many([xq])
    clean_us = (time.perf_counter() - t0) * 1e6
    return [
        ("faults/rebalance_publish", rebalance_us, "drop_shard+publish"),
        ("faults/serve_clean", clean_us, "8q;4shards"),
        ("faults/serve_post_recovery", healed_us, "8q;survivor-model"),
    ]


def bench_faults(m: int = 24):
    return _admm_dropout_rows(m=m) + _serving_rebalance_rows()


if __name__ == "__main__":
    for row in bench_faults():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
