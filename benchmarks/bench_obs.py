"""Observability overhead + communication accounting benchmark.

Three questions the flight recorder must answer about itself:

  1. what does a DISABLED tracer cost on the hot path (the no-op span —
     this is the price every serve request pays all the time);
  2. what does an ENABLED tracer / a metric update cost (the opt-in price);
  3. what does one ADMM iteration actually move over the wire, per
     transport (the ``CommLedger`` numbers the paper's §4.2 cost analysis
     predicts analytically).

Plus the phase breakdown of an async drain (pack / dispatch / device /
resolve span means) measured from a live traced engine — the numbers
``benchmarks/run.py`` lifts into the committed BENCH json as derived
fields (``bytes_per_iter``, ``flush_phase_ms``).

Rows follow the harness convention (name, us_per_call, derived).
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import KernelSpec, build_setup, oos
from repro.core.solver import run_chunked
from repro.core.topology import ring
from repro.data import kpca_dataset, node_dataset
from repro.obs import metrics, trace
from repro.obs.comm import CommLedger
from repro.serve import KpcaEngine, KpcaServeConfig

SPEC = KernelSpec(kind="rbf")


def _time_span_loop(n: int) -> float:
    """us per ``with trace.span(...)`` round trip."""
    t0 = time.perf_counter()
    for _ in range(n):
        with trace.span("bench.overhead"):
            pass
    return (time.perf_counter() - t0) / n * 1e6


def _span_overhead_rows(n: int = 50_000):
    rows = []
    was = trace.active()
    trace.disable()
    rows.append(("obs/span_disabled", _time_span_loop(n),
                 "noop-singleton;per-call"))
    t = trace.enable(capacity=4096)          # ring absorbs n >> capacity
    rows.append(("obs/span_enabled", _time_span_loop(n),
                 f"recorded={t.n_recorded};dropped={t.n_dropped}"))
    trace.install(was)                       # hand back an outer --trace-out
    c = metrics.counter("bench_obs_overhead_total", "bench-only")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
    rows.append(("obs/counter_inc", (time.perf_counter() - t0) / n * 1e6,
                 "locked-counter;per-call"))
    return rows


def _flush_phase_rows(m: int = 64):
    """Mean per-drain span durations from a live traced async engine."""
    x = jnp.asarray(kpca_dataset(256, m=m, seed=0))
    model = oos.fit_central(x, SPEC, n_components=2, center=True)
    eng = KpcaEngine(model, KpcaServeConfig(
        max_batch=64, min_bucket=8, flush_max_wait_s=0.002))
    eng.warmup()                   # compile every bucket before timing
    eng.stats = type(eng.stats)()

    was = trace.active()
    tr = was if was is not None else trace.enable()
    rng = np.random.default_rng(0)
    reqs = [rng.normal(size=(int(q), m)).astype(np.float32)
            for q in rng.integers(1, 33, size=96)]
    t0 = time.perf_counter()
    with eng:
        futs = []

        def submitter(lo):
            for r in reqs[lo::2]:
                futs.append(eng.submit(r))

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for f in list(futs):
            f.result(timeout=60.0)
    wall = time.perf_counter() - t0

    def mean_ms(name):
        d = tr.durations(name)
        return float(np.mean(d)) * 1e3 if d else 0.0

    phases = {p: mean_ms(f"serve.{p}")
              for p in ("pack", "dispatch", "device", "resolve")}
    if was is None:
        trace.disable()
    derived = ";".join(f"flush_{p}_ms={v:.4f}" for p, v in phases.items())
    return [("obs/flush_phases", wall / len(reqs) * 1e6,
             derived + f";flushes={eng.stats.n_flushes}")]


def _comm_rows():
    """Measured per-iteration wire traffic, dense reference transport (and
    the SPMD ring when enough devices are exposed)."""
    rows = []
    nodes, _ = node_dataset(n_nodes=8, n_per_node=16, m=12, seed=0)
    setup = build_setup(jnp.asarray(nodes), ring(8, hops=2), SPEC)
    led = CommLedger()
    t0 = time.perf_counter()
    for _ in run_chunked(setup, n_iters=8, chunk=4, ledger=led):
        pass
    dt = time.perf_counter() - t0
    p = led.per_iter
    rows.append(("obs/comm_dense", dt / 8 * 1e6,
                 f"bytes_per_iter={p.bytes};msgs_per_iter={p.messages};"
                 f"scope=network;iters={led.iterations}"))

    if jax.device_count() >= 4:
        from jax.sharding import Mesh
        from repro.core.dkpca import dkpca_distributed
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4, 1),
                    ("data", "model"))
        led2 = CommLedger()
        x = jnp.asarray(node_dataset(n_nodes=4, n_per_node=16, m=12,
                                     seed=1)[0])
        t0 = time.perf_counter()
        dkpca_distributed(x, mesh, hops=1, n_iters=8, ledger=led2)
        dt = time.perf_counter() - t0
        p = led2.per_iter
        rows.append((
            "obs/comm_ring", dt / 8 * 1e6,
            f"bytes_per_iter={p.bytes};msgs_per_iter={p.messages};"
            f"collectives_per_iter={p.collectives};scope=per-node;"
            f"setup_bytes={led2.setup.bytes}"))
    return rows


def bench_obs(m: int = 64):
    return _span_overhead_rows() + _flush_phase_rows(m=m) + _comm_rows()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in bench_obs():
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
