"""Quickstart: decentralized kernel PCA in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Eight nodes on a ring, each holding 60 local samples, agree on the global
first kernel principal component without any fusion center — then we check
the result against central kPCA (which needs all the data in one place)."""

import jax.numpy as jnp
import numpy as np

from repro.core import (KernelSpec, build_setup, central_kpca, run_admm,
                        similarity)
from repro.core.topology import ring
from repro.data import node_dataset


def main():
    nodes, pooled = node_dataset(n_nodes=8, n_per_node=60, m=64, seed=0)
    graph = ring(8, hops=2)                      # paper: 4 nearest neighbors
    spec = KernelSpec(kind="rbf")                # gamma: median heuristic

    setup = build_setup(jnp.asarray(nodes), graph, spec)
    result = run_admm(setup, n_iters=30)         # paper Alg. 1

    alpha_gt, _, _ = central_kpca(jnp.asarray(pooled), spec, 1,
                                  gamma=setup.gamma)
    sims = [float(similarity(result.alpha[j], jnp.asarray(nodes[j]),
                             alpha_gt[:, 0], jnp.asarray(pooled), spec,
                             gamma=setup.gamma))
            for j in range(8)]
    print("per-node similarity to the central solution:")
    for j, s in enumerate(sims):
        print(f"  node {j}: {s:.4f}")
    print(f"mean: {np.mean(sims):.4f}  "
          f"(paper Fig 3 reports > 0.91 in this regime)")
    assert np.mean(sims) > 0.9


if __name__ == "__main__":
    main()
