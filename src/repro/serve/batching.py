"""Shared request batching/queueing layer for the serving engines.

``DecodeEngine`` (token slots) and ``KpcaEngine`` (projection slabs) shape
traffic the same way: variable-size requests go into a FIFO queue, a
drainer packs them into fixed-shape device batches, and per-request
accounting rides along. This module owns that machinery once:

  * ``RequestQueue`` — thread-safe FIFO of ``Request`` entries with an
    optional admission bound: when the queued work exceeds ``max_queries``
    the queue either REJECTS the new request (``QueueFullError``) or SHEDS
    the oldest queued ones (their futures fail) to admit it. A condition
    variable lets a background drainer sleep until a size-or-deadline
    trigger fires (``wait_for_work``).
  * ``RequestFuture`` — a ``concurrent.futures.Future`` carrying the
    request id/size, the handle ``submit()`` returns in the async API.
  * ``SlotFuture``/``FlushSlots`` — the zero-churn replacement on the kPCA
    hot path: one result slot table and ONE ``threading.Event`` per flush;
    every future of a drain is resolved by slab index with a single event
    broadcast instead of per-future condition variables
    (``RequestQueue(slot_futures=True)``).
  * ``SlabArena`` — preallocated host staging: requests copy their rows
    into a pinned ring buffer at SUBMIT time, so the flusher's pack step
    is a slice (``pack_slabs``), not a gather-and-concatenate; per-bucket
    frame pools absorb the non-contiguous leftovers without per-flush
    allocation.
  * pow2 shape buckets (``pow2_buckets``/``bucket_for``) and slab packing
    (``pack_slabs`` arena-aware plan packing and the legacy ``iter_slabs``
    head-to-tail rows for kPCA, ``left_pad_pack`` padded token waves for
    decode) — the fixed set of compiled shapes that keeps any request mix
    recompile-free in steady state.
  * per-request accounting (``RequestStats``/``EngineStats``).

Everything here is engine-agnostic: payloads are opaque, only their row
count ``n`` matters to the queue and the packers.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Deque, List, Optional, Sequence, Tuple

import numpy as np


# ---- accounting -----------------------------------------------------------

# Window of recent per-request records kept by ``EngineStats``: enough for
# stable p50/p99 estimates, bounded so a long-running async engine cannot
# grow without limit (requests beyond the window age out oldest-first).
PER_REQUEST_WINDOW = 4096

@dataclasses.dataclass
class RequestStats:
    request_id: int
    n_queries: int
    latency_s: float              # wall time inside the engine for this req
    model_version: int = 0        # handle version this request was served at
    queue_wait_s: float = 0.0     # submit -> start-of-serve wait (async path)


@dataclasses.dataclass
class EngineStats:
    n_requests: int = 0
    n_queries: int = 0
    n_padded: int = 0             # wasted pad rows actually computed
    n_compiles: int = 0           # distinct (bucket) programs built
    n_rejected: int = 0           # admissions refused (QueueFullError)
    n_shed: int = 0               # queued requests dropped to admit newer
    n_flushes: int = 0            # drain cycles that served >= 1 request
    n_retries: int = 0            # drain attempts retried after a fault
    n_deadline_expired: int = 0   # requests failed on the request deadline
    n_donated: int = 0            # dispatches through donated entry points
    n_warmup_compiles: int = 0    # programs built by the start() warmup pass
    n_zero_copy_slabs: int = 0    # slabs served as arena slices (no copy)
    n_arena_fallback: int = 0     # submits that missed the arena ring
    n_routed_mp: int = 0          # sharded slabs routed model-parallel
    n_routed_dp: int = 0          # sharded slabs routed data-parallel
    n_routed_single: int = 0      # sharded slabs routed single-device
    max_inflight_drains: int = 0  # peak pipelined drains in flight at once
    total_time_s: float = 0.0
    # Ring of the most recent PER_REQUEST_WINDOW requests (bounded: a
    # long-running async engine must not accumulate one record per request
    # forever). Aggregate counters above cover the full history; the ring
    # feeds the percentile estimates.
    per_request: Deque[RequestStats] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=PER_REQUEST_WINDOW))

    @property
    def queries_per_s(self) -> float:
        return self.n_queries / self.total_time_s if self.total_time_s else 0.0

    def routing_summary(self) -> str:
        """Compact ``policy:count`` rendering of the sharded routing
        decisions (bench ``derived`` strings); "-" when nothing routed
        (single-device models)."""
        parts = [(p, getattr(self, f"n_routed_{p}"))
                 for p in ("mp", "dp", "single")]
        return ",".join(f"{p}:{n}" for p, n in parts if n) or "-"

    def latency_percentiles(self, qs=(50, 99)) -> Tuple[float, ...]:
        """Per-request latency percentiles in seconds over the retained
        window (last ``PER_REQUEST_WINDOW`` requests), one per entry of
        ``qs`` (default p50/p99); (0.0, ...) before any request is served."""
        lat = [r.latency_s for r in self.per_request] or [0.0]
        return tuple(float(np.percentile(lat, q)) for q in qs)


def format_latency(seconds: float) -> str:
    """Render a latency for human-facing derived strings.

    µs below 0.1 ms (sub-millisecond percentiles must not round down to
    "0.00ms"), ms below 1 s, seconds above. JSON rows keep the raw
    seconds — only the display string is quantized.
    """
    if seconds < 1e-4:
        return f"{seconds * 1e6:.1f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds:.3f}s"


# ---- queue ----------------------------------------------------------------

class QueueFullError(RuntimeError):
    """Admission control refused a request (queue at capacity)."""


class ShedError(RuntimeError):
    """This queued request was shed to admit a newer one."""


class RequestFuture(concurrent.futures.Future):
    """Future for one request's result, tagged with its queue identity."""

    def __init__(self, request_id: int, n: int):
        super().__init__()
        self.request_id = request_id
        self.n = n


# SlotFuture lifecycle states (terminal unless _PENDING).
_PENDING, _CANCELLED, _EXCEPTION, _RESULT = range(4)


class FlushSlots:
    """One flush's shared result table: the flusher publishes ``results``
    (list indexed by slab order) or ``error`` exactly once, then sets
    ``event`` — a single broadcast resolves every future of the drain.

    A "void" publish (event set with BOTH fields still None) means the
    flush failed and its entries were restored for retry; waiters go back
    to sleep until a later flush rebinds them.
    """

    __slots__ = ("event", "results", "error")

    def __init__(self):
        self.event = threading.Event()
        self.results: Optional[List[Any]] = None
        self.error: Optional[BaseException] = None


class SlotFuture:
    """Zero-churn replacement for ``RequestFuture`` on the hot path.

    Instead of one lock + condition variable per request
    (``concurrent.futures.Future`` carries both), every SlotFuture of a
    queue shares the queue's condition variable for the pre-bind wait and
    resolves through a per-flush ``FlushSlots`` table by index: the
    flusher publishes the whole result list and fires ONE event.

    Supports the ``concurrent.futures.Future`` surface the engines and
    tests use: ``result``/``exception`` (with timeout), ``done``,
    ``cancel``/``cancelled``, ``set_result``/``set_exception``.
    """

    __slots__ = ("request_id", "n", "_cond", "_slots", "_index",
                 "_state", "_value")

    def __init__(self, request_id: int, n: int, cond: threading.Condition):
        self.request_id = request_id
        self.n = n
        self._cond = cond
        self._slots: Optional[FlushSlots] = None   # guarded-by: _cond
        self._index = -1                           # guarded-by: _cond
        self._state = _PENDING                     # guarded-by: _cond
        self._value: Any = None                    # guarded-by: _cond

    # -- flusher side -------------------------------------------------------

    @staticmethod
    def bind(pairs: Sequence[Tuple["SlotFuture", int]],
             slots: FlushSlots) -> None:
        """Attach (future, result-index) pairs to one flush's slot table
        with a single notification."""
        if not pairs:
            return
        cond = pairs[0][0]._cond
        with cond:
            for fut, idx in pairs:
                if fut._state == _PENDING:
                    fut._slots, fut._index = slots, idx
            cond.notify_all()

    @staticmethod
    def unbind(futures: Sequence["SlotFuture"]) -> None:
        """Detach futures from their flush (failed flush, entries being
        restored for retry). The flusher must still void-publish the old
        ``FlushSlots`` afterwards so in-flight waiters wake and re-wait."""
        if not futures:
            return
        cond = futures[0]._cond
        with cond:
            for fut in futures:
                fut._slots, fut._index = None, -1

    # -- waiter side --------------------------------------------------------

    def _outcome(self, timeout: Optional[float]):
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._cond:
                while True:
                    if self._state == _CANCELLED:
                        return "cancelled", None
                    if self._state == _EXCEPTION:
                        return "exception", self._value
                    if self._state == _RESULT:
                        return "result", self._value
                    slots, index = self._slots, self._index
                    if slots is not None:
                        break
                    left = None if deadline is None \
                        else deadline - time.monotonic()
                    if left is not None and left <= 0:
                        raise concurrent.futures.TimeoutError()
                    self._cond.wait(timeout=left)
            # Even with the deadline already past, a published table still
            # resolves: event.wait(0) just reads the flag.
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            if not slots.event.wait(timeout=left):
                raise concurrent.futures.TimeoutError()
            if slots.error is not None:
                return "exception", slots.error
            if slots.results is not None:
                return "result", slots.results[index]
            # Void publish: flush failed, entries restored for retry.
            # Drop the stale binding (unless already rebound) and re-wait.
            with self._cond:
                if self._slots is slots:
                    self._slots, self._index = None, -1

    def result(self, timeout: Optional[float] = None):
        kind, value = self._outcome(timeout)
        if kind == "cancelled":
            raise concurrent.futures.CancelledError()
        if kind == "exception":
            raise value
        return value

    def exception(self, timeout: Optional[float] = None):
        kind, value = self._outcome(timeout)
        if kind == "cancelled":
            raise concurrent.futures.CancelledError()
        return value if kind == "exception" else None

    def done(self) -> bool:
        with self._cond:
            if self._state != _PENDING:
                return True
            slots = self._slots
        return slots is not None and slots.event.is_set() and \
            (slots.results is not None or slots.error is not None)

    def running(self) -> bool:
        return False

    def cancelled(self) -> bool:
        with self._cond:
            return self._state == _CANCELLED

    def cancel(self) -> bool:
        with self._cond:
            if self._state == _CANCELLED:
                return True
            if self._state != _PENDING or self._slots is not None:
                return False
            self._state = _CANCELLED
            self._cond.notify_all()
        return True

    # Direct per-future resolution stays available for the fault paths
    # (deadline expiry, shed) where no flush table exists. Terminal states
    # win; late sets after a broadcast resolution are ignored.
    def set_result(self, value) -> None:
        with self._cond:
            if self._state != _PENDING:
                return
            self._state, self._value = _RESULT, value
            self._cond.notify_all()

    def set_exception(self, exc: BaseException) -> None:
        with self._cond:
            if self._state != _PENDING:
                return
            self._state, self._value = _EXCEPTION, exc
            self._cond.notify_all()


@dataclasses.dataclass
class Request:
    """One queued request: opaque payload + its row count and future.

    ``arena_start`` is the row offset of this request's staged copy inside
    the engine's ``SlabArena`` (None = payload lives in ``payload`` only).
    """

    rid: int
    payload: Any
    n: int
    future: Any
    t_submit: float
    arena_start: Optional[int] = None


class RequestQueue:
    """Thread-safe bounded FIFO with admission control and a drain trigger.

    ``max_queries`` bounds the total queued row count (None = unbounded).
    ``policy`` picks what happens when an admission would exceed it:
    "reject" raises ``QueueFullError`` at ``put``; "shed" drops the OLDEST
    queued requests (failing their futures with ``ShedError``) until the
    new one fits — latency-loving head drop, matching LM-serving practice
    where a stale queued request is worth less than a fresh one. A request
    larger than the whole capacity is always rejected.

    ``slot_futures=True`` makes ``put`` hand out ``SlotFuture``s (sharing
    this queue's condition variable) instead of ``RequestFuture``s — the
    zero-churn hot path. ``on_shed`` is called (outside the lock, before
    the shed futures are failed) with the list of dropped ``Request``
    entries so the owner can reclaim resources (e.g. arena rows).
    """

    def __init__(self, max_queries: Optional[int] = None,
                 policy: str = "reject", slot_futures: bool = False,
                 on_shed=None):
        if policy not in ("reject", "shed"):
            raise ValueError(f"unknown admission policy {policy!r}")
        if max_queries is not None and max_queries < 1:
            raise ValueError(f"max_queries must be >= 1, got {max_queries}")
        self.max_queries = max_queries
        self.policy = policy
        self.slot_futures = slot_futures
        self.on_shed = on_shed
        self._cond = threading.Condition()
        self._entries: List[Request] = []   # guarded-by: _cond
        self._depth = 0               # queued rows     guarded-by: _cond
        self._next_id = 0                   # guarded-by: _cond
        self.n_rejected = 0                 # guarded-by: _cond
        self.n_shed = 0                     # guarded-by: _cond
        self.depth_peak = 0                 # guarded-by: _cond

    # -- producer side ------------------------------------------------------

    def put(self, payload: Any, n: int,
            arena_start: Optional[int] = None) -> Tuple[Any, List[Any]]:
        """Enqueue one request of ``n`` rows.

        Returns (future, shed) where ``shed`` lists the futures of any
        requests dropped to admit this one (empty unless policy="shed").
        Raises ``QueueFullError`` when the request cannot be admitted.
        """
        with self._cond:
            shed_entries: List[Request] = []
            if self.max_queries is not None and \
                    self._depth + n > self.max_queries:
                if n > self.max_queries or self.policy == "reject":
                    self.n_rejected += 1
                    raise QueueFullError(
                        f"queue at capacity ({self._depth}/"
                        f"{self.max_queries} rows queued, request adds {n})")
                while self._entries and self._depth + n > self.max_queries:
                    old = self._entries.pop(0)
                    self._depth -= old.n
                    self.n_shed += 1
                    shed_entries.append(old)
            rid = self._next_id
            self._next_id += 1
            if self.slot_futures:
                fut: Any = SlotFuture(rid, n, self._cond)
            else:
                fut = RequestFuture(rid, n)
            self._entries.append(
                Request(rid, payload, n, fut, time.monotonic(), arena_start))
            self._depth += n
            self.depth_peak = max(self.depth_peak, self._depth)
            self._cond.notify_all()
        if shed_entries and self.on_shed is not None:
            self.on_shed(shed_entries)
        for e in shed_entries:
            e.future.set_exception(ShedError("shed by admission control"))
        return fut, [e.future for e in shed_entries]

    # -- consumer side ------------------------------------------------------

    @property
    def depth(self) -> int:
        """Queued rows (not requests)."""
        with self._cond:
            return self._depth

    def __len__(self) -> int:
        with self._cond:
            return len(self._entries)

    def drain(self) -> List[Request]:
        """Atomically take everything queued (FIFO order)."""
        with self._cond:
            out, self._entries = self._entries, []
            self._depth = 0
            return out

    def take(self, n_requests: int) -> List[Request]:
        """Atomically take up to ``n_requests`` entries from the head."""
        with self._cond:
            out = self._entries[:n_requests]
            self._entries = self._entries[n_requests:]
            for e in out:
                self._depth -= e.n
            return out

    def restore(self, entries: Sequence[Request]) -> None:
        """Put drained entries back at the FRONT (failed-flush retry)."""
        with self._cond:
            self._entries = list(entries) + self._entries
            self._depth += sum(e.n for e in entries)
            self._cond.notify_all()

    def kick(self) -> None:
        """Wake any ``wait_for_work`` sleeper (e.g. on engine shutdown)."""
        with self._cond:
            self._cond.notify_all()

    def coalesce(self, max_rows: int, stall_s: float,
                 stop: threading.Event) -> None:
        """Post-trigger arrival damper: after a flush trigger fires, keep
        YIELDING the core to submitter threads as long as rows keep
        arriving, so a wave of concurrent submitters lands in one drain
        instead of one drain per submit. Returns once no new rows have
        arrived for ``stall_s`` seconds, ``max_rows`` is queued, or
        ``stop`` is set. ``time.sleep(0)`` (sched_yield) instead of a
        timed condition wait: sub-millisecond ``Condition.wait(timeout)``
        overshoots its timeout ~2-3x on Linux, while a yield loop tracks
        arrivals at scheduler granularity — worst-case cost is one quiet
        ``stall_s``, and each yield hands the core to whoever has work."""
        if stall_s <= 0:
            return
        with self._cond:
            last = self._depth
        if not 0 < last < max_rows:
            return
        t_stall = time.perf_counter()
        while not stop.is_set():
            time.sleep(0)                  # yield: let submitters run
            with self._cond:
                d = self._depth
            if d >= max_rows or d == 0:
                return
            if d != last:
                last, t_stall = d, time.perf_counter()
            elif time.perf_counter() - t_stall >= stall_s:
                return

    def wait_for_work(self, min_queries: int, max_wait_s: float,
                      stop: threading.Event) -> bool:
        """Sleep until a flush trigger fires: queued rows reach
        ``min_queries``, OR the oldest entry has waited ``max_wait_s``
        since submit, OR ``stop`` is set. Returns True when there is
        anything queued (the caller should drain), False otherwise.
        """
        with self._cond:
            while not stop.is_set():
                if self._entries:
                    if self._depth >= min_queries:
                        return True
                    age = time.monotonic() - self._entries[0].t_submit
                    if age >= max_wait_s:
                        return True
                    self._cond.wait(timeout=max_wait_s - age)
                else:
                    self._cond.wait(timeout=0.1)
            return bool(self._entries)


# ---- shape buckets --------------------------------------------------------

def pow2_buckets(min_bucket: int, max_batch: int) -> List[int]:
    """Power-of-two widths: min_bucket, 2*min_bucket, ..., max_batch."""
    if not 0 < min_bucket <= max_batch:
        raise ValueError(f"need 0 < min_bucket <= max_batch, got "
                         f"min_bucket={min_bucket} max_batch={max_batch}")
    out, b = [], min_bucket
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


def bucket_for(buckets: Sequence[int], size: int) -> int:
    """Smallest bucket holding ``size`` rows (widest bucket for overflow —
    callers split anything larger across multiple slabs)."""
    for b in buckets:
        if size <= b:
            return b
    return buckets[-1]


# ---- slab packing ---------------------------------------------------------

def iter_slabs(entries: Sequence[Request], max_batch: int,
               buckets: Sequence[int]):
    """Head-to-tail pack 2-D float payloads into pow2-bucketed slabs.

    Concatenates every entry's ``payload`` rows into one flat stream and
    yields ``(slab, take, owners)`` per device batch: ``slab`` is a
    (bucket, M) float32 array whose first ``take`` rows are real,
    ``owners`` maps each real row back to its request id. Row-wise kernel
    math makes valid rows independent of the zero padding, so per-request
    results are exactly the unbatched ones.
    """
    if not entries:
        return
    stream = np.concatenate([e.payload for e in entries], axis=0)
    owners = np.concatenate(
        [np.full(e.n, e.rid, np.int64) for e in entries])
    pos = 0
    while pos < stream.shape[0]:
        take = min(max_batch, stream.shape[0] - pos)
        bucket = bucket_for(buckets, take)
        slab = np.zeros((bucket, stream.shape[1]), np.float32)
        slab[:take] = stream[pos:pos + take]
        yield slab, take, owners[pos:pos + take]
        pos += take


class SlabArena:
    """Preallocated host staging ring for request rows.

    Submitters copy their query rows into one pinned ``(capacity, M)``
    buffer at submit time (``stage``); the flusher packs slabs as SLICES
    of that buffer (``pack_slabs``) instead of gather-and-concatenate, and
    releases each request's rows once its results are assembled
    (``release``). Rows are handed out as contiguous runs from a ring:
    FIFO staging + FIFO release means reclamation is almost always a
    cheap released-prefix pop.

    Per-bucket frame pools (``acquire_frame``/``release_frame``) cover the
    slabs that cannot be served as one contiguous arena slice — those are
    copied into a reused frame, never a fresh allocation in steady state.

    Thread-safe; stats counters are read racily for reporting.
    """

    def __init__(self, n_features: int, capacity_rows: int,
                 dtype=np.float32, max_frames_per_bucket: int = 8):
        if capacity_rows < 1 or n_features < 1:
            raise ValueError("SlabArena needs capacity_rows, n_features >= 1")
        self.n_features = int(n_features)
        self.capacity = int(capacity_rows)
        self.buf = np.zeros((self.capacity, self.n_features), dtype)
        self._lock = threading.Lock()
        # Live staged runs, FIFO: [start, n, released]. guarded-by: _lock
        self._segs: Deque[list] = collections.deque()
        self._tail = 0                      # guarded-by: _lock
        self._high_water = 0                # guarded-by: _lock
        self._frames: dict = {}             # bucket -> [frame]  gb: _lock
        self._max_frames = max_frames_per_bucket
        self.n_staged = 0                   # guarded-by: _lock
        self.n_reused_rows = 0              # guarded-by: _lock
        self.n_fallback = 0                 # guarded-by: _lock
        self.n_frame_allocs = 0             # guarded-by: _lock

    # -- row ring -----------------------------------------------------------

    @staticmethod
    def _find_run(n: int, capacity: int, head: Optional[int],
                  tail: int) -> Optional[int]:
        """Pure ring geometry: first start row fitting an ``n``-row run,
        given the oldest live start (``head``, None when empty) and the
        next free row (``tail``). Caller snapshots state under ``_lock``."""
        if head is None:                    # ring empty
            return 0 if n <= capacity else None
        if tail > head:                     # one occupied span [head, tail)
            if capacity - tail >= n:
                return tail
            if head >= n:
                return 0                    # wrap
            return None
        if tail < head:                     # wrapped: occupied both ends
            return tail if head - tail >= n else None
        return None                         # tail == head: ring full

    def stage(self, x: np.ndarray) -> Optional[int]:
        """Copy ``x`` (n, M) into the ring; returns the start row, or None
        when the ring cannot hold it (caller keeps its own copy)."""
        n = int(x.shape[0])
        if n == 0 or n > self.capacity:
            with self._lock:
                self.n_fallback += 1
            return None
        with self._lock:
            if not self._segs:
                self._tail = 0
            head = self._segs[0][0] if self._segs else None
            start = self._find_run(n, self.capacity, head, self._tail)
            if start is None:
                self.n_fallback += 1
                return None
            self._segs.append([start, n, False])
            self._tail = start + n
            self.n_staged += 1
            if start + n <= self._high_water:
                self.n_reused_rows += n
            else:
                self._high_water = max(self._high_water, start + n)
        # Copy OUTSIDE the lock: the run is exclusively ours once reserved,
        # and the queue entry referencing it is only published afterwards.
        self.buf[start:start + n] = x
        return start

    def release(self, start: int) -> None:
        """Return one staged run to the ring (results assembled)."""
        with self._lock:
            for seg in self._segs:
                if seg[0] == start and not seg[2]:
                    seg[2] = True
                    break
            while self._segs and self._segs[0][2]:
                self._segs.popleft()
            if not self._segs:
                self._tail = 0

    # -- frame pool ---------------------------------------------------------

    def acquire_frame(self, bucket: int) -> np.ndarray:
        """A reusable (bucket, M) scratch slab for non-contiguous packs."""
        with self._lock:
            pool = self._frames.get(bucket)
            if pool:
                return pool.pop()
            self.n_frame_allocs += 1
        return np.zeros((bucket, self.n_features), self.buf.dtype)

    def release_frame(self, frame: np.ndarray) -> None:
        with self._lock:
            pool = self._frames.setdefault(int(frame.shape[0]), [])
            if len(pool) < self._max_frames:
                pool.append(frame)

    def stats(self) -> dict:
        with self._lock:
            return {"n_staged": self.n_staged,
                    "n_reused_rows": self.n_reused_rows,
                    "n_fallback": self.n_fallback,
                    "n_frame_allocs": self.n_frame_allocs,
                    "live_runs": len(self._segs)}


def pack_slabs(entries: Sequence[Request], max_batch: int,
               buckets: Sequence[int], arena: Optional[SlabArena]):
    """Plan-pack drained entries into pow2-bucketed slabs.

    The arena-aware successor to ``iter_slabs``: when a slab's rows form
    one contiguous run of arena-staged requests (the common FIFO case),
    the slab IS a slice of the arena buffer — zero copies on the pack
    path. Otherwise rows are copied into a pooled frame. Pad rows of a
    zero-copy slab are whatever the arena holds; row-wise kernel math
    keeps valid rows independent of them, and the pad outputs are never
    read back.

    Returns ``(slabs, plan, frames)``:
      * ``slabs`` — list of ``(slab, take, zero_copy)``; first ``take``
        rows of each (bucket, M) ``slab`` are real.
      * ``plan`` — per entry (same order) a list of
        ``(slab_idx, row_in_slab, row_in_entry, n)`` segments mapping its
        rows to slab positions; result assembly is pure slicing.
      * ``frames`` — pooled frames to hand back via ``release_frame``
        once the flush's device results are on host.
    """
    plan: List[List[Tuple[int, int, int, int]]] = [[] for _ in entries]
    slabs: List[Tuple[np.ndarray, int, bool]] = []
    frames: List[np.ndarray] = []
    runs = []                    # (entry_idx, kind, ref, n_rows)
    for i, e in enumerate(entries):
        if e.n == 0:
            continue
        if arena is not None and e.arena_start is not None:
            runs.append((i, "arena", e.arena_start, e.n))
        else:
            runs.append((i, "mem", e.payload, e.n))
    if not runs:
        return slabs, plan, frames
    n_features = arena.n_features if arena is not None else \
        int(runs[0][2].shape[1])
    remaining = sum(n for (_i, _k, _ref, n) in runs)
    r, r_off = 0, 0
    while r < len(runs):
        # Best-fit tail split: pad rows cost real compute on row-
        # proportional backends, so when the leftover rows would pad far
        # past a smaller bucket (e.g. 66 rows -> a 128 slab), cut a FULL
        # smaller slab first (64 + an 8-tail beats 128 by 56 pad rows).
        # Only split when it saves at least two min-buckets of rows —
        # below that the extra program dispatch costs more than the pad.
        cap = max_batch
        if remaining < max_batch:
            b1 = bucket_for(buckets, remaining)
            lower = max((b for b in buckets if b <= remaining), default=None)
            if lower is not None and lower < remaining:
                rest = bucket_for(buckets, remaining - lower)
                if b1 - (lower + rest) >= 2 * buckets[0]:
                    cap = lower
        take = 0
        pieces = []              # (entry_idx, kind, ref, src_off, n)
        while r < len(runs) and take < cap:
            i, kind, ref, n = runs[r]
            m = min(n - r_off, cap - take)
            pieces.append((i, kind, ref, r_off, m))
            take += m
            r_off += m
            if r_off == n:
                r, r_off = r + 1, 0
        remaining -= take
        bucket = bucket_for(buckets, take)
        slab = None
        if arena is not None and all(p[1] == "arena" for p in pieces):
            s0 = pieces[0][2] + pieces[0][3]
            end = s0
            for (_i, _k, ref, off, m) in pieces:
                if ref + off != end:
                    end = -1
                    break
                end += m
            if end >= 0 and s0 + bucket <= arena.capacity:
                slab = arena.buf[s0:s0 + bucket]
        zero_copy = slab is not None
        if not zero_copy:
            if arena is not None:
                slab = arena.acquire_frame(bucket)
                frames.append(slab)
            else:
                slab = np.zeros((bucket, n_features), np.float32)
            row = 0
            for (_i, kind, ref, off, m) in pieces:
                if kind == "arena":
                    slab[row:row + m] = arena.buf[ref + off:ref + off + m]
                else:
                    slab[row:row + m] = ref[off:off + m]
                row += m
            if take < bucket:
                slab[take:bucket] = 0.0   # frames are reused: scrub pads
        row = 0
        for (i, _k, _ref, off, m) in pieces:
            plan[i].append((len(slabs), row, off, m))
            row += m
        slabs.append((slab, take, zero_copy))
    return slabs, plan, frames


def left_pad_pack(prompts: Sequence[Sequence[int]], slots: int,
                  pad_id: int = 0) -> Tuple[np.ndarray, int]:
    """Pack up to ``slots`` token prompts into one LEFT-padded int32 wave.

    Returns (toks, plen): toks is (slots, plen) with prompt i right-aligned
    in row i (rows beyond len(prompts) stay all-pad), plen the longest
    prompt. Left padding keeps the last prompt token in the last column, so
    one uniform-length prefill position works for the whole wave.
    """
    if not prompts:
        raise ValueError("left_pad_pack needs at least one prompt")
    if len(prompts) > slots:
        raise ValueError(f"{len(prompts)} prompts > {slots} slots")
    plen = max(len(p) for p in prompts)
    toks = np.full((slots, plen), pad_id, np.int32)
    for i, prompt in enumerate(prompts):
        if len(prompt):
            toks[i, plen - len(prompt):] = prompt
    return toks, plen


__all__ = [
    "EngineStats", "FlushSlots", "PER_REQUEST_WINDOW", "QueueFullError",
    "Request", "RequestFuture", "RequestQueue", "RequestStats", "ShedError",
    "SlabArena", "SlotFuture", "bucket_for", "format_latency", "iter_slabs",
    "left_pad_pack", "pack_slabs", "pow2_buckets",
]
