"""Synthetic datasets.

The container is offline, so MNIST (paper §6.1) is replaced by a generated
"digits-like" dataset with matched regime: M=784 features, a few nonlinear
class manifolds, values in [0, 1], randomly and evenly distributed to nodes.
The kPCA experiments sweep the same (J, N_j, |Omega|) grids as Figs 3-5.

Everything is purely functional: generators take an explicit seed and
generation is independent of sharding (same data for any node layout).
"""

from __future__ import annotations

import numpy as np


def kpca_dataset(n: int, m: int = 784, n_classes: int = 4, seed: int = 0,
                 noise: float = 0.05, dominant: float = 3.0) -> np.ndarray:
    """Nonlinear data with a *dominant* first kernel principal component
    (digits-like regime: MNIST's 0/3/5/8 kernel spectrum has a clear gap,
    which is what makes the paper's similarity metric well-conditioned).

    Structure: one strong shared nonlinear factor (amplitude ``dominant``)
    + per-class offsets + weak secondary factors + isotropic noise, embedded
    into R^m by a frozen random map and squashed to [0, 1].
    Returns (n, m) float32.
    """
    rng = np.random.default_rng(seed)
    latent_dim = 6
    # frozen embedding maps
    w_dom = rng.normal(0, 1.0, size=(2, m)) / np.sqrt(2)
    w_sec = rng.normal(0, 1.0, size=(latent_dim, m)) / np.sqrt(latent_dim)
    offs = rng.normal(0, 0.6, size=(n_classes, m))
    labels = np.arange(n) % n_classes
    # dominant shared 1-D nonlinear factor (a curve, not a line). The
    # harmonic amplitudes are ASYMMETRIC (4/3:1 vs dominant) so the global
    # kernel has a clear top-eigenvalue gap (~2.7-3.0 across seeds at
    # M=784) — symmetric amplitudes create a degenerate top pair that makes
    # the paper's top-1 similarity metric ill-posed for any solver.
    t = rng.uniform(0, 2 * np.pi, size=(n,))
    dom = np.stack([(4.0 / 3.0) * dominant * np.cos(t),
                    0.5 * dominant * np.sin(2 * t)], axis=1)        # (n, 2)
    # weak secondary factors
    sec = np.tanh(rng.normal(0, 1.0, size=(n, latent_dim))) * 0.4
    x = dom @ w_dom + sec @ w_sec + offs[labels]
    x = x + rng.normal(0, noise * np.sqrt(m) / 4, size=(n, m))
    x = 1.0 / (1.0 + np.exp(-x / np.sqrt(m) * 8.0))                 # [0, 1]
    perm = rng.permutation(n)
    return x[perm].astype(np.float32)


def distribute(x: np.ndarray, n_nodes: int, seed: int = 0) -> np.ndarray:
    """Randomly, evenly distribute samples to nodes: (J, N_j, M).
    Truncates the remainder (paper uses exactly even splits)."""
    rng = np.random.default_rng(seed)
    n = (x.shape[0] // n_nodes) * n_nodes
    perm = rng.permutation(x.shape[0])[:n]
    return x[perm].reshape(n_nodes, n // n_nodes, *x.shape[1:])


def node_dataset(n_nodes: int, n_per_node: int, m: int = 784,
                 n_classes: int = 4, seed: int = 0):
    """Convenience: (J, N, M) node-distributed data + the pooled (J*N, M)."""
    x = kpca_dataset(n_nodes * n_per_node, m, n_classes, seed)
    nodes = distribute(x, n_nodes, seed=seed + 1)
    return nodes, nodes.reshape(n_nodes * n_per_node, m)
