"""JAX/Pallas purity and recompilation rules.

Tracing makes a specific class of Python habits silently wrong: host-side
nondeterminism is baked in at trace time (``impure-jit``), Python scalars
captured by closure are frozen into the compiled program and never retrace
(``closure-capture``), a hardcoded ``interpret=True`` ships the Pallas
interpreter to production (``interpret-literal``), and a buffer passed to a
``donate_argnums`` jit is dead the moment the call returns
(``donated-reuse``).

Jitted functions are found syntactically: a ``def`` decorated with
``jax.jit`` / ``partial(jax.jit, ...)`` / ``pl.pallas_call``, or whose name
is passed directly to a ``jax.jit(...)`` / ``pallas_call(...)`` call in the
same file. Analysis is file-local and does not follow calls — a helper
called FROM a jitted function is not scanned (annotate hot helpers with
their own decorator, or pragma the call site).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..engine import FileContext, Finding, Rule, register

_JIT_NAMES = {"jit", "pallas_call"}


def _mentions_jit(expr: ast.AST) -> bool:
    """Does a decorator / call-func expression refer to jax.jit or
    pallas_call (possibly through functools.partial)?"""
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in _JIT_NAMES:
            return True
        if isinstance(node, ast.Attribute) and node.attr in _JIT_NAMES:
            return True
    return False


def jitted_defs(tree: ast.Module) -> List[ast.FunctionDef]:
    """Every function definition that is traced: jit/pallas decorated, or
    passed by name to a jit/pallas_call call somewhere in the file."""
    wrapped_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _mentions_jit(node.func):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    wrapped_names.add(arg.id)
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if any(_mentions_jit(d) for d in node.decorator_list):
            out.append(node)
        elif node.name in wrapped_names:
            out.append(node)
    return out


# ---------------------------------------------------------------------------

_IMPURE_MODULES = {"time", "random"}
_IMPURE_RANDOM_ROOTS = {"np", "numpy"}


@register
class ImpureJitRule(Rule):
    name = "impure-jit"
    summary = ("no time.*/random.*/np.random.* inside a jitted or "
               "pallas_call-wrapped function (baked in at trace time)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in jitted_defs(ctx.tree):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                culprit = self._impure(node.func)
                if culprit:
                    yield self.finding(
                        ctx, node,
                        f"'{culprit}' inside traced function "
                        f"'{fn.name}' runs ONCE at trace time, not per "
                        f"call — thread a jax PRNG key / pass the value "
                        f"as an argument instead")

    @staticmethod
    def _impure(fn) -> Optional[str]:
        if not isinstance(fn, ast.Attribute):
            return None
        if isinstance(fn.value, ast.Name):
            if fn.value.id in _IMPURE_MODULES:
                return f"{fn.value.id}.{fn.attr}"
        if isinstance(fn.value, ast.Attribute) and \
                isinstance(fn.value.value, ast.Name) and \
                fn.value.value.id in _IMPURE_RANDOM_ROOTS and \
                fn.value.attr == "random":
            return f"{fn.value.value.id}.random.{fn.attr}"
        return None


# ---------------------------------------------------------------------------


def _is_scalar_expr(expr: ast.AST) -> bool:
    """Syntactically-a-Python-scalar: literals, arithmetic on literals, or
    int()/float()/len()/bool() results."""
    if isinstance(expr, ast.Constant):
        return isinstance(expr.value, (int, float, bool))
    if isinstance(expr, ast.UnaryOp):
        return _is_scalar_expr(expr.operand)
    if isinstance(expr, ast.BinOp):
        return _is_scalar_expr(expr.left) or _is_scalar_expr(expr.right)
    if isinstance(expr, ast.BoolOp):
        return any(_is_scalar_expr(v) for v in expr.values)
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name):
        return expr.func.id in ("int", "float", "len", "bool")
    return False


@register
class ClosureCaptureRule(Rule):
    name = "closure-capture"
    summary = ("a Python scalar captured by closure in a jitted function "
               "is frozen at trace time (recompilation/staleness hazard)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in jitted_defs(ctx.tree):
            enclosing = self._enclosing_fns(fn)
            if not enclosing:
                continue            # module-level def: globals, not closure
            scalars = self._scalar_assignments(enclosing, fn)
            if not scalars:
                continue
            bound = self._bound_names(fn)
            for node in ast.walk(fn):
                if (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id in scalars and node.id not in bound):
                    yield self.finding(
                        ctx, node,
                        f"jitted '{fn.name}' closes over Python scalar "
                        f"'{node.id}' (assigned at line "
                        f"{scalars[node.id]}) — it is frozen into the "
                        f"compiled program; pass it as an argument (or "
                        f"mark it static) so updates take effect")
                    break           # one finding per captured name is plenty

    @staticmethod
    def _enclosing_fns(fn) -> List[ast.AST]:
        out, node = [], fn
        while hasattr(node, "parent"):
            node = node.parent
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(node)
        return out

    @staticmethod
    def _scalar_assignments(enclosing, fn) -> Dict[str, int]:
        out: Dict[str, int] = {}
        inside_fn = set(map(id, ast.walk(fn)))   # exclude the jitted subtree
        for outer in enclosing:
            for node in ast.walk(outer):
                if id(node) in inside_fn:
                    continue
                if isinstance(node, ast.Assign) and \
                        _is_scalar_expr(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = node.lineno
        return out

    @staticmethod
    def _bound_names(fn) -> Set[str]:
        bound = {a.arg for a in fn.args.args + fn.args.kwonlyargs
                 + fn.args.posonlyargs}
        if fn.args.vararg:
            bound.add(fn.args.vararg.arg)
        if fn.args.kwarg:
            bound.add(fn.args.kwarg.arg)
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and \
                    isinstance(node.ctx, (ast.Store, ast.Del)):
                bound.add(node.id)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                bound.add(node.name)
        return bound


# ---------------------------------------------------------------------------


@register
class InterpretLiteralRule(Rule):
    name = "interpret-literal"
    summary = ("hardcoded interpret=True outside tests ships the Pallas "
               "interpreter to production")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.is_test:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg == "interpret" and \
                        isinstance(kw.value, ast.Constant) and \
                        kw.value.value is True:
                    yield self.finding(
                        ctx, kw.value,
                        "hardcoded 'interpret=True' — plumb the flag "
                        "(resolved per-backend) instead of pinning the "
                        "interpreter on")


# ---------------------------------------------------------------------------


@register
class DonatedReuseRule(Rule):
    name = "donated-reuse"
    summary = ("an argument donated via donate_argnums is dead after the "
               "call; reusing it reads freed device memory")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        donated = self._donating_callables(ctx.tree)
        if not donated:
            return
        for scope in ast.walk(ctx.tree):
            if not isinstance(scope, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Module)):
                continue
            yield from self._scan_scope(ctx, scope, donated)

    # -- which names are donate_argnums-jitted callables --------------------

    @staticmethod
    def _donating_callables(tree) -> Dict[str, Tuple[int, ...]]:
        out: Dict[str, Tuple[int, ...]] = {}

        def argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
            for kw in call.keywords:
                if kw.arg != "donate_argnums":
                    continue
                v = kw.value
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    return (v.value,)
                if isinstance(v, (ast.Tuple, ast.List)):
                    vals = []
                    for e in v.elts:
                        if isinstance(e, ast.Constant) and \
                                isinstance(e.value, int):
                            vals.append(e.value)
                    return tuple(vals) or None
            return None

        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.value, ast.Call) and \
                    _mentions_jit(node.value.func):
                nums = argnums(node.value)
                if nums:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out[t.id] = nums
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and _mentions_jit(dec):
                        nums = argnums(dec)
                        if nums:
                            out[node.name] = nums
        return out

    # -- donated-name liveness inside one scope -----------------------------

    def _scan_scope(self, ctx, scope, donated) -> Iterator[Finding]:
        body_nodes = self._scope_nodes(scope)
        for node in body_nodes:
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in donated):
                continue
            rebound = self._stmt_targets(node)
            for pos in donated[node.func.id]:
                if pos >= len(node.args):
                    continue
                arg = node.args[pos]
                if not isinstance(arg, ast.Name) or arg.id in rebound:
                    continue
                use = self._later_use(body_nodes, arg.id, node.lineno)
                if use is not None:
                    yield Finding(
                        self.name, ctx.path, use.lineno, use.col_offset,
                        f"'{arg.id}' was donated to '{node.func.id}' at "
                        f"line {node.lineno} (donate_argnums) — its buffer "
                        f"is freed; rebind the result instead of reusing "
                        f"the input")

    @staticmethod
    def _scope_nodes(scope) -> List[ast.AST]:
        """Nodes belonging to ``scope`` itself — nested function bodies are
        their own scope and are excluded (a module-level donated call must
        not be related to same-named uses inside unrelated functions)."""
        out: List[ast.AST] = []
        stack: List[ast.AST] = [scope]
        while stack:
            node = stack.pop()
            out.append(node)
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue     # nested defs are scanned as their own scope
                stack.append(child)
        return out

    @staticmethod
    def _stmt_targets(call: ast.Call) -> Set[str]:
        """Names the statement containing ``call`` assigns to (the
        ``x = f(x)`` donation idiom rebinds the name)."""
        node = call
        while hasattr(node, "parent"):
            parent = node.parent
            if isinstance(parent, ast.Assign):
                out: Set[str] = set()
                for t in parent.targets:
                    for sub in ast.walk(t):
                        if isinstance(sub, ast.Name):
                            out.add(sub.id)
                return out
            if isinstance(parent, (ast.stmt, ast.Module)):
                return set()
            node = parent
        return set()

    @staticmethod
    def _later_use(body_nodes, name: str, after_line: int):
        """First Load of ``name`` after ``after_line``, unless a Store
        rebinds it first."""
        first_load, first_store = None, None
        for node in body_nodes:
            if not isinstance(node, ast.Name) or node.id != name:
                continue
            if node.lineno <= after_line:
                continue
            if isinstance(node.ctx, ast.Load):
                if first_load is None or node.lineno < first_load.lineno:
                    first_load = node
            else:
                if first_store is None or node.lineno < first_store.lineno:
                    first_store = node
        if first_load is None:
            return None
        if first_store is not None and \
                first_store.lineno <= first_load.lineno:
            return None
        return first_load
