"""Versioned model publishing: the trainer-to-server hand-off.

A still-running ADMM driver (``repro.core.solver.run_chunked``) produces a
stream of coefficient snapshots; the serving side must pick them up without
dropping or mixing in-flight work. ``ModelHandle`` is the seam: a
thread-safe, versioned, atomically-swappable reference to a servable model.
``KpcaEngine`` reads THROUGH the handle — each drain snapshots (model,
version) once up front, so every slab of that drain scores against one
consistent model version even if a publish lands mid-drain; the next drain
sees the new version. Sharded models swap per shard the same way
(``refresh_shard``): the rebuilt model is still ONE atomic publish, so a
request can never observe a mix of shard versions. Publishing never blocks
serving (the swap is a reference assignment under a lock, not a copy).

The reverse direction must not block either: rebuilding + publishing a
refresh stalls the solver driver for the refresh cost every time it fires.
``BackgroundPublisher`` moves that work off-thread — the driver hands the
live alpha over in O(1) and keeps iterating; a publisher thread performs
refresh + publish, coalescing latest-wins per target (a stale snapshot that
was never published is pure waste), mirroring how DeEPCA/COKE overlap
computation with communication.

End-to-end streaming glue: ``stream_chunks`` consumes a ``run_chunked``
iterator and republishes a refreshed model under a pluggable cadence
policy (``repro.core.solver``: fixed every-k or residual-improvement
triggered), optionally through a ``BackgroundPublisher``.
"""

from __future__ import annotations

import threading
from typing import Iterable, List, Optional, Tuple

from ..core import oos
from ..core.solver import resolve_refresh_policy
from ..obs import metrics, trace

# Module-level cached handles: every ModelHandle/BackgroundPublisher in the
# process shares these (publishes are process-wide events, and resolving
# once keeps registry lookups off the publish path).
_M_PUBLISHES = metrics.counter(
    "publish_swaps_total", "Model versions atomically published")
_M_COALESCED = metrics.counter(
    "publish_coalesced_total", "Snapshots dropped unpublished (latest-wins)")
_M_ERRORS = metrics.counter(
    "publish_errors_total", "Publisher worker jobs that raised")


class ModelHandle:
    """Thread-safe versioned reference to a servable kPCA model.

    The handle pins the model TYPE at construction (``FittedKpca`` or
    ``ShardedFittedKpca``) — and, for sharded models, the shard count: the
    engine compiles its projection path against that type (and its mesh
    against that shard count), so a publish may change coefficients/shapes
    (jit re-traces on shape changes) but not the artifact kind or the
    shard layout.
    """

    def __init__(self, model, version: int = 0):
        self._lock = threading.Lock()
        # Serializes the read-rebuild-publish cycle of refresh/
        # refresh_shard: two concurrent refreshes must not both rebuild
        # from the same base and silently drop one of the updates.
        self._refresh_lock = threading.Lock()
        self._model = model                 # guarded-by: _lock
        self._version = version             # guarded-by: _lock
        self._kind = type(model)
        # the engine's compiled sharded path also pins its mesh to the
        # initial shard count, so that is part of the contract too
        self._n_shards = getattr(model, "n_shards", None)

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def current(self):
        """The live model (convenience; use ``get`` when the matching
        version number matters)."""
        with self._lock:
            return self._model

    def get(self) -> Tuple[object, int]:
        """Consistent (model, version) snapshot — THE read path: take it
        once per batch so all work in the batch serves one version."""
        with self._lock:
            return self._model, self._version

    def publish(self, model) -> int:
        """Atomically swap in a new model; returns its version number.

        In-flight readers keep the snapshot they took; only subsequent
        ``get``/``current`` calls see the new model.
        """
        if not isinstance(model, self._kind):
            raise TypeError(
                f"handle serves {self._kind.__name__}, got "
                f"{type(model).__name__}")
        if self._n_shards is not None and model.n_shards != self._n_shards:
            raise ValueError(
                f"handle serves a {self._n_shards}-shard model (the "
                f"engine's mesh is pinned to it), got {model.n_shards} "
                f"shards — re-shard behind a new engine instead")
        with self._lock:
            self._model = model
            self._version += 1
            version = self._version
        trace.instant("publish.swap", version=version)
        _M_PUBLISHES.inc()
        return version

    def refresh(self, alpha) -> int:
        """Publish the current model rebuilt around live dual coefficients
        (``repro.core.oos.refresh_coefficients`` — reuses the cached
        kernel-mean statistics; sharded models rebuild per shard). Returns
        the new version. Compressed models cannot refresh — build and
        ``publish`` a re-compressed model instead. Refreshes from
        different threads serialize, so none is silently lost."""
        with self._refresh_lock:
            with trace.span("publish.refresh"):
                model = oos.refresh_coefficients(self.current(), alpha)
            return self.publish(model)

    def refresh_shard(self, shard: int, alpha) -> int:
        """Publish the current SHARDED model with one shard's coefficient
        rows swapped (``repro.core.oos.refresh_shard_coefficients`` —
        global centering rebuilt from the per-shard cached stats). The
        swap is still one atomic whole-model publish: concurrent readers
        see the old model or the new one, never a mix of shards; and
        concurrent refreshes serialize, so two threads swapping DIFFERENT
        shards both land. Returns the new version."""
        with self._refresh_lock:
            with trace.span("publish.refresh", shard=shard):
                model = oos.refresh_shard_coefficients(
                    self.current(), shard, alpha)
            return self.publish(model)


class BackgroundPublisher:
    """Non-blocking publish pipeline: hand coefficients over in O(1), a
    daemon thread does the refresh + publish.

    Jobs are coalesced LATEST-WINS per target — the whole model, or one
    shard index: if the producer outpaces the publisher, intermediate
    snapshots for the same target are dropped unpublished (``n_coalesced``
    counts them), because only the freshest coefficients matter to the
    serving side. Job order across targets is preserved (FIFO of targets).

    A worker-side failure is remembered and re-raised at the next
    ``drain``/``close`` on the caller's thread — the worker itself keeps
    serving later jobs. Use as a context manager to guarantee the thread
    is joined:

        with BackgroundPublisher(handle) as pub:
            for chunk in run_chunked(...):
                pub.refresh(chunk.state.alpha)      # never blocks
        # exit == drain (everything published) + join
    """

    def __init__(self, handle: ModelHandle):
        self.handle = handle
        self._cond = threading.Condition()
        self._jobs = {}                  # key -> payload   guarded-by: _cond
        self._order: List[tuple] = []    # FIFO of keys     guarded-by: _cond
        self._busy = False                  # guarded-by: _cond
        self._closed = False                # guarded-by: _cond
        self._errors: List[BaseException] = []  # guarded-by: _cond
        self.n_published = 0                # guarded-by: _cond
        self.n_coalesced = 0                # guarded-by: _cond
        self._thread = threading.Thread(
            target=self._run, name="kpca-publisher", daemon=True)
        self._thread.start()

    # -- producer side ------------------------------------------------------

    def refresh(self, alpha) -> None:
        """Queue a whole-model coefficient refresh (latest-wins)."""
        self._enqueue(("refresh", None), alpha)

    def refresh_shard(self, shard: int, alpha) -> None:
        """Queue a single-shard coefficient refresh (latest-wins per
        shard index)."""
        self._enqueue(("shard", shard), alpha)

    def publish(self, model) -> None:
        """Queue a prebuilt model publish (latest-wins)."""
        self._enqueue(("publish", None), model)

    def _enqueue(self, key, payload) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("publisher is closed")
            coalesced = key in self._jobs
            if coalesced:
                self.n_coalesced += 1
            else:
                self._order.append(key)
            self._jobs[key] = payload
            self._cond.notify_all()
        if coalesced:
            _M_COALESCED.inc()
            trace.instant("publish.coalesced", target=str(key))

    # -- lifecycle ----------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every queued job has been published; re-raises the
        first worker-side error if any occurred since the last drain."""
        with self._cond:
            if not self._cond.wait_for(
                    lambda: not self._order and not self._busy,
                    timeout=timeout):
                raise TimeoutError("publisher did not drain in time")
            self._reraise_locked()

    def close(self, timeout: float = 30.0) -> None:
        """Drain remaining jobs, stop and JOIN the worker thread.
        Idempotent; re-raises a pending worker error."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():      # pragma: no cover
            raise RuntimeError("publisher thread failed to stop")
        with self._cond:
            self._reraise_locked()

    def _reraise_locked(self) -> None:  # holds-lock: _cond
        if self._errors:
            err, self._errors = self._errors[0], []
            raise err

    def __enter__(self) -> "BackgroundPublisher":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._order and not self._closed:
                    self._cond.wait()
                if not self._order:      # closed and fully drained
                    return
                key = self._order.pop(0)
                payload = self._jobs.pop(key)
                self._busy = True
            try:
                kind, shard = key
                with trace.span("publish.job", kind=kind,
                                shard=-1 if shard is None else shard):
                    if kind == "refresh":
                        self.handle.refresh(payload)
                    elif kind == "shard":
                        self.handle.refresh_shard(shard, payload)
                    else:
                        self.handle.publish(payload)
                ok = True
            except BaseException as e:   # remembered, reraised at drain
                ok = False
                _M_ERRORS.inc()
                with self._cond:
                    self._errors.append(e)
            with self._cond:
                if ok:
                    self.n_published += 1
                self._busy = False
                self._cond.notify_all()


def stream_chunks(chunks: Iterable, handle: ModelHandle,
                  every: Optional[int] = None, policy=None,
                  publisher: Optional[BackgroundPublisher] = None):
    """Drive a ``repro.core.solver.run_chunked`` iterator to completion,
    refreshing ``handle`` from the live state under a cadence policy (and
    always at the last chunk, so the served model never lags the finished
    fit). Returns the final ``ChunkResult`` (None if the iterator was
    empty).

    Args:
      chunks: the driver's ``ChunkResult`` iterator.
      handle: publish target.
      every: fixed cadence shorthand — refresh each ``every`` chunks
        (``repro.core.solver.EveryK``). Mutually exclusive with
        ``policy``; both None means every chunk.
      policy: pluggable cadence — anything
        ``repro.core.solver.resolve_refresh_policy`` accepts: an int, the
        string "residual" (``ResidualImprovement``: publish only when the
        primal residual improved by >= 10% since the last publish), a
        ``should_refresh(ChunkResult) -> bool`` object, or a bare
        callable.
      publisher: route refreshes through a ``BackgroundPublisher`` so the
        driver loop never blocks on a publish; drained (all snapshots
        published, worker errors re-raised) before returning. The caller
        still owns ``close``.
    """
    if every is not None and policy is not None:
        raise ValueError("pass either every= or policy=, not both")
    pol = resolve_refresh_policy(policy if policy is not None else every)
    target = publisher if publisher is not None else handle
    # COKE-style cadence accounting: every should_refresh decision is an
    # event — "fired" (snapshot published) or "censored" (communication
    # saved), labeled by the policy that made it.
    pol_name = type(pol).__name__
    m_fired = metrics.counter(
        "solver_refresh_fired_total",
        "Refresh-policy decisions that published", policy=pol_name)
    m_censored = metrics.counter(
        "solver_refresh_censored_total",
        "Refresh-policy decisions that skipped a publish", policy=pol_name)
    last = None
    pending = False
    for chunk in chunks:
        last = chunk
        fired = pol.should_refresh(chunk)
        if trace.is_enabled():
            trace.instant("solver.refresh_decision", fired=fired,
                          policy=pol_name, t=int(chunk.state.t))
        if fired:
            m_fired.inc()
            target.refresh(chunk.state.alpha)
            pending = False
        else:
            m_censored.inc()
            pending = True
    if last is not None and pending:
        target.refresh(last.state.alpha)
    if publisher is not None:
        publisher.drain()
    return last


__all__ = ["BackgroundPublisher", "ModelHandle", "stream_chunks"]
