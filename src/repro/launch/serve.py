"""Serving launcher: batched greedy/temperature decode with slot reuse.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
        --prompts 8 --max-new 16
"""

from __future__ import annotations

from . import env as _env
_env.apply_from_environ()          # before any jax-importing import

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..serve import DecodeEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompts", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12)).tolist()
               for _ in range(args.prompts)]
    engine = DecodeEngine(model, params, args.slots,
                          ServeConfig(max_len=64,
                                      max_new_tokens=args.max_new,
                                      temperature=args.temperature))
    t0 = time.perf_counter()
    outs = engine.generate(prompts)
    dt = time.perf_counter() - t0
    total = sum(len(o) for o in outs)
    print(f"served {len(prompts)} prompts, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  [{i}] {o}")


if __name__ == "__main__":
    main()
