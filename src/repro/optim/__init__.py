from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .compression import (compress_local, compressed_psum_grads,
                          compression_ratio, init_compression_state)
from .schedule import cosine_with_warmup

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "compress_local",
           "compressed_psum_grads", "compression_ratio",
           "cosine_with_warmup", "global_norm", "init_compression_state"]
